"""End-to-end serving driver: batched requests through the wave engine
(prefill + KV-cache decode) on a reduced model, with per-wave stats.

Run: PYTHONPATH=src python examples/serve_lm.py [--requests 12]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve.engine import Engine, Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3-8b")
ap.add_argument("--requests", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--max-new", type=int, default=16)
args = ap.parse_args()

cfg = registry.smoke_config(args.arch)
model = lm.build(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = Engine(model, params, batch_slots=args.slots, max_len=64)

rng = np.random.default_rng(0)
reqs = [Request(i, rng.integers(2, cfg.vocab, rng.integers(4, 12))
                .astype(np.int32), max_new_tokens=args.max_new)
        for i in range(args.requests)]

t0 = time.perf_counter()
results = eng.serve(reqs)
dt = time.perf_counter() - t0
n_tok = sum(len(r.tokens) for r in results)
print(f"served {len(results)} requests in {dt:.2f}s "
      f"({args.slots} slots/wave): {n_tok} tokens, "
      f"{n_tok / dt:.1f} tok/s on CPU")
for r in results[:5]:
    print(f"  req {r.uid}: {len(r.tokens)} tokens -> {r.tokens[:8]}...")

"""Beyond-paper: GreenPod TOPSIS as the placement engine for a TPU fleet.

Loads the compiled dry-run roofline records (launch/dryrun.py output) as
schedulable JOBS and places them on a heterogeneous fleet of slices with the
paper's weighting schemes. Shows the energy-centric vs performance-centric
allocation difference — the TPU analogue of paper §V.D — and straggler
re-placement.

Run: PYTHONPATH=src python examples/fleet_scheduler.py [dryrun_dir]
"""
import sys

from repro.launch import fleet

dryrun_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
jobs = fleet.load_jobs(dryrun_dir)
if not jobs:
    # standalone demo jobs if no dry-run artifacts exist yet
    jobs = [fleet.Job("llama3-8b", "train_4k", 256, 1.5, 12.0, 8.0, 8e9),
            fleet.Job("gemma-7b", "prefill_32k", 256, 0.6, 3.5, 1.7, 8e9),
            fleet.Job("rwkv6-1.6b", "decode_32k", 256, 1e-5, 1e-3, 4e-4,
                      1e9)]
print(f"{len(jobs)} jobs loaded from {dryrun_dir}")


def new_fleet():
    return [fleet.Slice("v5e-0", 256, 256, "v5e"),
            fleet.Slice("v5e-1", 256, 256, "v5e"),
            fleet.Slice("v4-0", 256, 256, "v4"),
            fleet.Slice("v5p-0", 256, 256, "v5p"),
            fleet.Slice("v5p-1", 512, 512, "v5p")]


for scheme in ("energy_centric", "performance_centric"):
    slices = new_fleet()
    placed = fleet.schedule_queue(jobs[:5], slices, scheme)
    print(f"\n--- scheme: {scheme}")
    for job, idx in placed:
        where = slices[idx].name if idx is not None else "UNSCHEDULABLE"
        step, energy = (fleet.job_on_slice(job, slices[idx])
                        if idx is not None else (float('nan'), float('nan')))
        print(f"  {job.arch:22s} {job.shape:12s} -> {where:8s} "
              f"step={step:9.3e}s energy={energy / 1e3:9.2f} kJ")

# --- straggler mitigation -------------------------------------------------------
slices = new_fleet()
job = jobs[0]
cur, _ = fleet.place(job, slices, "energy_centric")
print(f"\njob {job.arch}/{job.shape} initially on {slices[cur].name}")
new = fleet.replace_slice(job, slices, cur, "energy_centric")
print(f"straggler alert -> degraded {slices[cur].name} (health "
      f"{slices[cur].health:.1f}x), re-placed on {slices[new].name}")

# --- fleet-scale batched scheduling ---------------------------------------------
# The paper's cluster has 4 nodes; the batched engine scores a whole queue
# of pods against thousands of candidate nodes in one TOPSIS pass
# (BatchScheduler.select_many — numpy for reference, jax/pallas for
# throughput; see benchmarks/scheduling_time.py for the full sweep).
import time

from repro.core.scheduler import BatchScheduler
from repro.cluster.node import make_fleet
from repro.cluster.workload import WORKLOADS, Pod

N_NODES, N_PODS = 2048, 64
table = make_fleet(N_NODES, seed=0, utilization=0.3)
queue = [Pod(i, WORKLOADS[("light", "medium", "complex")[i % 3]], "topsis")
         for i in range(N_PODS)]
print(f"\n--- batched fleet scheduling: {N_PODS} pods x {N_NODES} nodes")
for backend in ("numpy", "jax"):
    sched = BatchScheduler("energy_centric", backend=backend)
    sched.select_many(queue, table)            # warm up (jit compile)
    t0 = time.perf_counter()
    assignments, diag = sched.select_many(queue, table)
    dt = time.perf_counter() - t0
    placed = sum(a is not None for a in assignments)
    print(f"  {backend:6s}: {placed}/{N_PODS} placed in {dt * 1e3:7.2f} ms "
          f"({diag['per_pod_time_s'] * 1e6:.0f} us/pod)")

# --- event-driven scenario: Poisson bursts, time-resolved energy ----------------
# Beyond the one-shot queue above: stream Poisson arrival bursts onto an
# edge-heavy fleet through the event-driven engine (run_scenario), each
# burst scored in one select_many pass, energy read off the per-node power
# timeline as a cumulative series instead of a single post-hoc total.
from repro.cluster.node import make_scenario_cluster
from repro.cluster.simulator import run_scenario
from repro.cluster.workload import PoissonArrivals

arrivals = PoissonArrivals(rate_per_s=0.2, n_bursts=6, burst_size=12, seed=0)
res = run_scenario(arrivals, "energy_centric",
                   cluster_factory=lambda: make_scenario_cluster(
                       "edge_heavy", 64, seed=0),
                   batch=True, batch_backend="jax")
print(f"\n--- event-driven scenario: {arrivals.total_pods()} pods in "
      f"{arrivals.n_bursts} Poisson bursts on 64 edge-heavy nodes")
# SimResult.summary() rolls up the per-scheduler metrics the sweeps record
summary = res.summary()
sched_stats = summary["schedulers"]
print(f"  unschedulable rate: {summary['unschedulable_rate']:.3f}   "
      f"TOPSIS {sched_stats['topsis']['energy_kj']:.2f} kJ vs "
      f"default {sched_stats['default']['energy_kj']:.2f} kJ")
print(f"  TOPSIS per-pod mean: {sched_stats['topsis']['mean_energy_kj']:.3f} kJ, "
      f"{sched_stats['topsis']['mean_sched_time_ms']:.2f} ms/decision, "
      f"allocation {sched_stats['topsis']['allocation']}")
edges, joules = res.energy_series("topsis")
for k in range(0, len(edges), max(1, len(edges) // 6)):
    print(f"  t={edges[k]:8.1f}s  cumulative TOPSIS energy "
          f"{joules[k] / 1e3:7.3f} kJ")

# --- carbon-aware scheduling: grid signals, deferral, preemption ----------------
# The fleet's nodes sit in regions with a staggered sinusoidal grid-carbon
# signal (all near peak at t=0, dipping within the run). carbon_centric
# weights the sixth TOPSIS criterion (node power x regional intensity at
# decision time) to chase clean regions; the CarbonPolicy additionally
# defers deferrable pods until the fleet-wide dip (bounded by their
# deadline) and preempts running deferrable tasks off spiking regions.
# Carbon is integrated exactly over the power timeline (power x intensity).
from repro.core.carbon import CarbonPolicy, diurnal_fleet_signal

period = 1800.0
signal = diurnal_fleet_signal(base=300.0, amplitude=200.0, period_s=period,
                              phase_s=period / 4.0, stagger_s=period / 16.0)
policy = CarbonPolicy(signal, defer_threshold=300.0,
                      preempt_threshold=450.0, check_interval_s=30.0)
carbon_arrivals = lambda: PoissonArrivals(
    rate_per_s=0.2, n_bursts=6, burst_size=12, seed=0,
    deferrable_share=0.5, deadline_s=period / 2.0)
print("\n--- carbon-aware scenario: staggered diurnal signal on 64 mixed "
      "nodes")
for scheme in ("energy_centric", "carbon_centric"):
    res = run_scenario(carbon_arrivals(), scheme,
                       cluster_factory=lambda: make_scenario_cluster(
                           "mixed", 64, seed=0),
                       batch=True, batch_backend="jax", carbon=policy)
    print(f"  {scheme:22s}: {res.energy_kj('topsis'):6.2f} kJ  "
          f"{res.total_carbon_g('topsis'):6.3f} gCO2  "
          f"defer {res.mean_deferral_latency_s('topsis'):5.1f}s  "
          f"preemptions {res.preemptions}")
edges, grams = res.carbon_series("topsis")
for k in range(0, len(edges), max(1, len(edges) // 4)):
    print(f"  t={edges[k]:8.1f}s  cumulative TOPSIS carbon "
          f"{grams[k]:7.4f} g")

# --- elastic fleet: idle-timeout sleep + TOPSIS-driven consolidation ------------
# Without a node lifecycle the fleet pays every node's idle power for the
# whole run. AutoscalePolicy sleeps nodes empty past the idle timeout
# (queue pressure wakes the TOPSIS-best sleeping node back up; pods landing
# on a WAKING node start after its wake latency), and the consolidation
# pass drains low-utilization nodes through the preemption machinery, then
# puts them straight to sleep. Fleet idle energy — busy-union idle + the
# IDLE/ASLEEP/WAKING state ledger + wake surges — drops accordingly.
from repro.core.elastic import AutoscalePolicy, always_on_fleet_idle_kj

elastic_arrivals = lambda: PoissonArrivals(rate_per_s=0.2, n_bursts=6,
                                           burst_size=12, seed=0)
mixed_fleet = lambda: make_scenario_cluster("mixed", 64, seed=0)
print("\n--- elastic fleet: idle-timeout + consolidation on 64 mixed nodes")
runs = {}
for name, pol in (
        ("no policy (always-on)", None),
        ("idle-timeout 60s", AutoscalePolicy(idle_timeout_s=60.0)),
        ("+ consolidation", AutoscalePolicy(idle_timeout_s=60.0,
                                            consolidate_interval_s=30.0,
                                            consolidate_util_below=0.3))):
    res = run_scenario(elastic_arrivals(), "energy_centric",
                       cluster_factory=mixed_fleet, batch=True,
                       batch_backend="jax", autoscale=pol)
    horizon = max(r.start_s + r.runtime_s for r in res.records)
    if pol is None:
        # lifecycle-free engine: every node draws idle power all run long
        idle_kj = always_on_fleet_idle_kj(mixed_fleet(), horizon)
    else:
        idle_kj = res.fleet_idle_energy_kj()
    runs[name] = idle_kj
    print(f"  {name:22s}: fleet idle {idle_kj:7.2f} kJ  "
          f"wakes {res.wakes:2d}  sleeps {res.sleeps:2d}  "
          f"migrations {res.migrations:2d}")
base = runs["no policy (always-on)"]
for name, kj in runs.items():
    if name != "no policy (always-on)":
        print(f"  {name:22s}: {100.0 * (1.0 - kj / base):.1f}% less fleet "
              f"idle energy than the always-on baseline")

# --- flight recorder: telemetry, decision latency, Perfetto trace ---------------
# Re-run the carbon+autoscale scenario with the flight recorder on. The
# recorder is a pure observer (placements and energy totals are bitwise
# identical with it enabled — tests/test_telemetry.py pins this); what it
# adds is the operator view: engine/cache counters, per-decision latency
# histograms, and a Chrome trace-event file for ui.perfetto.dev with one
# track group per node (task lanes + power states) and one per policy.
from repro.core import telemetry
from repro.telemetry.export import write_perfetto

with telemetry.enabled() as tel:
    res = run_scenario(carbon_arrivals(), "carbon_centric",
                       cluster_factory=mixed_fleet, batch=True,
                       batch_backend="jax", carbon=policy,
                       autoscale=AutoscalePolicy(idle_timeout_s=60.0))
print("\n--- flight recorder: carbon+autoscale scenario, telemetry on")
print(f"  events: "
      + "  ".join(f"{k}={int(tel.counter_value('engine_events', kind=k))}"
                  for k in ("arrival", "completion", "carbon_check",
                            "wake_done")))
print(f"  rounds {len([s for s in tel.spans if s['name'] == 'engine_round'])}"
      f"  deferral holds "
      f"{int(tel.counter_value('policy_deferred_pods', policy='CarbonScheduling'))}"
      f"  wakes "
      f"{int(tel.counter_value('policy_node_wakes', policy='AutoscaleScheduling'))}")
hist = tel.histogram("scheduler_batch_seconds", scheduler="topsis-batch",
                     backend="jax")
if hist is not None:
    print(f"  batch decision latency ({hist.count} rounds, "
          f"min {hist.min * 1e3:.2f} ms, max {hist.max * 1e3:.2f} ms):")
    for edge, c in zip(hist.edges, hist.counts):
        if c:
            print(f"    le {edge * 1e3:9.3f} ms : {'#' * c} {c}")
trace_path = write_perfetto(res, "fleet_scheduler.trace.json",
                            trace_name="carbon+autoscale demo")
print(f"  wrote {trace_path} — open at https://ui.perfetto.dev")

# --- why TOPSIS picked that node: per-criterion attribution ---------------------
# explain=True (numpy scoring) records, per decision, how each criterion
# moved the winner-vs-runner-up closeness gap — the deltas sum to the gap
# exactly, so "why this node" reads off as six signed numbers.
res = run_scenario(elastic_arrivals(), "energy_centric",
                   cluster_factory=mixed_fleet, batch=True,
                   batch_backend="numpy", explain=True)
exp = max((e for e in res.explanations if e["runner_up"] is not None),
          key=lambda e: abs(e["gap"]))
print(f"\n--- decision explainability: pod {exp['pod']} -> {exp['node']} "
      f"(runner-up {exp['runner_up_node']}, "
      f"gap {exp['gap']:+.4f} closeness)")
for c in sorted(exp["contributions"], key=lambda c: -abs(c["delta_cc"])):
    print(f"  {c['criterion']:16s} delta_cc {c['delta_cc']:+.4f}   "
          f"winner {c['winner_value']:10.4f}  vs  "
          f"runner-up {c['runner_up_value']:10.4f}")

# --- operator HTML report + benchmark regression gate ---------------------------
# With the recorder on, the registry also carries sim-time timelines
# (queue depth, fleet power, cumulative energy/carbon at every clock
# advance); html_report renders them — plus the run summary and the
# TOPSIS explanation table — as a single dependency-free HTML file with
# inline-SVG charts, the same artifact CI uploads for every PR.
from repro.telemetry.report import write_html_report

with telemetry.enabled() as tel:
    res = run_scenario(elastic_arrivals(), "energy_centric",
                       cluster_factory=mixed_fleet, batch=True,
                       batch_backend="numpy", explain=True)
report_path = write_html_report("fleet_scheduler_report.html", tel=tel,
                                result=res, title="fleet scheduler demo")
print(f"\n--- operator report: wrote {report_path} "
      f"({len(tel.timeseries)} series charted) — open in a browser")

# Cross-run regression gating: compare_reports diffs two recorded
# BENCH_*.json cell-by-cell (exact physics at 1e-6 relative, wall-clock
# timings one-sided at +75%). `python -m benchmarks.run --check` runs
# this against the committed baselines and exits nonzero on regression.
from repro.telemetry.baseline import compare_reports, format_verdict

cells = [{"profile": "mixed", "n_nodes": 8, "backend": "numpy",
          "energy_topsis_kj": 10.0, "mean_sched_time_topsis_ms": 5.0}]
baseline = {"bench": "demo_sweep", "results": cells}
drifted = {"bench": "demo_sweep",
           "results": [dict(cells[0], energy_topsis_kj=10.4,
                            mean_sched_time_topsis_ms=6.0)]}
print("\n--- regression gate: 4% energy drift trips, 20% timing "
      "noise does not")
print(format_verdict(compare_reports(drifted, baseline)))

# --- Pareto frontier: 512 weighting schemes in one fused dispatch ---------------
# The paper ships five hand-named schemes; the grid engine scores an
# entire simplex lattice of them at once. select_many_grid places the
# same queue under all 512 schemes in one (S x P x N) dispatch per
# round, placement_metrics reads predicted energy / latency / carbon off
# the decision tensor, pareto_mask keeps only the non-dominated schemes,
# and the atlas answers the operator question: which weighting dominates
# under which carbon regime?
from repro.core import pareto
from repro.core.carbon import ConstantCarbon

frontier_pods = [Pod(i, WORKLOADS[("light", "medium", "complex")[i % 3]],
                     "topsis") for i in range(24)]
frontier_nodes = make_scenario_cluster("mixed", 128, seed=0)
ws = pareto.weight_grid_upto(512, criteria=6)   # 6th column = carbon weight
# two regional regimes (flat intensities would make carbon ∝ energy and
# collapse the trade-off): a mild split vs a hard one where eu-west runs
# on a nearly clean grid while ap-south burns coal
regimes = {"mild split (300±100)": ConstantCarbon(300.0, per_region={
               "eu-west": 200.0, "ap-south": 400.0}),
           "hard split (50 vs 700)": ConstantCarbon(400.0, per_region={
               "eu-west": 50.0, "ap-south": 700.0})}
atlas = pareto.FrontierAtlas()
print(f"\n--- Pareto frontier: {len(ws)} weighting schemes x "
      f"{len(frontier_pods)} pods x {len(frontier_nodes)} nodes per regime")
for regime, signal in regimes.items():
    points = pareto.placement_metrics(frontier_pods, frontier_nodes, ws,
                                      backend="jax", carbon_signal=signal)
    front = pareto.frontier_for(points)
    atlas.add(regime, front)
    dom = atlas.dominant_scheme(regime)
    w = ", ".join(f"{v:.2f}" for v in dom.weights)
    print(f"  {regime:24s}: {len(front.front):3d}/{len(points)} "
          f"Pareto-optimal; dominant scheme #{dom.index} w=[{w}]")
    print(f"    {'  '.join(f'{k}={v:.4g}' for k, v in dom.metrics.items())}")

# the same atlas feeds the HTML report's frontier section: one scatter +
# table per regime, dominant pick starred
report_path = write_html_report("fleet_frontier_report.html",
                                frontier=atlas.to_report(),
                                title="weighting-scheme frontier")
print(f"  wrote {report_path} — frontier scatter + table per regime")

"""GreenPod quickstart: schedule the paper's AIoT workload with both
schedulers and print the energy outcome, then make a single placement
decision by hand to see the TOPSIS pipeline.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.cluster.node import make_paper_cluster
from repro.cluster.simulator import run_experiment
from repro.cluster.workload import WORKLOADS, Pod
from repro.core.scheduler import GreenPodScheduler, decision_matrix

# --- 1. one placement decision, step by step -----------------------------------
nodes = make_paper_cluster()
pod = Pod(uid=0, workload=WORKLOADS["medium"], scheduler="topsis")
matrix = decision_matrix(pod, nodes)
print("decision matrix (exec_s, energy_J, cores, memory, balance):")
for n, row in zip(nodes, matrix):
    print(f"  {n.name:13s} {np.round(row, 3)}")

sched = GreenPodScheduler("energy_centric")
idx, diag = sched.select(pod, nodes)
print(f"\nGreenPod (energy-centric) binds the pod to: {nodes[idx].name} "
      f"(closeness {diag['closeness'][idx]:.3f})")

# --- 2. the paper's experiment: medium competition, energy-centric -------------
res = run_experiment("medium", "energy_centric")
dk = res.mean_energy_kj("default")
tk = res.mean_energy_kj("topsis")
print("\nmedium competition, energy-centric profile:")
print(f"  default K8s : {dk:.4f} kJ/pod")
print(f"  GreenPod    : {tk:.4f} kJ/pod")
print(f"  energy optimization: {100 * (dk - tk) / dk:.2f}% "
      f"(paper Table VI: 39.13%)")
print(f"  TOPSIS scheduling overhead: "
      f"{res.mean_sched_time_ms('topsis'):.3f} ms/pod")

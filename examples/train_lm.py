"""End-to-end driver: train a reduced llama3-family model for a few hundred
steps on CPU with the full production substrate — data pipeline, AdamW,
checkpointing, fault supervisor (a simulated node failure at step 120), and
loss curve report.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch llama3-8b]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train import fault
from repro.train import loop as tl

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3-8b")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

cfg = registry.smoke_config(args.arch)
# widen the smoke config a bit (~few M params) so the loss curve is
# interesting while staying CPU-friendly
model = lm.build(cfg)
mesh = make_host_mesh()
ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
step, _ = tl.make_train_step(model, ocfg, mesh, n_micro=2, donate=False)
params = model.init(jax.random.PRNGKey(0))
ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)


def data_fn(s):
    return {"tokens": jnp.asarray(ds.batch_at(s))}


def fault_hook(s):
    if s == min(120, args.steps // 2) and not getattr(fault_hook, "fired", 0):
        fault_hook.fired = 1
        raise RuntimeError("simulated node failure")


with tempfile.TemporaryDirectory() as ckpt_dir:
    sup = fault.Supervisor(ckpt_dir=ckpt_dir, ckpt_every=50, max_restarts=3)
    state = {"params": params, "opt_state": adamw.init(ocfg, params)}
    final, hist = sup.run(state=state, step_fn=step, data_fn=data_fn,
                          n_steps=args.steps, fault_hook=fault_hook)

losses = [h["loss"] for h in hist]
print(f"\ntrained {args.steps} steps ({len(hist)} executed incl. replays; "
      f"1 simulated failure, restarted from checkpoint)")
for i in range(0, len(losses), max(1, len(losses) // 12)):
    print(f"  step {hist[i]['step']:4d}  loss {losses[i]:.4f}")
print(f"  final loss {losses[-1]:.4f}  (start {losses[0]:.4f})")
assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not improve"
print("loss improved ✓")

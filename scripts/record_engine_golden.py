"""Record golden event-engine outputs for the kernel-refactor pin.

Runs the four policy combinations (policy-free, carbon-only,
autoscale-only, carbon+autoscale) over the shared recorded scenario
(tests/engine_golden_spec.py — one source for both this recorder and the
pinning tests) on every backend, and writes placements, start/runtimes,
energy/carbon totals, and event counters to
tests/golden_engine_scenarios.json. tests/test_engine.py asserts the
engine reproduces the file bitwise; re-record only on an *intentional*
behaviour change, and say so in the PR.

Run: PYTHONPATH=src python scripts/record_engine_golden.py
"""
from __future__ import annotations

import json
import os
import sys

_TESTS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "tests")
sys.path.insert(0, _TESTS_DIR)

from engine_golden_spec import SCENARIOS, run_cell   # noqa: E402

BACKENDS = ("numpy", "jax", "pallas")


def run_one(name: str, backend: str) -> dict:
    res = run_cell(name, backend)
    out = {
        "nodes": [r.node for r in res.records],
        "uids": [r.pod.uid for r in res.records],
        "start_s": [r.start_s for r in res.records],
        "runtime_s": [r.runtime_s for r in res.records],
        "energy_topsis_kj": res.energy_kj("topsis"),
        "energy_default_kj": res.energy_kj("default"),
        "unschedulable": res.unschedulable,
        "preemptions": res.preemptions,
        "migrations": res.migrations,
        "wakes": res.wakes,
        "sleeps": res.sleeps,
    }
    if SCENARIOS[name]["carbon"]:
        out["carbon_topsis_g"] = res.total_carbon_g("topsis")
        out["mean_deferral_latency_s"] = res.mean_deferral_latency_s("topsis")
    if SCENARIOS[name]["autoscale"]:
        out["fleet_idle_energy_kj"] = res.fleet_idle_energy_kj()
        out["state_energy_kj"] = res.state_energy_kj()
    return out


def main() -> None:
    golden: dict = {"config": {"profile": "mixed", "n_nodes": 8,
                               "fleet_seed": 3, "arrival_seed": 7,
                               "n_bursts": 3, "burst_size": 4,
                               "scheme": "energy_centric"},
                    "runs": {}}
    for name in SCENARIOS:
        for backend in BACKENDS:
            print(f"recording {name} / {backend} ...")
            golden["runs"][f"{name}/{backend}"] = run_one(name, backend)
    path = os.path.join(_TESTS_DIR, "golden_engine_scenarios.json")
    with open(path, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {os.path.normpath(path)}")


if __name__ == "__main__":
    main()

"""CI smoke lane for the flight recorder.

Runs one small carbon+autoscale scenario with telemetry enabled, checks
the pure-observer invariant against a recording-free run of the same
scenario, asserts the sim-time metric timelines were captured, and writes
the exporter outputs — a Prometheus text snapshot, a Perfetto trace with
counter tracks (validated against the trace-event schema), and the
self-contained HTML run report — that CI uploads as artifacts, so every
PR leaves an openable ui.perfetto.dev trace and an operator report of
the scheduling engine behind.

Run: PYTHONPATH=src python scripts/telemetry_smoke.py [out_dir]
"""
from __future__ import annotations

import os
import sys

_TESTS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "tests")
sys.path.insert(0, _TESTS_DIR)

from engine_golden_spec import run_cell              # noqa: E402
from repro.core import telemetry                     # noqa: E402
from repro.telemetry.export import (perfetto_trace,  # noqa: E402
                                    prometheus_text, validate_trace,
                                    write_perfetto)
from repro.telemetry.report import write_html_report  # noqa: E402


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "."
    os.makedirs(out_dir, exist_ok=True)

    baseline = run_cell("carbon_autoscale", "numpy")
    with telemetry.enabled() as tel:
        res = run_cell("carbon_autoscale", "numpy")

    # pure-observer invariant: recording changed nothing
    assert [r.node for r in res.records] == [r.node
                                             for r in baseline.records]
    assert res.energy_kj("topsis") == baseline.energy_kj("topsis")
    assert res.fleet_idle_energy_kj() == baseline.fleet_idle_energy_kj()
    # ...and the recorder demonstrably recorded
    assert tel.counter_value("engine_events", kind="arrival") > 0
    assert any(s["name"] == "engine_round" for s in tel.spans)
    # ...including the sim-time timelines
    names = tel.series_names()
    for want in ("engine_pending_depth", "fleet_power_w",
                 "fleet_energy_cum_kj", "scheduler_energy_cum_kj"):
        assert want in names, f"timeline {want} missing"
    assert all(len(s) > 0 for s in tel.timeseries.values())

    prom_path = os.path.join(out_dir, "telemetry_smoke.prom")
    with open(prom_path, "w") as f:
        f.write(prometheus_text(tel))
    print(f"wrote {prom_path} "
          f"({len(tel.counters)} counters, {len(tel.gauges)} gauges, "
          f"{len(tel.histograms)} histograms, {len(tel.spans)} spans, "
          f"{len(tel.timeseries)} series)")

    trace = perfetto_trace(res, trace_name="telemetry smoke", tel=tel)
    stats = validate_trace(trace)
    assert stats["counters"] > 0, "no counter tracks in the trace"
    trace_path = write_perfetto(
        res, os.path.join(out_dir, "telemetry_smoke.trace.json"),
        trace_name="telemetry smoke", tel=tel)
    print(f"wrote {trace_path} ({stats['spans']} spans, "
          f"{stats['instants']} instants, {stats['counters']} counter "
          f"samples, {stats['tracks']} tracks) — "
          f"open at https://ui.perfetto.dev")

    report_path = write_html_report(
        os.path.join(out_dir, "telemetry_smoke.html"), tel=tel,
        result=res, title="telemetry smoke run")
    print(f"wrote {report_path} ({len(tel.timeseries)} charted series)")


if __name__ == "__main__":
    main()

"""CI smoke lane for the flight recorder.

Runs one small carbon+autoscale scenario with telemetry enabled, checks
the pure-observer invariant against a recording-free run of the same
scenario, and writes both exporter outputs — a Prometheus text snapshot
and a Perfetto trace (validated against the trace-event schema) that CI
uploads as an artifact, so every PR leaves an openable
ui.perfetto.dev trace of the scheduling engine behind.

Run: PYTHONPATH=src python scripts/telemetry_smoke.py [out_dir]
"""
from __future__ import annotations

import os
import sys

_TESTS_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "tests")
sys.path.insert(0, _TESTS_DIR)

from engine_golden_spec import run_cell              # noqa: E402
from repro.core import telemetry                     # noqa: E402
from repro.telemetry.export import (perfetto_trace,  # noqa: E402
                                    prometheus_text, validate_trace,
                                    write_perfetto)


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "."
    os.makedirs(out_dir, exist_ok=True)

    baseline = run_cell("carbon_autoscale", "numpy")
    with telemetry.enabled() as tel:
        res = run_cell("carbon_autoscale", "numpy")

    # pure-observer invariant: recording changed nothing
    assert [r.node for r in res.records] == [r.node
                                             for r in baseline.records]
    assert res.energy_kj("topsis") == baseline.energy_kj("topsis")
    assert res.fleet_idle_energy_kj() == baseline.fleet_idle_energy_kj()
    # ...and the recorder demonstrably recorded
    assert tel.counter_value("engine_events", kind="arrival") > 0
    assert any(s["name"] == "engine_round" for s in tel.spans)

    prom_path = os.path.join(out_dir, "telemetry_smoke.prom")
    with open(prom_path, "w") as f:
        f.write(prometheus_text(tel))
    print(f"wrote {prom_path} "
          f"({len(tel.counters)} counters, {len(tel.gauges)} gauges, "
          f"{len(tel.histograms)} histograms, {len(tel.spans)} spans)")

    trace = perfetto_trace(res, trace_name="telemetry smoke")
    stats = validate_trace(trace)
    trace_path = write_perfetto(
        res, os.path.join(out_dir, "telemetry_smoke.trace.json"),
        trace_name="telemetry smoke")
    print(f"wrote {trace_path} ({stats['spans']} spans, "
          f"{stats['instants']} instants, {stats['tracks']} tracks) — "
          f"open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()

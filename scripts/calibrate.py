"""Calibration search for the cluster-simulator free constants (DESIGN.md §7).

The paper publishes Table VI (energy kJ + optimization %) but not the node
wattages, task durations, or scheme weight vectors. This script fits those
free constants by randomized hill-climbing so the simulator's default-K8s
column and optimization percentages match Table VI. Run once; the winning
constants are frozen into repro.core.energy / repro.cluster.workload /
repro.core.weighting.

Usage: PYTHONPATH=src python scripts/calibrate.py [n_iters]
"""
from __future__ import annotations

import json
import sys

import numpy as np

from repro.core import energy, weighting
from repro.cluster import workload
from repro.cluster.workload import WorkloadSpec

PAPER = {  # (level, scheme) -> (default_kj, topsis_kj, optimization_pct)
    ("low", "general"): (0.5036, 0.4586, 8.93),
    ("low", "energy_centric"): (0.5036, 0.3124, 37.96),
    ("low", "performance_centric"): (0.5036, 0.4924, 2.22),
    ("low", "resource_efficient"): (0.5036, 0.3686, 26.80),
    ("medium", "general"): (0.4375, 0.3650, 16.57),
    ("medium", "energy_centric"): (0.4375, 0.2663, 39.13),
    ("medium", "performance_centric"): (0.4375, 0.4037, 7.72),
    ("medium", "resource_efficient"): (0.4375, 0.2944, 32.70),
    ("high", "general"): (0.4471, 0.3867, 13.50),
    ("high", "energy_centric"): (0.4257, 0.2817, 33.82),
    ("high", "performance_centric"): (0.4257, 0.3904, 8.29),
    ("high", "resource_efficient"): (0.4257, 0.4050, 4.86),
}


def set_params(p: dict) -> None:
    for cls in ("A", "B", "C", "default"):
        energy.NODE_ENERGY_PROFILES[cls]["speed"] = p[f"speed_{cls}"]
        energy.NODE_ENERGY_PROFILES[cls]["dyn_power_per_vcpu"] = p[f"dyn_{cls}"]
        energy.NODE_ENERGY_PROFILES[cls]["idle_power"] = p[f"idle_{cls}"]
    for kind in ("light", "medium", "complex"):
        old = workload.WORKLOADS[kind]
        workload.WORKLOADS[kind] = WorkloadSpec(
            old.kind, old.cpu_request, old.mem_request, p[f"t_{kind}"],
            old.description)
    weighting.SCHEMES["energy_centric"] = np.array(
        [p["ec_exec"], p["ec_energy"], p["ec_res"], p["ec_res"], p["ec_bal"]])
    weighting.SCHEMES["resource_efficient"] = np.array(
        [p["re_exec"], p["re_energy"], p["re_res"], p["re_res"], p["re_bal"]])
    weighting.SCHEMES["performance_centric"] = np.array(
        [p["pc_exec"], p["pc_energy"], p["pc_res"], p["pc_res"], p["pc_bal"]])


def objective(p: dict) -> float:
    set_params(p)
    from repro.cluster.simulator import table6
    t = table6()
    err = 0.0
    for (level, scheme), (dk, tk, opt) in PAPER.items():
        cell = t[level][scheme]
        err += ((cell["optimization_pct"] - opt) / 10.0) ** 2
        err += ((cell["default_kj"] - dk) / 0.05) ** 2 * 0.25
        err += ((cell["topsis_kj"] - tk) / 0.05) ** 2 * 0.25
    return err


P0 = dict(
    speed_A=0.80, speed_B=1.00, speed_C=1.30, speed_default=0.95,
    dyn_A=4.0, dyn_B=7.0, dyn_C=11.0, dyn_default=8.0,
    idle_A=8.0, idle_B=14.0, idle_C=24.0, idle_default=13.0,
    t_light=6.0, t_medium=20.0, t_complex=45.0,
    ec_exec=0.10, ec_energy=0.55, ec_res=0.10, ec_bal=0.15,
    re_exec=0.10, re_energy=0.30, re_res=0.225, re_bal=0.15,
    pc_exec=0.45, pc_energy=0.10, pc_res=0.175, pc_bal=0.10,
)

BOUNDS = {k: (0.5 * v, 3.0 * v) for k, v in P0.items()}
BOUNDS.update({f"speed_{c}": (0.5, 2.0) for c in ("A", "B", "C", "default")})


def main(iters: int = 600, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    best = dict(P0)
    best_err = objective(best)
    print(f"start err={best_err:.3f}")
    keys = list(P0)
    for i in range(iters):
        cand = dict(best)
        # perturb a random subset of parameters
        for k in rng.choice(keys, size=rng.integers(1, 5), replace=False):
            lo, hi = BOUNDS[k]
            scale = 0.25 if i < iters // 2 else 0.10
            cand[k] = float(np.clip(
                cand[k] * np.exp(rng.normal(0, scale)), lo, hi))
        err = objective(cand)
        if err < best_err:
            best, best_err = cand, err
            print(f"iter {i}: err={err:.3f}")
    set_params(best)
    from repro.cluster.simulator import table6
    t = table6()
    print(json.dumps(best, indent=2))
    for (level, scheme), (dk, tk, opt) in PAPER.items():
        c = t[level][scheme]
        print(f'{level:7s} {scheme:22s} default={c["default_kj"]:.4f}/{dk:.4f}'
              f' topsis={c["topsis_kj"]:.4f}/{tk:.4f}'
              f' opt={c["optimization_pct"]:+6.2f}% / {opt:5.2f}%')
    with open("scripts/calibrated_params.json", "w") as f:
        json.dump({"params": best, "err": best_err}, f, indent=2)


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 600)


def refine(iters: int = 1200, seed: int = 1) -> None:
    """Second pass: seed from calibrated_params.json, add physical-ordering
    penalties (frugal A < B < C in dynamic power; C fastest)."""
    rng = np.random.default_rng(seed)
    with open("scripts/calibrated_params.json") as f:
        best = json.load(f)["params"]

    def obj(p):
        e = objective(p)
        # physical sanity: dyn power ordering A < B < C; speed A < B < C
        for a, b in (("dyn_A", "dyn_B"), ("dyn_B", "dyn_C"),
                     ("idle_A", "idle_B"), ("idle_B", "idle_C"),
                     ("speed_A", "speed_B"), ("speed_B", "speed_C"),
                     ("t_light", "t_medium"), ("t_medium", "t_complex")):
            e += 25.0 * max(0.0, (p[a] - p[b]) / max(p[b], 1e-9)) ** 2
        return e

    best_err = obj(best)
    print(f"refine start err={best_err:.3f}")
    keys = list(P0)
    for i in range(iters):
        cand = dict(best)
        for k in rng.choice(keys, size=rng.integers(1, 5), replace=False):
            lo, hi = BOUNDS[k]
            scale = 0.20 if i < iters // 2 else 0.08
            cand[k] = float(np.clip(
                cand[k] * np.exp(rng.normal(0, scale)), lo, hi))
        err = obj(cand)
        if err < best_err:
            best, best_err = cand, err
            print(f"iter {i}: err={err:.3f}")
    set_params(best)
    from repro.cluster.simulator import table6
    t = table6()
    print(json.dumps(best, indent=2))
    for (level, scheme), (dk, tk, opt) in PAPER.items():
        c = t[level][scheme]
        print(f'{level:7s} {scheme:22s} default={c["default_kj"]:.4f}/{dk:.4f}'
              f' topsis={c["topsis_kj"]:.4f}/{tk:.4f}'
              f' opt={c["optimization_pct"]:+6.2f}% / {opt:5.2f}%')
    with open("scripts/calibrated_params.json", "w") as f:
        json.dump({"params": best, "err": best_err}, f, indent=2)

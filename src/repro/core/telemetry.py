"""Flight recorder: structured telemetry for the scheduling engine.

The paper's headline claim — up to 39.1% energy savings "despite slight
scheduling latency" — is exactly the trade-off an operator must be able to
*see*: per-decision latency, why TOPSIS picked a node, where energy and
carbon went over time. This module is the substrate: a :class:`Telemetry`
registry of counters, gauges, histograms (fixed log-spaced buckets for
latencies), and nestable timed spans, consumed by the instrumented hot
layers (``cluster/engine.py``, ``core/scheduler.py``, ``core/energy.py``)
and exported by ``repro.telemetry.export`` (JSON snapshot, Prometheus text
exposition, Perfetto trace).

Design constraints (the pure-observer invariant):

* **Disabled costs ~nothing.** The module-level default is a
  :class:`NullTelemetry` whose methods are no-ops; instrumented code calls
  ``telemetry.active()`` and never branches on whether recording is on.
  Heavier rollups (per-node energy gauges) guard on ``tel.enabled``.
* **Enabled changes nothing.** Telemetry is write-only from the
  simulation's point of view: wall-clock times live only in telemetry
  output, never in sim state, so golden scenarios reproduce bitwise with
  recording on (tests/test_telemetry.py pins this across all three
  backends and the full policy matrix). The one wall-time quantity that
  predates telemetry — ``PodRecord.scheduling_time_s`` — is measured by
  the same :class:`Span` objects (a span times even when recording is
  off), so decision latency has exactly one code path.

Metric names follow Prometheus conventions (``[a-zA-Z_][a-zA-Z0-9_]*``,
labels as keyword arguments)::

    tel = telemetry.enable()
    tel.inc("engine_events", kind="arrival")
    tel.set_gauge("engine_pending_depth", 12)
    tel.record("engine_pending_depth", t_sim, 12)   # sim-time timeline
    with tel.span("scheduler_decision", backend="numpy") as sp:
        ...
    sp.duration_s            # wall seconds, also observed into the
                             # "scheduler_decision_seconds" histogram

Timelines (:class:`TimeSeries`, via :meth:`Telemetry.record`) are keyed on
the **simulation clock**, never wall time: the recorded values are sim
quantities (queue depths, fleet power, cumulative energy), so the same
scenario records bit-identical series on every backend, and recording one
can never perturb the run (tests/test_timeline.py pins both). Memory is
bounded per series by deterministic decimation (see :class:`TimeSeries`).
Because the sim clock restarts at zero each run, timelines describe **one
run**: the engine calls :meth:`Telemetry.clear_series` at run start, so a
registry shared across runs (table6's factorial) keeps the latest run's
series while counters / gauges / histograms keep aggregating.
"""
from __future__ import annotations

import math
import time
from contextlib import contextmanager

__all__ = [
    "Telemetry", "NullTelemetry", "Histogram", "Span", "TimeSeries",
    "log_buckets", "DEFAULT_LATENCY_BUCKETS", "DEFAULT_SERIES_MAX_POINTS",
    "active", "enable", "disable", "enabled", "NULL",
]


def log_buckets(lo: float, hi: float, per_decade: int = 4) -> tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds covering ``[lo, hi]`` with
    ``per_decade`` buckets per decade. The edges are exact powers
    ``10**(k / per_decade)`` so two registries configured alike always
    agree on bucket boundaries."""
    if not (0.0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1, got {per_decade}")
    k0 = round(math.log10(lo) * per_decade)
    k1 = round(math.log10(hi) * per_decade)
    return tuple(10.0 ** (k / per_decade) for k in range(k0, k1 + 1))


# Decision latencies span ~1 us (a cached numpy row view) to seconds (a
# cold pallas interpret-mode dispatch): six decades, 4 buckets per decade.
DEFAULT_LATENCY_BUCKETS = log_buckets(1e-6, 10.0, per_decade=4)


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Histogram:
    """Fixed-bucket histogram: ``edges`` are ascending upper bounds, an
    observation lands in the first bucket whose edge is >= the value
    (Prometheus ``le`` semantics); values above the last edge land in the
    overflow (+Inf) bucket. ``counts`` has ``len(edges) + 1`` entries."""

    __slots__ = ("name", "labels", "edges", "counts", "sum", "count",
                 "min", "max")

    def __init__(self, name: str, labels: dict | None = None,
                 edges: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.labels = dict(labels or {})
        self.edges = tuple(edges)
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError(f"histogram edges must be strictly ascending, "
                             f"got {edges}")
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        lo, hi = 0, len(self.edges)
        while lo < hi:                      # first edge >= value
            mid = (lo + hi) // 2
            if self.edges[mid] < value:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.sum += value
        self.count += 1
        self.min = min(self.min, value)
        self.max = max(self.max, value)

    def cumulative(self) -> list[int]:
        """Cumulative counts per ``le`` edge plus the +Inf total — the
        Prometheus exposition shape."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def snapshot(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "edges": list(self.edges), "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max}


class Gauge:
    """Last-write-wins sample with running min/max/sample-count, so a
    sampled series (pending-queue depth at each clock advance) keeps its
    envelope without storing the series."""

    __slots__ = ("name", "labels", "value", "min", "max", "samples")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.samples = 0

    def set(self, value: float) -> None:
        self.value = value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        self.samples += 1

    def snapshot(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self.value, "samples": self.samples,
                "min": None if self.samples == 0 else self.min,
                "max": None if self.samples == 0 else self.max}


# Default per-series point budget: plenty for an operator chart, small
# enough that a registry full of series stays a few hundred KB.
DEFAULT_SERIES_MAX_POINTS = 512


class TimeSeries:
    """A metric timeline keyed on the simulation clock.

    ``record(t, value)`` appends one sample; repeated samples at the same
    sim instant overwrite (rounds can repeat at one clock instant via the
    backoff step — last write wins), and time must never run backwards.

    Memory is bounded by **deterministic decimation**: whenever the stored
    points exceed ``max_points``, every other interior point is dropped
    (the first and the most recent point are always kept). The surviving
    points are a function of the append sequence alone — no randomness, no
    wall clock — so the same scenario decimates to the identical series on
    every backend, and the series endpoints are always exact."""

    __slots__ = ("name", "labels", "max_points", "samples", "_t", "_v")

    def __init__(self, name: str, labels: dict | None = None,
                 max_points: int = DEFAULT_SERIES_MAX_POINTS):
        if max_points < 4:
            raise ValueError(f"max_points must be >= 4, got {max_points}")
        self.name = name
        self.labels = dict(labels or {})
        self.max_points = max_points
        self.samples = 0            # total record() calls, pre-decimation
        self._t: list[float] = []
        self._v: list[float] = []

    def __len__(self) -> int:
        return len(self._t)

    def record(self, t: float, value: float) -> None:
        self.samples += 1
        if self._t:
            last = self._t[-1]
            if t < last:
                raise ValueError(
                    f"series {self.name!r}: sim time ran backwards "
                    f"({t} < {last})")
            if t == last:
                self._v[-1] = value
                return
        self._t.append(t)
        self._v.append(value)
        if len(self._t) > self.max_points:
            # drop every other interior point; keep index 0 and the last
            last_i = len(self._t) - 1
            keep = list(range(0, last_i, 2))
            if keep[-1] != last_i:
                keep.append(last_i)
            self._t = [self._t[i] for i in keep]
            self._v = [self._v[i] for i in keep]

    @property
    def times(self) -> tuple[float, ...]:
        return tuple(self._t)

    @property
    def values(self) -> tuple[float, ...]:
        return tuple(self._v)

    def points(self) -> list[tuple[float, float]]:
        return list(zip(self._t, self._v))

    def snapshot(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "t": list(self._t), "values": list(self._v),
                "samples": self.samples, "max_points": self.max_points}


class Span:
    """One nestable timed span. A span *always* times (``duration_s`` is
    valid after the ``with`` block even under :class:`NullTelemetry`) —
    instrumented code reads the duration from here so wall-clock
    measurement has one code path — but it is only *recorded* (span log +
    ``<name>_seconds`` histogram) by an active :class:`Telemetry`."""

    __slots__ = ("name", "labels", "t0", "duration_s", "depth", "_tel")

    def __init__(self, tel: "NullTelemetry", name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.t0 = 0.0
        self.duration_s = 0.0
        self.depth = 0
        self._tel = tel

    def __enter__(self) -> "Span":
        self._tel._start_span(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.duration_s = time.perf_counter() - self.t0
        self._tel._finish_span(self)


class NullTelemetry:
    """The disabled default: every recording method is a no-op, ``span``
    still hands back a timing :class:`Span` (see there). ``enabled`` lets
    call sites skip building expensive rollups entirely."""

    enabled = False

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        pass

    def set_gauge(self, name: str, value: float, **labels) -> None:
        pass

    def observe(self, name: str, value: float, **labels) -> None:
        pass

    def record(self, name: str, t: float, value: float, **labels) -> None:
        pass

    def clear_series(self) -> None:
        pass

    def span(self, name: str, **labels) -> Span:
        return Span(self, name, labels)

    def _start_span(self, span: Span) -> None:
        pass

    def _finish_span(self, span: Span) -> None:
        pass


class Telemetry(NullTelemetry):
    """The live registry. One instance records one run (or any scope the
    caller wants); ``snapshot()`` is the JSON-ready view the exporters
    consume."""

    enabled = True

    def __init__(self,
                 latency_buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS,
                 series_max_points: int = DEFAULT_SERIES_MAX_POINTS):
        self.latency_buckets = tuple(latency_buckets)
        self.series_max_points = series_max_points
        self.counters: dict[tuple, list] = {}     # key -> [name, labels, val]
        self.gauges: dict[tuple, Gauge] = {}
        self.histograms: dict[tuple, Histogram] = {}
        self.timeseries: dict[tuple, TimeSeries] = {}
        self.spans: list[dict] = []               # completed spans, log order
        self._span_stack: list[Span] = []
        self._epoch = time.perf_counter()

    # --- counters / gauges / histograms --------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        key = (name, _labels_key(labels))
        cell = self.counters.get(key)
        if cell is None:
            self.counters[key] = [name, labels, value]
        else:
            cell[2] += value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        key = (name, _labels_key(labels))
        g = self.gauges.get(key)
        if g is None:
            g = self.gauges[key] = Gauge(name, labels)
        g.set(value)

    def observe(self, name: str, value: float, **labels) -> None:
        key = (name, _labels_key(labels))
        h = self.histograms.get(key)
        if h is None:
            h = self.histograms[key] = Histogram(name, labels,
                                                 self.latency_buckets)
        h.observe(value)

    def record(self, name: str, t: float, value: float, **labels) -> None:
        """Append one sim-time sample to the named :class:`TimeSeries`."""
        key = (name, _labels_key(labels))
        s = self.timeseries.get(key)
        if s is None:
            s = self.timeseries[key] = TimeSeries(name, labels,
                                                  self.series_max_points)
        s.record(t, value)

    def histogram(self, name: str, **labels) -> Histogram | None:
        """The named histogram cell (None if nothing observed yet)."""
        return self.histograms.get((name, _labels_key(labels)))

    def series(self, name: str, **labels) -> TimeSeries | None:
        """The named timeline cell (None if nothing recorded yet)."""
        return self.timeseries.get((name, _labels_key(labels)))

    def series_names(self) -> list[str]:
        """Sorted distinct timeline metric names."""
        return sorted({s.name for s in self.timeseries.values()})

    def clear_series(self) -> None:
        """Drop every timeline (the engine calls this at run start: the
        sim clock restarts at zero each run, so series never span runs —
        unlike counters/gauges/histograms, which keep aggregating)."""
        self.timeseries.clear()

    def counter_value(self, name: str, **labels) -> float:
        cell = self.counters.get((name, _labels_key(labels)))
        return cell[2] if cell is not None else 0.0

    # --- spans ---------------------------------------------------------------
    def span(self, name: str, **labels) -> Span:
        return Span(self, name, labels)

    def _start_span(self, span: Span) -> None:
        span.depth = len(self._span_stack)
        self._span_stack.append(span)

    def _finish_span(self, span: Span) -> None:
        if self._span_stack and self._span_stack[-1] is span:
            self._span_stack.pop()
        self.spans.append({"name": span.name, "labels": span.labels,
                           "start_s": span.t0 - self._epoch,
                           "duration_s": span.duration_s,
                           "depth": span.depth})
        self.observe(f"{span.name}_seconds", span.duration_s, **span.labels)

    # --- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready view of every metric (spans summarized by their
        histograms; the raw span log stays on ``self.spans``)."""
        return {
            "counters": [{"name": n, "labels": dict(lb), "value": v}
                         for n, lb, v in self.counters.values()],
            "gauges": [g.snapshot() for g in self.gauges.values()],
            "histograms": [h.snapshot() for h in self.histograms.values()],
            "series": [s.snapshot() for s in self.timeseries.values()],
            "spans": len(self.spans),
        }


# --- module-level active registry -------------------------------------------
NULL = NullTelemetry()
_active: NullTelemetry = NULL


def active() -> NullTelemetry:
    """The registry instrumented code records into — :data:`NULL` unless a
    caller enabled one."""
    return _active


def enable(tel: Telemetry | None = None) -> Telemetry:
    """Install ``tel`` (or a fresh :class:`Telemetry`) as the active
    registry and return it."""
    global _active
    _active = tel if tel is not None else Telemetry()
    return _active


def disable() -> NullTelemetry:
    """Back to the no-op default; returns the registry that was active."""
    global _active
    prev = _active
    _active = NULL
    return prev


@contextmanager
def enabled(tel: Telemetry | None = None):
    """``with telemetry.enabled() as tel:`` — record for one scope."""
    tel = enable(tel)
    try:
        yield tel
    finally:
        if _active is tel:
            disable()

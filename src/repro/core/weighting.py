"""Weighting schemes (paper Table III) + the adaptive weighting module (§III.A).

The paper names four schemes but does not publish the weight vectors; the
vectors below are our calibration (DESIGN.md §7), ordered as
``criteria.CRITERIA_NAMES``: (execution_time, energy, cores, memory, balance).
"""
from __future__ import annotations

import numpy as np

# Vectors calibrated against paper Table VI (scripts/calibrate.py);
# normalized at use. Order: (exec_time, energy, cores, memory, balance).
SCHEMES: dict[str, np.ndarray] = {
    # Equal importance to all five metrics ("general (balanced)").
    "general": np.array([0.20, 0.20, 0.20, 0.20, 0.20]),
    # Prioritize power consumption.
    "energy_centric": np.array([0.2016, 0.3352, 0.0505, 0.0505, 0.0869]),
    # Emphasize execution speed.
    "performance_centric": np.array([0.2250, 0.1696, 0.1732, 0.1732, 0.2158]),
    # Balance overall resource utilization and energy efficiency.
    "resource_efficient": np.array([0.1348, 0.3605, 0.1876, 0.1876, 0.2383]),
}

SCHEME_NAMES = tuple(SCHEMES)


def weights_for(scheme: str) -> np.ndarray:
    try:
        w = SCHEMES[scheme]
    except KeyError as e:
        raise ValueError(f"unknown weighting scheme {scheme!r}; "
                         f"choose from {sorted(SCHEMES)}") from e
    return w / w.sum()


def adaptive_weights(scheme: str, cluster_utilization: float) -> np.ndarray:
    """Adaptive weighting module (paper §III.A): 'dynamically adjusts criteria
    weights based on system conditions'.

    As cluster utilization rises toward saturation, placement quality is
    increasingly determined by *fit* rather than *preference*: we shift weight
    from the energy criterion toward cores/memory/balance, mirroring the
    paper's observation (§V.C) that high competition 'may require hybrid
    approaches balancing energy awareness with resource efficiency'.
    """
    w = weights_for(scheme).copy()
    u = float(np.clip(cluster_utilization, 0.0, 1.0))
    # Linear pull of up to 50% of the energy weight once utilization > 0.6.
    pull = 0.5 * max(0.0, (u - 0.6) / 0.4) * w[1]
    w[1] -= pull
    w[2:5] += pull / 3.0
    return w / w.sum()

"""Weighting schemes (paper Table III) + the adaptive weighting module (§III.A).

The paper names four schemes but does not publish the weight vectors; the
vectors below are our calibration (DESIGN.md §7), ordered as
``criteria.CRITERIA_NAMES``: (execution_time, energy, cores, memory, balance).
"""
from __future__ import annotations

import numpy as np

# Vectors calibrated against paper Table VI (scripts/calibrate.py);
# normalized at use. Order: (exec_time, energy, cores, memory, balance).
SCHEMES: dict[str, np.ndarray] = {
    # Equal importance to all five metrics ("general (balanced)").
    "general": np.array([0.20, 0.20, 0.20, 0.20, 0.20]),
    # Prioritize power consumption.
    "energy_centric": np.array([0.2016, 0.3352, 0.0505, 0.0505, 0.0869]),
    # Emphasize execution speed.
    "performance_centric": np.array([0.2250, 0.1696, 0.1732, 0.1732, 0.2158]),
    # Balance overall resource utilization and energy efficiency.
    "resource_efficient": np.array([0.1348, 0.3605, 0.1876, 0.1876, 0.2383]),
}

SCHEME_NAMES = tuple(SCHEMES)

# Carbon-aware schemes (beyond-paper, repro.core.carbon): six weights, the
# sixth on the carbon-rate criterion. Requires a carbon signal — the
# schedulers reject these schemes without one. carbon_centric chases clean
# regions first; carbon_energy_balanced splits sustainability weight between
# joules and grams.
# Order: (exec_time, energy, cores, memory, balance, carbon_rate).
CARBON_SCHEMES: dict[str, np.ndarray] = {
    "carbon_centric": np.array([0.15, 0.10, 0.04, 0.04, 0.07, 0.60]),
    "carbon_energy_balanced": np.array([0.15, 0.25, 0.05, 0.05, 0.10, 0.40]),
}

CARBON_SCHEME_NAMES = tuple(CARBON_SCHEMES)


def validate_weights(w, name: str | None = None) -> np.ndarray:
    """Validate one weight vector or an (S, C) stack of them and return the
    float64 array. A valid vector has 5 or 6 entries (the paper criteria,
    optionally extended with carbon_rate), every entry finite and
    non-negative, and sums to 1 within 1e-6 — the registry schemes are
    stored unnormalized by design but leave :func:`weights_for` already
    normalized, and the simplex-lattice grid (``repro.core.pareto``)
    normalizes at generation, so everything the schedulers consume passes.
    User-supplied grids that don't raise a ValueError naming the first
    offending row instead of silently skewing the ranking."""
    w = np.asarray(w, dtype=np.float64)
    label = name or "weights"
    if w.ndim not in (1, 2):
        raise ValueError(f"{label} must be a (C,) vector or (S, C) grid, "
                         f"got shape {w.shape}")
    rows = w[None] if w.ndim == 1 else w
    if rows.shape[-1] not in (5, 6):
        raise ValueError(
            f"{label} must have 5 weights (paper criteria) or 6 (with "
            f"carbon_rate), got {rows.shape[-1]}")
    for i, row in enumerate(rows):
        where = label if w.ndim == 1 else f"{label}[{i}]"
        if not np.isfinite(row).all():
            raise ValueError(f"{where} has non-finite entries: {row}")
        if (row < 0.0).any():
            raise ValueError(f"{where} has negative entries: {row}")
        total = float(row.sum())
        if abs(total - 1.0) > 1e-6:
            raise ValueError(
                f"{where} sums to {total:.6f}, not 1 (±1e-6) — normalize "
                f"it (w / w.sum()) before handing it to the scheduler")
    return w


def scheme_grid(schemes: "tuple[str, ...]" = SCHEME_NAMES,
                carbon: bool = False) -> np.ndarray:
    """(S, C) stack of :func:`weights_for` rows — the paper's named schemes
    expressed as a weight grid, so the fused grid scorer recovers the fixed
    per-scheme results as a special case (tests pin this bitwise)."""
    return np.stack([weights_for(s, carbon=carbon) for s in schemes])


def weights_for(scheme: str, carbon: bool = False) -> np.ndarray:
    """Normalized weight vector for a scheme.

    With ``carbon=True`` (a carbon signal is attached) the paper's 5-weight
    schemes are padded with a zero carbon weight — the 6-criteria ranking is
    then bitwise identical to the 5-criteria one. Carbon schemes are always
    6 weights (``carbon`` is implied).
    """
    if scheme in CARBON_SCHEMES:
        w = CARBON_SCHEMES[scheme]
        return w / w.sum()
    try:
        w = SCHEMES[scheme]
    except KeyError as e:
        raise ValueError(
            f"unknown weighting scheme {scheme!r}; choose from "
            f"{sorted(SCHEMES) + sorted(CARBON_SCHEMES)}") from e
    if carbon:
        w = np.concatenate([w, [0.0]])
    return w / w.sum()


def adaptive_weights(scheme: str, cluster_utilization: float,
                     carbon: bool = False) -> np.ndarray:
    """Adaptive weighting module (paper §III.A): 'dynamically adjusts criteria
    weights based on system conditions'.

    As cluster utilization rises toward saturation, placement quality is
    increasingly determined by *fit* rather than *preference*: we shift weight
    from the energy criterion toward cores/memory/balance, mirroring the
    paper's observation (§V.C) that high competition 'may require hybrid
    approaches balancing energy awareness with resource efficiency'. The
    carbon weight (6-criteria schemes) is left untouched — grid intensity
    does not depend on cluster load.
    """
    w = weights_for(scheme, carbon=carbon).copy()
    u = float(np.clip(cluster_utilization, 0.0, 1.0))
    # Linear pull of up to 50% of the energy weight once utilization > 0.6.
    pull = 0.5 * max(0.0, (u - 0.6) / 0.4) * w[1]
    w[1] -= pull
    w[2:5] += pull / 3.0
    return w / w.sum()

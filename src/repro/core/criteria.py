"""Criterion definitions for GreenPod scheduling (paper §I, §III).

The five GreenPod criteria, in canonical column order:

  0 execution_time  (cost)    — predicted task runtime on the candidate node
  1 energy          (cost)    — predicted task energy on the candidate node
  2 cores           (benefit) — available processing cores after placement
  3 memory          (benefit) — available memory after placement
  4 balance         (benefit) — resource balance (1 - |cpu_util - mem_util|)

The carbon-aware stack (beyond-paper; repro.core.carbon) appends a sixth:

  5 carbon_rate     (cost)    — node power draw x grid carbon intensity of
                                the node's region at decision time

``greenpod_criteria(carbon=...)`` selects the 5- or 6-criteria tuple; with
the carbon weight at zero the 6-criteria TOPSIS ranking is bitwise identical
to the legacy 5-criteria one (a zero-weight column contributes exactly 0 to
every distance), which is what keeps paper-mode reproduction intact.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Criterion:
    name: str
    benefit: bool  # True: higher is better; False: cost criterion
    description: str = ""


GREENPOD_CRITERIA: tuple[Criterion, ...] = (
    Criterion("execution_time", False, "predicted runtime (s)"),
    Criterion("energy", False, "predicted energy (J)"),
    Criterion("cores", True, "free vCPU after placement"),
    Criterion("memory", True, "free memory (GB) after placement"),
    Criterion("balance", True, "1 - |cpu_util - mem_util| after placement"),
)

CRITERIA_NAMES: tuple[str, ...] = tuple(c.name for c in GREENPOD_CRITERIA)
N_CRITERIA = len(GREENPOD_CRITERIA)

# Sixth criterion (carbon-aware stack): instantaneous emission rate of the
# placement — the task's power draw on the candidate node (dynamic power for
# its vCPUs, plus the idle power a placement on a sleeping node newly wakes)
# times the node region's grid intensity at decision time. A cost criterion:
# the scheduler steers work toward currently-clean regions.
CARBON_CRITERION = Criterion(
    "carbon_rate", False,
    "node power draw x regional grid intensity (W * gCO2/kWh) at decision "
    "time")

GREENPOD_CRITERIA_CARBON: tuple[Criterion, ...] = (
    GREENPOD_CRITERIA + (CARBON_CRITERION,))
N_CRITERIA_CARBON = len(GREENPOD_CRITERIA_CARBON)


def greenpod_criteria(carbon: bool = False) -> tuple[Criterion, ...]:
    """The decision-matrix column tuple: 5 paper criteria, or 6 with the
    carbon-rate criterion appended (when a carbon signal is attached)."""
    return GREENPOD_CRITERIA_CARBON if carbon else GREENPOD_CRITERIA


def benefit_mask(criteria=GREENPOD_CRITERIA) -> np.ndarray:
    return np.array([c.benefit for c in criteria], dtype=bool)


# Fleet-level criteria (beyond-paper: TOPSIS over TPU slices; values derived
# from compiled roofline terms — see repro.launch.fleet).
FLEET_CRITERIA: tuple[Criterion, ...] = (
    Criterion("step_time", False, "roofline-estimated step time (s)"),
    Criterion("energy", False, "step_time x slice TDP (J)"),
    Criterion("chips", True, "free chips on slice"),
    Criterion("hbm_headroom", True, "free HBM after placement (GB)"),
    Criterion("balance", True, "1 - |compute_util - hbm_util|"),
)

"""Criterion definitions for GreenPod scheduling (paper §I, §III).

The five GreenPod criteria, in canonical column order:

  0 execution_time  (cost)    — predicted task runtime on the candidate node
  1 energy          (cost)    — predicted task energy on the candidate node
  2 cores           (benefit) — available processing cores after placement
  3 memory          (benefit) — available memory after placement
  4 balance         (benefit) — resource balance (1 - |cpu_util - mem_util|)
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Criterion:
    name: str
    benefit: bool  # True: higher is better; False: cost criterion
    description: str = ""


GREENPOD_CRITERIA: tuple[Criterion, ...] = (
    Criterion("execution_time", False, "predicted runtime (s)"),
    Criterion("energy", False, "predicted energy (J)"),
    Criterion("cores", True, "free vCPU after placement"),
    Criterion("memory", True, "free memory (GB) after placement"),
    Criterion("balance", True, "1 - |cpu_util - mem_util| after placement"),
)

CRITERIA_NAMES: tuple[str, ...] = tuple(c.name for c in GREENPOD_CRITERIA)
N_CRITERIA = len(GREENPOD_CRITERIA)


def benefit_mask(criteria=GREENPOD_CRITERIA) -> np.ndarray:
    return np.array([c.benefit for c in criteria], dtype=bool)


# Fleet-level criteria (beyond-paper: TOPSIS over TPU slices; values derived
# from compiled roofline terms — see repro.launch.fleet).
FLEET_CRITERIA: tuple[Criterion, ...] = (
    Criterion("step_time", False, "roofline-estimated step time (s)"),
    Criterion("energy", False, "step_time x slice TDP (J)"),
    Criterion("chips", True, "free chips on slice"),
    Criterion("hbm_headroom", True, "free HBM after placement (GB)"),
    Criterion("balance", True, "1 - |compute_util - hbm_util|"),
)

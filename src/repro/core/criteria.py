"""Criterion definitions for GreenPod scheduling (paper §I, §III).

The five GreenPod criteria, in canonical column order:

  0 execution_time  (cost)    — predicted task runtime on the candidate node
  1 energy          (cost)    — predicted task energy on the candidate node
  2 cores           (benefit) — available processing cores after placement
  3 memory          (benefit) — available memory after placement
  4 balance         (benefit) — resource balance (1 - |cpu_util - mem_util|)

The carbon-aware stack (beyond-paper; repro.core.carbon) appends a sixth:

  5 carbon_rate     (cost)    — node power draw x grid carbon intensity of
                                the node's region at decision time

``greenpod_criteria(carbon=...)`` selects the 5- or 6-criteria tuple; with
the carbon weight at zero the 6-criteria TOPSIS ranking is bitwise identical
to the legacy 5-criteria one (a zero-weight column contributes exactly 0 to
every distance), which is what keeps paper-mode reproduction intact.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Criterion:
    name: str
    benefit: bool  # True: higher is better; False: cost criterion
    description: str = ""


GREENPOD_CRITERIA: tuple[Criterion, ...] = (
    Criterion("execution_time", False, "predicted runtime (s)"),
    Criterion("energy", False, "predicted energy (J)"),
    Criterion("cores", True, "free vCPU after placement"),
    Criterion("memory", True, "free memory (GB) after placement"),
    Criterion("balance", True, "1 - |cpu_util - mem_util| after placement"),
)

CRITERIA_NAMES: tuple[str, ...] = tuple(c.name for c in GREENPOD_CRITERIA)
N_CRITERIA = len(GREENPOD_CRITERIA)

# Sixth criterion (carbon-aware stack): instantaneous emission rate of the
# placement — the task's power draw on the candidate node (dynamic power for
# its vCPUs, plus the idle power a placement on a sleeping node newly wakes)
# times the node region's grid intensity at decision time. A cost criterion:
# the scheduler steers work toward currently-clean regions.
CARBON_CRITERION = Criterion(
    "carbon_rate", False,
    "node power draw x regional grid intensity (W * gCO2/kWh) at decision "
    "time")

GREENPOD_CRITERIA_CARBON: tuple[Criterion, ...] = (
    GREENPOD_CRITERIA + (CARBON_CRITERION,))
N_CRITERIA_CARBON = len(GREENPOD_CRITERIA_CARBON)


def greenpod_criteria(carbon: bool = False) -> tuple[Criterion, ...]:
    """The decision-matrix column tuple: 5 paper criteria, or 6 with the
    carbon-rate criterion appended (when a carbon signal is attached)."""
    return GREENPOD_CRITERIA_CARBON if carbon else GREENPOD_CRITERIA


def benefit_mask(criteria=GREENPOD_CRITERIA) -> np.ndarray:
    return np.array([c.benefit for c in criteria], dtype=bool)


# --- decision-matrix column computation --------------------------------------
# Single source of the criteria arithmetic for both the full-rebuild path
# (repro.core.scheduler.decision_matrix_table) and the dirty-column refresh
# of the incremental FleetState caches. Every operation is elementwise per
# node — no cross-node reduction happens before TOPSIS scoring — which is
# the property that makes subset recomputation bitwise-identical to slicing
# a full rebuild: computing columns for the dirty node indices alone yields
# exactly the floats a fresh ``NodeTable.from_nodes`` rebuild would.
def criteria_matrix(cpu, mem, base_time_s, table,
                    carbon_intensity=None, cols=None) -> np.ndarray:
    """(..., N', C) GreenPod criteria block over ``table``'s column arrays
    (``CRITERIA_NAMES`` order; C = 5, or 6 with ``carbon_intensity``).

    ``cpu`` / ``mem`` / ``base_time_s`` are scalars for one pod or ``(P, 1)``
    request columns for a queue. ``cols`` optionally restricts the block to
    a node-index subset (the dirty-column recompute path): N' is then
    ``len(cols)``, and — because the arithmetic is elementwise per node —
    the block equals the corresponding columns of the full matrix bitwise.
    ``carbon_intensity`` must already be sliced to ``cols`` by the caller
    (it is a per-node column too)."""
    from repro.core.energy import predicted_task_energy_joules_np

    sl = slice(None) if cols is None else cols
    speed = table.speed[sl]
    awake = table.awake[sl]
    exec_t = base_time_s / speed
    energy = predicted_task_energy_joules_np(
        table.dyn_power_per_vcpu[sl], table.idle_power[sl], exec_t, cpu,
        awake)
    cpu_after = (table.reserved_cpu[sl] + table.used_cpu[sl]
                 + cpu) / table.vcpus[sl]
    mem_after = (table.reserved_mem[sl] + table.used_mem[sl]
                 + mem) / table.mem_gb[sl]
    rows = [
        np.broadcast_to(exec_t, cpu_after.shape),
        energy,
        np.maximum(1.0 - cpu_after, 0.0),    # core availability
        np.maximum(1.0 - mem_after, 0.0),    # memory availability
        1.0 - np.abs(cpu_after - mem_after),
    ]
    if carbon_intensity is not None:
        rows.append(placement_power(cpu, table, cols=cols)
                    * np.asarray(carbon_intensity, dtype=np.float64))
    return np.stack(rows, axis=-1).astype(np.float64, copy=False)


def placement_power(cpu, table, cols=None) -> np.ndarray:
    """(..., N') marginal power draw (W) of placing ``cpu`` vCPUs on each
    node of ``table`` (optionally restricted to the ``cols`` subset):
    the carbon_rate criterion is this times grid intensity. Split out of
    :func:`criteria_matrix` so the incremental caches can refresh the
    carbon column alone when only decision time ``now`` moved (the power
    factor is time-invariant; the intensity column is not)."""
    from repro.core.energy import predicted_power_w_np

    sl = slice(None) if cols is None else cols
    return predicted_power_w_np(table.dyn_power_per_vcpu[sl],
                                table.idle_power[sl], cpu, table.awake[sl])


# Fleet-level criteria (beyond-paper: TOPSIS over TPU slices; values derived
# from compiled roofline terms — see repro.launch.fleet).
FLEET_CRITERIA: tuple[Criterion, ...] = (
    Criterion("step_time", False, "roofline-estimated step time (s)"),
    Criterion("energy", False, "step_time x slice TDP (J)"),
    Criterion("chips", True, "free chips on slice"),
    Criterion("hbm_headroom", True, "free HBM after placement (GB)"),
    Criterion("balance", True, "1 - |compute_util - hbm_util|"),
)

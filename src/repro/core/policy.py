"""Scheduling-policy protocol and typed events for the simulation kernel.

The discrete-event kernel (``repro.cluster.engine``) owns the clock, the
pending/running queues, and the scheduling round; everything else — carbon
temporal shifting, the elastic power-state lifecycle, and any future policy
(cost-benefit drain, predictive wake) — plugs in through the
:class:`SchedulingPolicy` hook protocol defined here. The protocol lives in
this leaf module (stdlib + numpy only) so policy implementations in
``repro.core.carbon`` / ``repro.core.elastic`` can subclass it without
importing the kernel, and the kernel can import the policies' dependencies
freely.

Event kinds
-----------

Every clock advance in the kernel is one of five typed events:

* ``ARRIVAL``          — a burst of pods lands (from the arrival process).
* ``COMPLETION``       — the earliest running task ends (backoff/retry step).
* ``CARBON_CHECK``     — a carbon-policy wake: re-test the deferral dip /
                         preemption spike (cadence wakes and exact deadlines).
* ``WAKE_DONE``        — an in-flight node wake completes (pods committed to
                         the WAKING node start now; the round re-runs).
* ``CONSOLIDATE_TICK`` — the periodic consolidation drain pass fires.

``ARRIVAL`` and ``COMPLETION`` are produced by the kernel itself;
wake-like events come from each policy's :meth:`~SchedulingPolicy.
next_wake_time`. Ties are broken COMPLETION < ARRIVAL < wake-like, then by
policy order — exactly the pre-kernel engine's hand-merged clock advance.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

import numpy as np

if TYPE_CHECKING:   # kernel types, import-free at runtime (no cycle)
    from repro.cluster.engine import EventEngine
    from repro.cluster.workload import Pod

# Event kinds (Event.kind values; also the kernel's event-log tags).
ARRIVAL = "arrival"
COMPLETION = "completion"
CARBON_CHECK = "carbon_check"
WAKE_DONE = "wake_done"
CONSOLIDATE_TICK = "consolidate_tick"
EVENT_KINDS = (ARRIVAL, COMPLETION, CARBON_CHECK, WAKE_DONE,
               CONSOLIDATE_TICK)

# Tie-break priority when several events land on one instant: release a
# completion first (freed capacity is visible to the round), ingest the
# arrival burst second, fire policy wakes last.
_PRIORITY = {COMPLETION: 0, ARRIVAL: 1, CARBON_CHECK: 2, WAKE_DONE: 2,
             CONSOLIDATE_TICK: 2}


@dataclasses.dataclass(order=True, frozen=True)
class Event:
    """One typed point on the simulation clock. Ordered by ``(t, priority)``
    so ``min()`` over candidate events reproduces the engine's tie rules;
    ``payload`` (a uid, a burst size, a node index — kind-dependent) never
    participates in ordering."""

    t: float
    priority: int
    kind: str = dataclasses.field(compare=False)
    payload: object = dataclasses.field(compare=False, default=None)

    @classmethod
    def make(cls, t: float, kind: str, payload: object = None) -> "Event":
        return cls(t, _PRIORITY[kind], kind, payload)


class SchedulingPolicy:
    """Hook protocol a scheduling policy implements against the kernel.

    The kernel calls the hooks in a fixed per-round order, for every policy
    in the engine's (ordered) policy list; every hook receives the engine
    (``sim``) whose ``state`` holds the queues, records, timeline, and
    counters, and whose services (``sim.evict``, ``sim.block_restart``,
    ``sim.deadline``) expose the preemption/requeue machinery. All hooks
    are no-ops by default — a policy overrides only what it needs.

    Round lifecycle (``t`` is the kernel clock):

    1.  ``bind(sim)``          — once, at run start (capture fleet state).
    2.  ``on_arrival``         — per pod, as its burst is ingested
                                 (validate, bookkeep).
    3.  ``on_clock``           — the clock landed on ``t``; finalize any
                                 lazily-derived state before the round.
    4.  ``on_round_start``     — mutate the queues before scheduling
                                 (preempt/evict, consolidation drains).
    5.  ``exclude_mask`` /
        ``exclude_for``        — (N,) fleet-wide and per-pod scoring masks.
    6.  ``filter_pending``     — pods to hold out of this round (deferral).
    7.  ``on_commit``          — a pod bound to a node; may move its
                                 effective start (WAKING nodes).
    8.  ``on_completion`` /
        ``on_evict``           — a task left its node (ran out / evicted).
    9.  ``on_round_end``       — the round placed what it could; react to
                                 still-unplaced pods (pressure wakes).
    10. ``next_wake_time``     — the policy's earliest future event, as a
                                 typed :class:`Event` (or None).
    11. ``on_tick``            — a wake-like event this policy scheduled
                                 just fired (observation hook).
    12. ``finalize``           — end of run (close ledgers, flush counters).
    """

    @property
    def carbon_signal(self):
        """Grid-intensity signal this policy wants attached to the TOPSIS
        schedulers (sixth criterion) and the run's power timeline (carbon
        accounting); None for signal-free policies."""
        return None

    def bind(self, sim: "EventEngine") -> None:
        """Run start: the engine's fleet/queues/timeline exist."""

    def on_arrival(self, sim: "EventEngine", pod: "Pod", t: float) -> None:
        """``pod`` ingested from a burst at clock ``t`` (validate here)."""

    def on_clock(self, sim: "EventEngine", t: float) -> None:
        """Clock advanced to ``t``; runs before any round-start mutation."""

    def on_round_start(self, sim: "EventEngine", t: float) -> None:
        """Mutate queues before the scheduling round (evictions, drains)."""

    def exclude_mask(self, sim: "EventEngine", t: float) -> np.ndarray | None:
        """(N,) bool of nodes no pod may be placed on this round."""
        return None

    def exclude_for(self, sim: "EventEngine", pod: "Pod",
                    base: np.ndarray | None,
                    t: float) -> np.ndarray | None:
        """Per-pod extra exclusions on top of the round's combined ``base``
        mask (None when no policy set a fleet-wide mask); return None to
        keep ``base`` as-is."""
        return None

    def filter_pending(self, sim: "EventEngine", pods: Sequence["Pod"],
                       t: float) -> "list[Pod]":
        """Subset of ``pods`` to hold out of this round (deferral). Held
        pods keep their queue position and are retried at the policy's
        next wake."""
        return []

    def on_commit(self, sim: "EventEngine", node_index: int,
                  t: float) -> float | None:
        """A pod was bound to ``node_index`` at ``t``; return an adjusted
        effective start time (e.g. a WAKING node's ready instant) or None
        to keep the current one."""
        return None

    def on_completion(self, sim: "EventEngine", node_index: int,
                      end_t: float) -> None:
        """A running task on ``node_index`` completed at ``end_t``."""

    def on_evict(self, sim: "EventEngine", node_index: int,
                 t: float) -> None:
        """A running task was evicted off ``node_index`` at ``t``."""

    def on_round_end(self, sim: "EventEngine", unplaced: Sequence["Pod"],
                     held: Sequence["Pod"], t: float) -> None:
        """The round is over; ``unplaced`` pods found no node (``held`` ⊆
        ``unplaced`` sat the round out voluntarily)."""

    def next_wake_time(self, sim: "EventEngine", t: float,
                       held: Sequence["Pod"]) -> Event | None:
        """This policy's earliest event strictly after ``t`` (a
        CARBON_CHECK / WAKE_DONE / CONSOLIDATE_TICK), or None."""
        return None

    def on_tick(self, sim: "EventEngine", event: Event) -> None:
        """A wake-like event contributed by this policy just fired."""

    def finalize(self, sim: "EventEngine", horizon: float) -> None:
        """End of run: close ledgers, publish counters into the state."""

"""Elastic fleet subsystem: node power-state lifecycle and autoscale policies.

GreenPod's energy wins come from consolidating work onto frugal nodes, but a
fleet without a node lifecycle pays every node's idle power forever. This
module makes powering idle capacity down — the biggest energy lever in
edge-cloud orchestration — a first-class scheduling dimension:

1. **Power-state machine** (``ElasticFleet``): every node is in one of four
   states::

       ACTIVE --(last task ends)--> IDLE --(idle_timeout_s)--> ASLEEP
         ^                           |                            |
         |                           +--(task commits)            |
         +--(wake completes, tasks)--WAKING <--(policy wake)------+

   * ``ACTIVE`` — ≥1 committed task; baseline idle power is attributed to
     the schedulers keeping the node awake (the legacy busy-union
     accounting, unchanged).
   * ``IDLE``   — awake but empty; draws full idle power, charged to the
     fleet's state ledger. An IDLE node has *zero marginal idle cost* for
     the TOPSIS energy criterion — it is already paying to be awake.
   * ``ASLEEP`` — suspended; draws ``sleep_power_w`` (a few percent of
     idle), is excluded from scheduling, and is only brought back by a
     policy wake.
   * ``WAKING`` — transitioning ASLEEP→awake; draws idle power for the
     class's ``wake_latency_s`` plus a one-shot ``wake_energy_j`` surge.
     Pods may be committed to a WAKING node — they start exactly when the
     wake completes.

   Sleep transitions are *lazy*: an IDLE node's fall-asleep instant is the
   deterministic ``idle_since + idle_timeout_s``, so the state at any query
   time — and the exact ledger intervals — are derived without event-loop
   ticks. Wake completions are real events (the engine re-runs a scheduling
   round when one lands).

2. **AutoscalePolicy** — the knobs the event-driven engine consumes:
   idle-timeout sleep, queue-pressure wake (pods that end a round unplaced
   wake the TOPSIS-best sleeping node, scored by the same 6-criteria stack
   on any backend), and periodic consolidation (low-utilization nodes are
   drained through the preemption/requeue machinery — every victim must fit
   on the remaining awake fleet *now*, and a deferrable victim is never
   drained past its deadline — then put straight to sleep).

With no policy attached (``run_scenario(..., autoscale=None)``) none of
this machinery runs and the engine reproduces the policy-free output
bitwise (tests/test_elastic.py pins golden table6 plus a cross-backend
property test).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from repro.core import telemetry
from repro.core.energy import NODE_ENERGY_PROFILES
from repro.core.policy import (CONSOLIDATE_TICK, WAKE_DONE, Event,
                               SchedulingPolicy)

# Canonical power-state names (NodeTable carries them as a column; the
# ``awake`` criterion derives from them when set).
ACTIVE = "active"
IDLE = "idle"
ASLEEP = "asleep"
WAKING = "waking"
POWER_STATES = (ACTIVE, IDLE, ASLEEP, WAKING)
AWAKE_STATES = frozenset((ACTIVE, IDLE, WAKING))

# --- per-class wake/sleep profiles ------------------------------------------
# A suspended node retains a wake-on-LAN residual draw (fraction of idle);
# waking draws idle power for the class's boot latency plus a one-shot surge
# (spin-up, cache warm) modelled as an energy lump. Frugal edge boxes (A)
# resume fast; the beefy class-C tier pays the longest latency.
SLEEP_POWER_FRACTION = 0.05
WAKE_SURGE_FACTOR = 2.0
_WAKE_LATENCY_S = {"A": 2.0, "B": 4.0, "C": 8.0, "default": 4.0}

NODE_WAKE_PROFILES: dict[str, dict[str, float]] = {
    cls: {
        "wake_latency_s": _WAKE_LATENCY_S[cls],
        "sleep_power_w": SLEEP_POWER_FRACTION * prof["idle_power"],
        "wake_energy_j": (WAKE_SURGE_FACTOR * prof["idle_power"]
                          * _WAKE_LATENCY_S[cls]),
    }
    for cls, prof in NODE_ENERGY_PROFILES.items()
}


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Elasticity configuration for the event-driven engine
    (``repro.cluster.simulator.run_scenario(..., autoscale=...)``).

    * ``idle_timeout_s`` — a node empty for this long falls ASLEEP
      (``math.inf`` keeps the fleet always-on: full state accounting, no
      sleeping — the baseline the idle-energy savings are measured against).
    * ``wake_on_pressure`` — pods that end a scheduling round unplaced wake
      the TOPSIS-best sleeping node that fits them (one wake per uncovered
      pod, FIFO); with this off, sleeping capacity is only recovered by
      consolidationless attrition, so pods can go unschedulable while
      capacity sleeps.
    * ``consolidate_interval_s`` — cadence of the periodic consolidation
      pass (``None`` disables): awake nodes with cpu utilization below
      ``consolidate_util_below`` are drained — their running tasks are
      evicted, requeued, and re-placed by the normal TOPSIS round — and put
      straight to sleep. A node is only drained when every one of its tasks
      fits on the remaining awake fleet at drain time, and never when that
      would start a deferrable pod past its deadline.
    * ``min_awake`` — the first ``min_awake`` nodes never auto-sleep and are
      never drained (a deterministic awake floor that keeps the fleet
      schedulable without waiting a wake latency).
    """

    idle_timeout_s: float = 60.0
    wake_on_pressure: bool = True
    consolidate_interval_s: float | None = None
    consolidate_util_below: float = 0.25
    min_awake: int = 1

    def __post_init__(self):
        if math.isnan(self.idle_timeout_s) or self.idle_timeout_s <= 0.0:
            raise ValueError(f"idle_timeout_s must be positive (inf keeps "
                             f"the fleet always-on), got {self.idle_timeout_s}")
        if self.consolidate_interval_s is not None and not (
                self.consolidate_interval_s > 0.0):
            raise ValueError(f"consolidate_interval_s must be positive or "
                             f"None, got {self.consolidate_interval_s}")
        if not 0.0 <= self.consolidate_util_below <= 1.0:
            raise ValueError(f"consolidate_util_below must be in [0, 1], "
                             f"got {self.consolidate_util_below}")
        if self.min_awake < 0:
            raise ValueError(f"min_awake must be >= 0, got {self.min_awake}")


def always_on_fleet_idle_kj(nodes: Sequence, horizon_s: float) -> float:
    """Fleet idle energy of a lifecycle-free (or never-sleeping) fleet:
    every node draws its idle power for the whole horizon. This is the
    analytic baseline autoscale policies are measured against
    (benchmarks/autoscale_sweep.py, the fleet_scheduler demo).
    ``SimResult.fleet_idle_energy_kj`` on an ``autoscale=None`` run counts
    only busy-union idle — its state ledger is empty by design — so
    comparing policies through that method alone would undercount the
    no-policy fleet's true idle draw; use this for the baseline side."""
    return sum(NODE_ENERGY_PROFILES[n.node_class]["idle_power"]
               for n in nodes) * horizon_s / 1000.0


def _best_node(sched, pod, nodes, t, exclude):
    """Highest-closeness feasible node under the run's own TOPSIS scheduler
    (per-pod or batched — whichever the engine is using), with ``exclude``
    masking everything that is not a wake candidate."""
    if hasattr(sched, "select_many"):
        assignments, _ = sched.select_many([pod], nodes, now=t,
                                           exclude=exclude)
        return assignments[0]
    idx, _ = sched.select(pod, nodes, now=t, exclude=exclude)
    return idx


class ElasticFleet:
    """Per-node power-state machine driven by the event-driven engine.

    Tracks, per node: the committed-task count, when the node last became
    empty (``IDLE``), an optional drain-forced sleep instant, and an
    in-flight wake (``WAKING`` until ``wake_ready``). States are *queried*
    at a time ``t`` (sleep transitions are lazy, see module docstring); the
    corresponding IDLE/ASLEEP/WAKING intervals are materialized into the
    run's ``PowerTimeline`` state ledger exactly when a node leaves them
    (or at :meth:`close`), so state-dependent idle power and wake-transition
    energy are accounted without time-stepping.
    """

    def __init__(self, nodes: Sequence, policy: AutoscalePolicy,
                 timeline, t0: float = 0.0):
        self.nodes = nodes
        self.policy = policy
        self.timeline = timeline
        # optional delta-maintained FleetState (the engine's source of
        # truth): when attached, write_states mirrors power-state changes
        # into its columns — marking transitioned nodes dirty, which is
        # what keeps the schedulers' incremental energy/carbon criteria
        # (they depend on the awake mask) in sync — and wake scoring runs
        # against it instead of re-flattening the Node list
        self.table = None
        n = len(nodes)
        self._running = [0] * n
        # when the node last became empty (None while ACTIVE or WAKING)
        self._idle_since: list[float | None] = [t0] * n
        # drain-forced sleep instant (skips the idle timeout)
        self._sleep_at: list[float | None] = [None] * n
        # in-flight wake: request time and completion time
        self._wake_started: list[float | None] = [None] * n
        self._wake_ready: list[float | None] = [None] * n
        self.wakes = 0
        self.sleeps = 0
        self.write_states(t0)

    # --- state queries -------------------------------------------------------
    def _sleep_due(self, i: int) -> float:
        """The instant node i falls (or fell) asleep, given its current
        idle stretch; inf when it cannot auto-sleep."""
        since = self._idle_since[i]
        if since is None:
            return math.inf
        if self._sleep_at[i] is not None:
            return self._sleep_at[i]
        if i < self.policy.min_awake:
            return math.inf
        return since + self.policy.idle_timeout_s

    def state(self, i: int, t: float) -> str:
        if self._wake_ready[i] is not None:
            return WAKING            # advance_to() clears completed wakes
        if self._running[i] > 0:
            return ACTIVE
        return ASLEEP if t >= self._sleep_due(i) else IDLE

    def states(self, t: float) -> list[str]:
        return [self.state(i, t) for i in range(len(self.nodes))]

    def write_states(self, t: float) -> list[str]:
        """Refresh every ``Node.power_state`` (the column the
        awake/marginal-idle criterion derives from); with an attached
        :attr:`table` the FleetState column is synced too, dirtying exactly
        the nodes that transitioned."""
        sts = self.states(t)
        for node, s in zip(self.nodes, sts):
            node.power_state = s
        if self.table is not None:
            self.table.set_power_states(sts)
        return sts

    def exclude_mask(self, t: float) -> np.ndarray:
        """(N,) bool: nodes no scheduler may place on this round (ASLEEP —
        capacity comes back only through a policy wake)."""
        return np.asarray([s == ASLEEP for s in self.states(t)])

    def exclude_for_deadline(self, base: np.ndarray,
                             deadline: float) -> np.ndarray:
        """``base`` plus WAKING nodes whose wake completes after
        ``deadline`` — a deferrable pod must never be started past it, and
        a pod committed to a WAKING node starts at its ready time."""
        ready = np.asarray([-math.inf if r is None else r
                            for r in self._wake_ready])
        return base | (ready > deadline)

    def next_transition(self, t: float) -> float | None:
        """Earliest in-flight wake completion strictly after ``t`` (the only
        state transition needing an engine event — sleeps are lazy and
        change no scheduling outcome until a round queries them)."""
        cands = [r for r in self._wake_ready if r is not None and r > t]
        return min(cands) if cands else None

    # --- ledger materialization ----------------------------------------------
    def _materialize_idle(self, i: int, upto: float) -> None:
        """Flush node i's open idle stretch (and the ASLEEP tail it lazily
        decayed into) to the state ledger, up to ``upto``."""
        since = self._idle_since[i]
        if since is None:
            return
        node = self.nodes[i]
        due = self._sleep_due(i)
        self.timeline.add_state(
            node.name, node.node_class, IDLE, since, min(upto, due),
            NODE_ENERGY_PROFILES[node.node_class]["idle_power"])
        if upto > due:
            self.timeline.add_state(
                node.name, node.node_class, ASLEEP, max(due, since), upto,
                NODE_WAKE_PROFILES[node.node_class]["sleep_power_w"])
            self.sleeps += 1
            telemetry.active().inc("policy_node_sleeps",
                                   policy="AutoscaleScheduling")
        self._idle_since[i] = None
        self._sleep_at[i] = None

    def advance_to(self, t: float) -> None:
        """Finalize wake transitions completed by ``t`` (called whenever the
        engine's clock advances): the WAKING interval lands in the ledger
        and the node becomes ACTIVE (tasks were committed while it woke) or
        IDLE."""
        for i, ready in enumerate(self._wake_ready):
            if ready is None or ready > t:
                continue
            node = self.nodes[i]
            self.timeline.add_state(
                node.name, node.node_class, WAKING,
                self._wake_started[i], ready,
                NODE_ENERGY_PROFILES[node.node_class]["idle_power"])
            self._wake_started[i] = None
            self._wake_ready[i] = None
            self._idle_since[i] = ready if self._running[i] == 0 else None

    # --- engine hooks --------------------------------------------------------
    def on_commit(self, i: int, t: float) -> float:
        """Resources bound on node i at clock ``t``; returns the task's
        effective start — ``t``, or the wake-completion instant when the
        node is still WAKING."""
        if self._wake_ready[i] is not None:
            start = self._wake_ready[i]
        else:
            if t >= self._sleep_due(i):
                raise RuntimeError(
                    f"commit on sleeping node {self.nodes[i].name} at t={t} "
                    f"(the engine must exclude ASLEEP nodes)")
            start = t
            self._materialize_idle(i, t)
        self._running[i] += 1
        self._idle_since[i] = None
        return start

    def on_complete(self, i: int, end_t: float) -> None:
        self._running[i] -= 1
        if self._running[i] == 0 and self._wake_ready[i] is None:
            self._idle_since[i] = end_t

    def on_evict(self, i: int, t: float) -> None:
        """A running task was preempted/drained off node i at ``t``."""
        self.on_complete(i, t)

    def request_wake(self, i: int, t: float) -> float:
        """ASLEEP → WAKING at ``t``: flushes the idle/asleep stretch, posts
        the wake-surge energy lump, and returns the ready instant."""
        node = self.nodes[i]
        self._materialize_idle(i, t)
        prof = NODE_WAKE_PROFILES[node.node_class]
        self._wake_started[i] = t
        self._wake_ready[i] = t + prof["wake_latency_s"]
        self.timeline.add_wake(node.name, node.node_class, t,
                               prof["wake_energy_j"])
        self.wakes += 1
        telemetry.active().inc("policy_node_wakes",
                               policy="AutoscaleScheduling")
        return self._wake_ready[i]

    def force_sleep(self, i: int, t: float) -> None:
        """Drain completed: the (now empty) node sleeps immediately,
        skipping the idle timeout."""
        self._idle_since[i] = t
        self._sleep_at[i] = t

    def close(self, horizon: float) -> None:
        """End of run: flush every open state interval up to ``horizon``."""
        for i, node in enumerate(self.nodes):
            ready = self._wake_ready[i]
            if ready is not None:
                # a wake still in flight (pressure-woken, pods landed
                # elsewhere): charge the transition up to the horizon
                self.timeline.add_state(
                    node.name, node.node_class, WAKING,
                    self._wake_started[i], min(ready, horizon),
                    NODE_ENERGY_PROFILES[node.node_class]["idle_power"])
                self._wake_started[i] = None
                self._wake_ready[i] = None
                if ready < horizon and self._running[i] == 0:
                    self._idle_since[i] = ready
                    self._sleep_at[i] = None
                    self._materialize_idle(i, horizon)
                continue
            self._materialize_idle(i, horizon)

    # --- autoscale decisions -------------------------------------------------
    def wake_for_pressure(self, sched, pods: Sequence, t: float) -> list[int]:
        """Queue-pressure wake: walk the still-pending queue FIFO; each pod
        not covered by capacity woken earlier in this pass wakes the
        TOPSIS-best sleeping node that fits it (scored by the run's own
        scheduler — same 6-criteria stack, any backend). Returns the woken
        node indices."""
        if not self.policy.wake_on_pressure:
            return []
        asleep = np.asarray([s == ASLEEP for s in self.states(t)])
        if not asleep.any():
            return []
        woken: list[int] = []
        free: dict[int, list[float]] = {}
        for pod in pods:
            covered = False
            for j in woken:
                if free[j][0] >= pod.cpu - 1e-9 and free[j][1] >= pod.mem - 1e-9:
                    free[j][0] -= pod.cpu
                    free[j][1] -= pod.mem
                    covered = True
                    break
            if covered:
                continue
            idx = _best_node(sched, pod,
                             self.table if self.table is not None
                             else self.nodes, t, exclude=~asleep)
            if idx is None:
                continue                 # fits no sleeping node either
            self.request_wake(idx, t)
            asleep[idx] = False
            woken.append(idx)
            free[idx] = [self.nodes[idx].free_cpu - pod.cpu,
                         self.nodes[idx].free_mem - pod.mem]
        return woken

    def consolidation_victims(self, t: float, running: Sequence,
                              deadline_of: Callable) -> tuple[list[int],
                                                              list]:
        """Pick this pass's drain targets: awake ACTIVE nodes (index ≥
        ``min_awake``) with cpu utilization below the policy threshold,
        lowest first. A node is drained only if (a) the awake floor
        survives, (b) none of its tasks belongs to a deferrable pod at or
        past its deadline (the restart must start ≤ deadline), and (c)
        every one of its tasks fits on the remaining awake, non-draining
        fleet right now (first-fit capacity ledger over ACTIVE/IDLE nodes —
        WAKING capacity is not counted, so a migrated deferrable pod is
        never forced past its deadline by a wake latency). The engine
        requeues victims at the *front* of the pending queue, so the
        fit-check holds against same-round arrivals.

        The TOPSIS round re-places victims by score, not by this ledger's
        first-fit order, so for *deferrable* victims (the class with a
        hard never-start-past-deadline contract) the bar is stricter and
        order-independent: the victim must fit on some awake node even if
        every other victim of the pass landed on that same node first.
        Non-deferrable victims keep the first-fit proof — in the rare
        packing divergence they retry like any pending pod (worst case a
        pressure wake recovers the capacity). ``running`` holds the
        kernel's ``RunningTask`` entries; returns (drained node indices,
        victim entries)."""
        sts = self.states(t)
        by_node: dict[int, list] = {}
        for e in running:
            by_node.setdefault(e.node_index, []).append(e)
        cands = sorted(
            (i for i in by_node
             if sts[i] == ACTIVE and i >= self.policy.min_awake
             and self.nodes[i].cpu_util < self.policy.consolidate_util_below),
            key=lambda i: (self.nodes[i].cpu_util, i))
        if not cands:
            return [], []
        n_awake = sum(s in AWAKE_STATES for s in sts)
        # conservative ledger: candidates host nobody else's victims
        base = {i: (self.nodes[i].free_cpu, self.nodes[i].free_mem)
                for i, s in enumerate(sts)
                if s in (ACTIVE, IDLE) and i not in set(cands)}
        ledger = {i: list(cap) for i, cap in base.items()}
        drained: list[int] = []
        victims: list = []
        for i in cands:
            if n_awake - len(drained) <= self.policy.min_awake:
                break
            vs = by_node[i]
            if any(e.pod.deferrable and not t < deadline_of(e.pod)
                   for e in vs):
                continue
            trial = {j: list(cap) for j, cap in ledger.items()}
            ok = True
            for e in vs:
                pod = e.pod
                fit = next((cap for cap in trial.values()
                            if cap[0] >= pod.cpu - 1e-9
                            and cap[1] >= pod.mem - 1e-9), None)
                if fit is None:
                    ok = False
                    break
                fit[0] -= pod.cpu
                fit[1] -= pod.mem
            if not ok:
                continue
            ledger = trial
            drained.append(i)
            victims.extend(vs)
        # order-independent deadline guarantee: a deferrable victim must
        # fit on some awake node even after every *other* victim of the
        # pass is charged against that node (whatever packing the TOPSIS
        # round picks, restart-now stays feasible). Nodes whose deferrable
        # victims miss that bar are dropped from the pass; shrinking the
        # victim set only loosens the test, so this converges.
        while victims:
            tot_cpu = sum(e.pod.cpu for e in victims)
            tot_mem = sum(e.pod.mem for e in victims)
            bad = {e.node_index for e in victims
                   if e.pod.deferrable and math.isfinite(deadline_of(e.pod))
                   and not any(
                       c - (tot_cpu - e.pod.cpu) >= e.pod.cpu - 1e-9
                       and m - (tot_mem - e.pod.mem) >= e.pod.mem - 1e-9
                       for c, m in base.values())}
            if not bad:
                break
            drained = [i for i in drained if i not in bad]
            victims = [e for e in victims if e.node_index not in bad]
        return drained, victims


class AutoscaleScheduling(SchedulingPolicy):
    """The elastic fleet lifecycle as a kernel policy: the engine-side
    logic of :class:`AutoscalePolicy`, expressed through the
    :class:`~repro.core.policy.SchedulingPolicy` hook protocol around an
    :class:`ElasticFleet` state machine.

    * ``on_clock``       — finalize wake transitions completed by ``t``
      (their WAKING intervals land in the state ledger before the round
      queries node states).
    * ``on_round_start`` — the *drain* event: at the consolidation
      cadence, low-utilization nodes' tasks are evicted through the
      kernel's truncate-and-requeue machinery (victims go to the *front*
      of the pending queue) and the emptied nodes sleep immediately.
    * ``exclude_mask`` / ``exclude_for`` — ASLEEP nodes are masked out of
      every pod's scoring validity; WAKING nodes whose ready time lies
      past a deferrable pod's deadline are masked for that pod.
    * ``on_commit``      — a pod bound to a still-WAKING node starts
      exactly at the wake-completion instant.
    * ``on_round_end``   — the *wake* event: pods that ended the round
      unplaced (and are not voluntarily deferring) wake the TOPSIS-best
      sleeping nodes.
    * ``next_wake_time`` — WAKE_DONE at in-flight wake completions;
      CONSOLIDATE_TICK at the drain cadence while tasks run.

    One instance drives one run (the fleet state machine is per-run);
    ``run_scenario`` constructs a fresh one per call.
    """

    def __init__(self, policy: AutoscalePolicy):
        self.policy = policy
        self.fleet: ElasticFleet | None = None
        self.next_consolidate = policy.consolidate_interval_s

    def bind(self, sim) -> None:
        self.fleet = ElasticFleet(sim.state.nodes, self.policy,
                                  sim.state.timeline)
        # adopt the engine's FleetState so power-state transitions land in
        # its columns (dirty-tracked) the moment write_states runs
        self.fleet.table = getattr(sim.state, "fleet", None)

    def on_clock(self, sim, t: float) -> None:
        self.fleet.advance_to(t)
        tel = telemetry.active()
        if tel.enabled:
            # observer-only: per-state node counts over sim time (states()
            # is a read-only view, so recording can't perturb the run)
            states = self.fleet.states(t)
            for state in POWER_STATES:
                tel.record("fleet_state_nodes", t,
                           float(states.count(state)), state=state)

    def on_round_start(self, sim, t: float) -> None:
        if self.next_consolidate is None or t < self.next_consolidate:
            return
        st = sim.state
        if st.running:
            drain_idxs, victims = self.fleet.consolidation_victims(
                t, st.running, sim.deadline)
            if victims:
                # drained pods go to the FRONT of the queue: they are
                # older than any pod arriving this round, and restart
                # priority is what keeps the drain-time fit guarantee
                # (and deferrable victims' deadlines) honest against
                # same-round arrival contention
                st.pending[:0] = sim.evict(victims, t)
                st.migrations += len(victims)
                telemetry.active().inc("policy_drained_tasks",
                                       value=float(len(victims)),
                                       policy=type(self).__name__)
                for i in drain_idxs:
                    self.fleet.force_sleep(i, t)
        self.next_consolidate = t + self.policy.consolidate_interval_s

    def exclude_mask(self, sim, t: float) -> np.ndarray:
        self.fleet.write_states(t)
        return self.fleet.exclude_mask(t)

    def exclude_for(self, sim, pod, base: np.ndarray,
                    t: float) -> np.ndarray | None:
        if pod.deferrable and math.isfinite(pod.deadline_s):
            return self.fleet.exclude_for_deadline(base, sim.deadline(pod))
        return None

    def on_commit(self, sim, node_index: int, t: float) -> float:
        return self.fleet.on_commit(node_index, t)

    def on_completion(self, sim, node_index: int, end_t: float) -> None:
        self.fleet.on_complete(node_index, end_t)

    def on_evict(self, sim, node_index: int, t: float) -> None:
        self.fleet.on_evict(node_index, t)

    def on_round_end(self, sim, unplaced, held, t: float) -> None:
        if not unplaced:
            return
        held_uids = {p.uid for p in held}
        pressure = [p for p in unplaced if p.uid not in held_uids]
        if pressure:
            self.fleet.wake_for_pressure(sim.state.schedulers["topsis"],
                                         pressure, t)

    def next_wake_time(self, sim, t: float, held) -> Event | None:
        cands: list[Event] = []
        ready = self.fleet.next_transition(t)
        if ready is not None:
            cands.append(Event.make(ready, WAKE_DONE))
        if (self.next_consolidate is not None and sim.state.running
                and self.next_consolidate > t):
            cands.append(Event.make(self.next_consolidate, CONSOLIDATE_TICK))
        return min(cands) if cands else None

    def finalize(self, sim, horizon: float) -> None:
        self.fleet.close(horizon)
        sim.state.wakes = self.fleet.wakes
        sim.state.sleeps = self.fleet.sleeps

"""Pareto frontier engine over TOPSIS weighting schemes (ROADMAP item 4).

The paper's headline result (up to 39.1% energy savings) depends on *which*
weighting scheme an operator picks, but it only evaluates five fixed
vectors. This module sweeps the whole trade-off surface instead: generate a
simplex-lattice grid of weight vectors, score every scheme in ONE fused
dispatch (``BatchScheduler.select_many_grid`` — the (S, P, N) closeness
tensor from ``topsis.closeness_grid`` / the weight-grid Pallas kernel),
collect per-scheme cost metrics (energy / carbon / mean latency /
unschedulable rate), and filter to the Pareto-optimal set with an exact
dominance pass. ``FrontierAtlas.dominant_scheme(regime)`` then answers "which
weights should this cluster run under this carbon regime".

Two metric collectors with different fidelity/cost trade-offs:

  placement_metrics — one-round what-if: the whole queue placed under every
      scheme off one fleet snapshot, metrics read from the decision tensor
      (predicted energy / runtime / emission of the greedy placements).
      Scales to thousands of schemes — this is the fused grid path.
  scenario_metrics  — engine-exact: one full ``run_scenario`` per NAMED
      scheme (serial; the event engine rebinds state between decisions, so
      only the scoring step parallelizes across schemes, not the dynamics).
      Use for the final handful of frontier survivors, not the full grid.

All metrics are cost-direction (lower is better); negate any benefit metric
before handing it to :func:`pareto_mask`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.weighting import validate_weights, weights_for

# The frontier's metric axes, all cost-direction. carbon_g is present only
# when a carbon signal is attached (the collectors drop it otherwise).
METRIC_KEYS = ("energy_kj", "carbon_g", "mean_latency_s",
               "unschedulable_rate")


# --- simplex-lattice weight grids -------------------------------------------
def grid_size(n: int, criteria: int = 5) -> int:
    """Number of points in the {n, criteria} simplex lattice:
    C(n + criteria - 1, criteria - 1) compositions of n."""
    return math.comb(n + criteria - 1, criteria - 1)


def lattice_n_for(min_schemes: int, criteria: int = 5) -> int:
    """Smallest lattice degree n whose grid has >= ``min_schemes`` points."""
    n = 1
    while grid_size(n, criteria) < min_schemes:
        n += 1
    return n


def _compositions(n: int, parts: int):
    """All compositions of n into ``parts`` non-negative ints, first part
    descending — the grid's deterministic lexicographic order."""
    if parts == 1:
        yield (n,)
        return
    for k in range(n, -1, -1):
        for rest in _compositions(n - k, parts - 1):
            yield (k,) + rest


def weight_grid(n: int, criteria: int = 5) -> np.ndarray:
    """The {n, criteria} simplex-lattice weight grid: every vector with
    entries k/n (k non-negative integers summing to n), as a
    (grid_size(n, criteria), criteria) float64 array in deterministic
    lexicographic order. Rows are normalized at generation (``w / w.sum()``)
    so every scheme passes :func:`repro.core.weighting.validate_weights` —
    the same check the schedulers apply to user grids. ``n=1`` yields
    exactly the ``criteria`` unit vectors (one all-in scheme per criterion);
    the paper's calibrated schemes are interior points of finer lattices."""
    if n < 1:
        raise ValueError(f"lattice degree n must be >= 1, got {n}")
    if criteria not in (5, 6):
        raise ValueError(f"criteria must be 5 or 6 (see validate_weights), "
                         f"got {criteria}")
    out = np.array(list(_compositions(n, criteria)), dtype=np.float64)
    out /= out.sum(axis=1, keepdims=True)
    return validate_weights(out, name="weight_grid")


def weight_grid_upto(n_schemes: int, criteria: int = 5) -> np.ndarray:
    """Exactly ``n_schemes`` rows: the finest lattice that reaches the count,
    truncated to its first ``n_schemes`` points (lexicographic prefix —
    deterministic, so benchmark cells at S=512/4096 are reproducible)."""
    full = weight_grid(lattice_n_for(n_schemes, criteria), criteria)
    return full[:n_schemes]


# --- exact dominance filtering ----------------------------------------------
def pareto_mask(metrics) -> np.ndarray:
    """(S,) bool mask of the Pareto-optimal rows of an (S, M) cost-metric
    matrix: row i survives iff no row j weakly dominates it (``j <= i`` on
    every metric AND ``j < i`` on at least one). Exact comparisons, no
    tolerance; identical rows never dominate each other, so ties all stay
    on the front; a single point is trivially optimal."""
    m = np.asarray(metrics, dtype=np.float64)
    if m.ndim != 2:
        raise ValueError(f"metrics must be (S, M), got shape {m.shape}")
    if not np.isfinite(m).all():
        raise ValueError("metrics must be finite to compare dominance")
    le = (m[:, None, :] <= m[None, :, :]).all(axis=-1)   # [j, i]: j <= i
    lt = (m[:, None, :] < m[None, :, :]).any(axis=-1)    # [j, i]: j < i
    return ~(le & lt).any(axis=0)


@dataclass
class SchemePoint:
    """One weighting scheme and its measured cost metrics."""
    index: int
    weights: np.ndarray
    metrics: dict[str, float]
    name: str | None = None

    def as_dict(self) -> dict:
        return {"index": self.index, "name": self.name,
                "weights": [round(float(w), 6) for w in self.weights],
                "metrics": {k: float(v) for k, v in self.metrics.items()}}


@dataclass
class ParetoFrontier:
    """Exact dominance filter over one scheme-metric table (one regime)."""
    points: list[SchemePoint]
    metric_names: tuple[str, ...]
    mask: np.ndarray = field(init=False)
    front: list[SchemePoint] = field(init=False)

    def __post_init__(self):
        matrix = np.array([[p.metrics[k] for k in self.metric_names]
                           for p in self.points], dtype=np.float64)
        self._matrix = matrix
        self.mask = pareto_mask(matrix)
        self.front = [p for p, keep in zip(self.points, self.mask) if keep]

    def dominant(self) -> SchemePoint:
        """The frontier's balanced pick: among Pareto-optimal points, the
        one minimizing the mean min-max-normalized cost across metrics
        (normalization spans the WHOLE point set, so the pick is stable
        under removing dominated points). Deterministic: exact-score ties
        break to the lowest scheme index."""
        lo = self._matrix.min(axis=0)
        span = np.maximum(self._matrix.max(axis=0) - lo, 1e-300)
        scores = ((self._matrix - lo) / span).mean(axis=1)
        scores = np.where(self.mask, scores, np.inf)
        return self.points[int(np.argmin(scores))]

    def as_dict(self) -> dict:
        return {"metrics": list(self.metric_names),
                "n_schemes": len(self.points),
                "n_front": int(self.mask.sum()),
                "dominant": self.dominant().as_dict(),
                "front": [p.as_dict() for p in self.front]}


class FrontierAtlas:
    """Per-regime frontier collection: sweep the same scheme grid under
    several operating regimes (carbon signals, fleet mixes, loads) and look
    up the scheme an operator should run in each."""

    def __init__(self):
        self.frontiers: dict[str, ParetoFrontier] = {}

    def add(self, regime: str, frontier: ParetoFrontier) -> None:
        self.frontiers[regime] = frontier

    def dominant_scheme(self, regime: str) -> SchemePoint:
        """The balanced Pareto-optimal scheme for ``regime`` (see
        :meth:`ParetoFrontier.dominant`)."""
        try:
            return self.frontiers[regime].dominant()
        except KeyError:
            raise KeyError(
                f"unknown regime {regime!r}; swept regimes: "
                f"{sorted(self.frontiers)}") from None

    def to_report(self) -> dict:
        """The frontier payload ``repro.telemetry.report.html_report``
        renders as a table + scatter section."""
        return {regime: f.as_dict() for regime, f in self.frontiers.items()}


# --- metric collection -------------------------------------------------------
def points_from_placements(ws, assignments, mats, inten=None,
                           names: Sequence[str] | None = None
                           ) -> list[SchemePoint]:
    """Per-scheme :class:`SchemePoint` metrics read off the decision tensor:
    ``assignments[s][i]`` is pod i's node under scheme s (None = unplaced),
    ``mats`` the (P, N, C) decision tensor the placements were scored on
    (CRITERIA_NAMES order: col 0 predicted runtime s, col 1 predicted task
    energy J, col 5 emission rate W·g/kWh when ``inten`` is given). Shared
    by :func:`placement_metrics` and the pareto sweep benchmark so both
    derive frontier membership from identical arithmetic."""
    points = []
    for s, assign in enumerate(assignments):
        placed = [(i, a) for i, a in enumerate(assign) if a is not None]
        energy_j = sum(mats[i, a, 1] for i, a in placed)
        # mean predicted runtime of the placed work; 0.0 when nothing
        # placed — the unschedulable_rate of 1.0 flags that degenerate row
        latency = (sum(mats[i, a, 0] for i, a in placed) / len(placed)
                   if placed else 0.0)
        metrics = {"energy_kj": float(energy_j / 1e3),
                   "mean_latency_s": float(latency),
                   "unschedulable_rate":
                       1.0 - len(placed) / max(len(assign), 1)}
        if inten is not None:
            # rate column is W x g/kWh; x runtime(s) / 3.6e6 -> grams
            metrics["carbon_g"] = float(sum(
                mats[i, a, 5] * mats[i, a, 0] for i, a in placed) / 3.6e6)
        points.append(SchemePoint(
            index=s, weights=np.asarray(ws[s], dtype=np.float64),
            metrics=metrics,
            name=None if names is None else names[s]))
    return points


def placement_metrics(pods, nodes, schemes, scheduler=None,
                      backend: str = "jax", carbon_signal=None,
                      now: float = 0.0,
                      names: Sequence[str] | None = None
                      ) -> list[SchemePoint]:
    """One-round what-if metrics for every scheme in one fused dispatch.

    ``select_many_grid`` scores the queue under all S schemes at once and
    walks an independent greedy ledger per scheme; each scheme's metrics
    are then read off the decision tensor for its placements — predicted
    task energy (kJ), mean predicted runtime (s, the placement-latency
    proxy; 0.0 when a scheme places nothing, which its unschedulable_rate
    of 1.0 flags), emission of the placed work (g, only with a signal:
    rate column x runtime), and the unplaced fraction. These are the
    criteria the scheduler itself trades off, so the frontier is exactly
    the scheduler's own preference surface — engine-exact dynamics
    (idle energy, deferrals) need :func:`scenario_metrics`.
    """
    from repro.core.scheduler import (BatchScheduler, _as_table,
                                      decision_matrix_batch)
    if scheduler is None:
        scheduler = BatchScheduler(scheme="general", backend=backend,
                                   carbon_signal=carbon_signal)
    table = _as_table(nodes)
    ws = scheduler._weight_grid(schemes)
    assignments, _ = scheduler.select_many_grid(pods, table, ws, now=now)
    signal = scheduler.carbon_signal
    inten = (signal.intensities(table.region, now)
             if signal is not None else None)
    mats = decision_matrix_batch(pods, table, carbon_intensity=inten)
    return points_from_placements(ws, assignments, mats, inten=inten,
                                  names=names)


def scenario_metrics(schemes: Sequence[str], arrivals_factory,
                     cluster_factory=None, carbon=None, autoscale=None,
                     batch: bool = False, batch_backend: str = "jax"
                     ) -> list[SchemePoint]:
    """Engine-exact per-scheme metrics: one full ``run_scenario`` per NAMED
    scheme, serially — the event engine's feedback loop (binds change the
    next decision's fleet state) can't be batched across schemes, which is
    exactly why :func:`placement_metrics` exists for the wide sweep.
    ``arrivals_factory`` is called once per scheme (fresh arrival process,
    same seed => identical workload)."""
    from repro.cluster.simulator import run_scenario
    points = []
    for s, scheme in enumerate(schemes):
        kwargs = {} if cluster_factory is None else {
            "cluster_factory": cluster_factory}
        res = run_scenario(arrivals_factory(), scheme, carbon=carbon,
                           autoscale=autoscale, batch=batch,
                           batch_backend=batch_backend, **kwargs)
        metrics = {"energy_kj": float(res.energy_kj("topsis")),
                   "mean_latency_s": float(res.mean_exec_time_s("topsis")),
                   "unschedulable_rate": float(res.unschedulable_rate())}
        if carbon is not None:
            metrics["carbon_g"] = float(res.total_carbon_g("topsis"))
        points.append(SchemePoint(
            index=s, weights=weights_for(scheme, carbon=carbon is not None),
            metrics=metrics, name=scheme))
    return points


def frontier_for(points: Sequence[SchemePoint]) -> ParetoFrontier:
    """Frontier over whatever metric keys the points actually carry (in
    METRIC_KEYS order) — collectors drop carbon_g without a signal."""
    present = tuple(k for k in METRIC_KEYS if k in points[0].metrics)
    return ParetoFrontier(list(points), present)

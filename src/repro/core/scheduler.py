"""Schedulers: GreenPod (TOPSIS), its fleet-scale batched variant, and the
default-K8s baseline.

Per-pod schedulers expose ``select(pod, nodes) -> (node_index | None,
diagnostics)`` over a list of ``repro.cluster.node.Node`` (or a prebuilt
``NodeTable``). ``BatchScheduler.select_many(pods, nodes)`` scores a whole
queue of pods against one fleet snapshot in a single call — the 1000+-node
path. The baseline reimplements the upstream kube-scheduler scoring
pipeline the paper compares against: filter (PodFitsResources) → score
(LeastRequestedPriority + BalancedResourceAllocation) → bind to max score.

Backends (scoring engines, identical semantics — tests assert equivalence):

  numpy   — ``topsis.closeness_np``; lowest latency for single decisions
            (no device dispatch) and the semantic reference.
  jax     — jitted jnp engine; ``BatchScheduler`` vmaps it over the pod
            queue (``topsis.batched_closeness``) for throughput.
  pallas  — the tiled TPU kernel via ``repro.kernels.ops`` (interpret mode
            on CPU, Mosaic on TPU); for fleets large enough to tile.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import telemetry, topsis
from repro.core.carbon import CarbonSignal
from repro.core.criteria import (benefit_mask, criteria_matrix,
                                 greenpod_criteria, placement_power)
from repro.core.energy import predicted_task_energy_joules
from repro.core.weighting import (CARBON_SCHEMES, adaptive_weights,
                                  scheme_grid, validate_weights, weights_for)
from repro.cluster.node import FleetState, Node, NodeTable
from repro.cluster.workload import Pod

_BENEFIT = benefit_mask()

BACKENDS = ("numpy", "jax", "pallas")


def predict_exec_time(pod: Pod, node: Node) -> float:
    """Energy-profiling module prediction: runtime scales inversely with the
    node class's per-core speed (requests are guaranteed, no oversubscription
    past the filter)."""
    return pod.workload.base_time_s / node.speed


def predict_energy(pod: Pod, node: Node) -> float:
    awake = node.used_cpu > 1e-9
    return predicted_task_energy_joules(
        node.node_class, predict_exec_time(pod, node), pod.cpu, awake)


def _as_table(nodes) -> NodeTable:
    return nodes if isinstance(nodes, NodeTable) else NodeTable.from_nodes(nodes)


def decision_matrix_table(cpu, mem, base_time_s, table: NodeTable,
                          carbon_intensity=None) -> np.ndarray:
    """(..., N, C) GreenPod decision matrix by broadcasting over the fleet's
    column arrays (criteria.CRITERIA_NAMES order) — no per-node Python loop.

    ``cpu`` / ``mem`` / ``base_time_s`` are scalars for one pod (→ (N, C))
    or ``(P, 1)`` arrays for a queue (→ (P, N, C)). C is 5, or 6 when
    ``carbon_intensity`` (the (N,) gCO2/kWh column for the fleet's regions
    at decision time) is given — the sixth column is the placement's
    emission rate: power draw (dynamic for the request, plus the idle power
    a sleeping node would newly wake) x regional intensity. The arithmetic
    lives in :func:`repro.core.criteria.criteria_matrix` — the same code
    the incremental :class:`FleetCriteriaCache` uses to refresh dirty node
    columns, so the two paths agree bitwise by construction."""
    return criteria_matrix(cpu, mem, base_time_s, table,
                           carbon_intensity=carbon_intensity)


def decision_matrix(pod: Pod, nodes, carbon_intensity=None) -> np.ndarray:
    """(N, C) decision matrix for one pod; ``nodes`` is a Node list or a
    NodeTable."""
    table = _as_table(nodes)
    return decision_matrix_table(pod.cpu, pod.mem, pod.workload.base_time_s,
                                 table, carbon_intensity=carbon_intensity)


def decision_matrix_batch(pods: Sequence[Pod], nodes,
                          carbon_intensity=None) -> np.ndarray:
    """(P, N, C) decision tensor for a queue of pods against one fleet
    snapshot (every pod scored on identical cluster state)."""
    table = _as_table(nodes)
    col = lambda xs: np.asarray(xs, dtype=np.float64)[:, None]
    return decision_matrix_table(col([p.cpu for p in pods]),
                                 col([p.mem for p in pods]),
                                 col([p.workload.base_time_s for p in pods]),
                                 table, carbon_intensity=carbon_intensity)


def _score(mat: np.ndarray, weights: np.ndarray, valid: np.ndarray,
           backend: str, benefit: np.ndarray = _BENEFIT) -> np.ndarray:
    """(N,) closeness for one decision matrix on the given backend
    (invalid rows are -inf)."""
    if backend == "numpy":
        return np.asarray(topsis.closeness_np(mat, weights, benefit,
                                              valid).closeness)
    if backend == "jax":
        return np.asarray(topsis.closeness(mat, weights, benefit,
                                           valid).closeness)
    if backend == "pallas":
        from repro.kernels import ops
        return np.asarray(ops.topsis_closeness(mat, weights, benefit,
                                               valid=valid))
    raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")


def _greedy_assign(cc: np.ndarray, pods: Sequence[Pod], table: NodeTable,
                   blocked=None) -> "list[int | None]":
    """Commit one (P, N) closeness matrix greedily in queue order against a
    fresh capacity ledger: each pod takes its best-ranked node that still
    fits (``blocked[i]`` optionally forbids one node index for ``pods[i]``).
    Extracted from :meth:`BatchScheduler.select_many` so the grid path
    commits every scheme through identical code — the per-scheme ledgers
    are independent what-if placements off the same snapshot."""
    order = np.argsort(-cc, kind="stable", axis=-1)
    free_cpu = table.free_cpu.copy()
    free_mem = table.free_mem.copy()
    assignments: list[int | None] = []
    for i, pod in enumerate(pods):
        forbid = blocked[i] if blocked is not None else None
        chosen = None
        for j in order[i]:
            if np.isneginf(cc[i, j]):
                break           # rest of the ranking is infeasible
            if forbid is not None and int(j) == forbid:
                continue
            if free_cpu[j] >= pod.cpu - 1e-9 \
                    and free_mem[j] >= pod.mem - 1e-9:
                chosen = int(j)
                free_cpu[j] -= pod.cpu
                free_mem[j] -= pod.mem
                break
        assignments.append(chosen)
    return assignments


def _check_carbon_scheme(scheme: str, carbon_signal) -> None:
    if scheme in CARBON_SCHEMES and carbon_signal is None:
        raise ValueError(
            f"scheme {scheme!r} weights the carbon-rate criterion; "
            f"construct the scheduler with a carbon_signal "
            f"(repro.core.carbon.CarbonSignal) to use it")


class FleetCriteriaCache:
    """Incrementally maintained decision-matrix cache over one attached
    :class:`~repro.cluster.node.FleetState`.

    The insight that makes the cache cheap: pods come in a handful of
    workload *kinds* (identical ``(cpu, mem, base_time_s)`` request
    triples), and the criteria arithmetic is elementwise per node — so one
    ``(K, N, C)`` float64 tensor (K = kinds seen so far) covers every pod,
    and a pod's ``(N, C)`` matrix is a zero-copy row view. Per round
    :meth:`sync` consumes the fleet's dirty-column contract
    (``modified_since``): only columns of nodes touched since the last
    sync are recomputed (through ``repro.core.criteria.criteria_matrix``,
    the same code the full-rebuild oracle uses — bitwise agreement by
    construction), and the carbon_rate column is refreshed from the cached
    time-invariant power factor whenever decision time moves.

    Returned matrices/rows are views into the cache: read-only until the
    next :meth:`sync`.
    """

    def __init__(self, fleet: FleetState, carbon_signal: CarbonSignal | None):
        self.fleet = fleet
        self.signal = carbon_signal
        self.n_criteria = 6 if carbon_signal is not None else 5
        self._kinds: dict[tuple, int] = {}    # request triple -> row index
        self._reqs: list[tuple] = []
        n = len(fleet)
        self.mats = np.zeros((0, n, self.n_criteria))
        self._power_w = np.zeros((0, n))      # carbon power factor per kind
        self._synced = fleet.version
        self._carbon_now: float | None = None
        self.intensities: np.ndarray | None = None   # (N,) at _carbon_now

    def _kind_of(self, pod: Pod) -> tuple:
        return (pod.cpu, pod.mem, pod.workload.base_time_s)

    def _full_row(self, req: tuple) -> tuple[np.ndarray, np.ndarray]:
        cpu, mem, bts = req
        mat = np.zeros((len(self.fleet), self.n_criteria))
        mat[:, :5] = criteria_matrix(cpu, mem, bts, self.fleet)
        power = np.zeros(0)
        if self.signal is not None:
            power = placement_power(cpu, self.fleet)
            mat[:, 5] = power * self.intensities
        return mat, power

    def sync(self, pods: Sequence[Pod], now: float):
        """Bring the cache up to date with the fleet and decision time;
        returns ``(kind_idx, dirty, carbon_moved, grew)`` — the per-pod row
        indices, the node indices whose columns were recomputed, whether
        the whole carbon column was refreshed (``now`` moved), and whether
        new kind rows were appended (device mirrors re-upload on growth)."""
        tel = telemetry.active()
        fleet = self.fleet
        dirty = fleet.modified_since(self._synced)
        self._synced = fleet.version
        carbon_moved = False
        if self.signal is not None and now != self._carbon_now:
            self.intensities = np.asarray(
                self.signal.intensities(fleet.region, now), dtype=np.float64)
            self._carbon_now = now
            carbon_moved = True
        if dirty.size and self._reqs:
            col = lambda xs: np.asarray(xs, dtype=np.float64)[:, None]
            cpus, mems, bts = (col([r[j] for r in self._reqs])
                               for j in range(3))
            self.mats[:, dirty, :5] = criteria_matrix(cpus, mems, bts,
                                                      fleet, cols=dirty)
            if self.signal is not None:
                self._power_w[:, dirty] = placement_power(cpus, fleet,
                                                          cols=dirty)
        if self.signal is not None and self._reqs:
            # the carbon column is (time-invariant power) x (intensity at
            # now): refresh all nodes when now moved, else just the dirty
            # subset — elementwise either way, so bitwise-equal to a full
            # rebuild at the same instant
            if carbon_moved:
                self.mats[:, :, 5] = self._power_w * self.intensities
            elif dirty.size:
                self.mats[:, dirty, 5] = (self._power_w[:, dirty]
                                          * self.intensities[dirty])
        grew = False
        new_kinds = 0
        kind_idx = np.empty(len(pods), dtype=np.int64)
        for i, pod in enumerate(pods):
            req = self._kind_of(pod)
            k = self._kinds.get(req)
            if k is None:
                mat, power = self._full_row(req)
                k = len(self._reqs)
                self._kinds[req] = k
                self._reqs.append(req)
                self.mats = np.concatenate([self.mats, mat[None]])
                if self.signal is not None:
                    self._power_w = np.concatenate(
                        [self._power_w, power[None]])
                grew = True
                new_kinds += 1
            kind_idx[i] = k
        tel.inc("cache_syncs")
        if dirty.size:
            tel.inc("cache_dirty_columns", value=float(dirty.size))
        if carbon_moved:
            tel.inc("cache_carbon_refreshes")
        if new_kinds:
            tel.inc("cache_kind_rows_added", value=float(new_kinds))
        return kind_idx, dirty, carbon_moved, grew


def _jit_helpers():
    """The incremental jax path's jitted helpers, built lazily so importing
    the scheduler never pays jax tracing up front."""
    global _scatter_node_cols, _set_carbon_col, _closeness_from_kinds
    global _closeness_grid_from_kinds
    if _scatter_node_cols is not None:
        return
    import functools

    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _scatter_node_cols(dev, idx, block):
        # donated: the old snapshot's buffer is reused in place, so a round
        # never holds two (K, N, C) copies on device
        return dev.at[:, idx, :].set(block)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _set_carbon_col(dev, col):
        return dev.at[:, :, -1].set(col)

    @jax.jit
    def _closeness_from_kinds(dev, kind_idx, ws, benefit, valids):
        # gather the per-kind rows and score in ONE dispatch; the closeness
        # body is topsis.batched_closeness — the same program the
        # full-rebuild jax path jits, so the two agree on identical inputs
        return topsis.batched_closeness(dev[kind_idx], ws, benefit,
                                        valids).closeness

    @jax.jit
    def _closeness_grid_from_kinds(dev, kind_idx, ws, benefit, valids):
        # the grid round: ONE fused gather + (S, P, N) closeness dispatch
        # off the device-resident kind tensor — no re-upload per scheme.
        # The gather happens once; XLA shares the weight-independent
        # normalization across the vmapped scheme axis.
        mats = dev[kind_idx]

        def one_scheme(w):
            wp = jnp.broadcast_to(w, (mats.shape[0], w.shape[-1]))
            return topsis.batched_closeness(mats, wp, benefit,
                                            valids).closeness

        return jax.vmap(one_scheme)(ws)


_scatter_node_cols = None
_set_carbon_col = None
_closeness_from_kinds = None
_closeness_grid_from_kinds = None


def _pow2_pad_len(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


class GreenPodScheduler:
    """TOPSIS-based multi-criteria scheduler (paper §III).

    With a ``carbon_signal`` attached the decision matrix gains the sixth
    carbon-rate column (node power x regional grid intensity at ``now``) and
    weight vectors are the 6-criteria form — paper schemes carry a zero
    carbon weight, so their rankings are bitwise unchanged."""

    name = "topsis"

    def __init__(self, scheme: str = "energy_centric", adaptive: bool = False,
                 backend: str = "numpy",
                 carbon_signal: CarbonSignal | None = None,
                 explain: bool = False):
        _check_carbon_scheme(scheme, carbon_signal)
        self.scheme = scheme
        self.adaptive = adaptive
        self.backend = backend
        self.carbon_signal = carbon_signal
        self.criteria = greenpod_criteria(carbon=carbon_signal is not None)
        self._benefit = benefit_mask(self.criteria)
        self.decision_log: list[dict] = []
        self.explain = explain
        self.explanations: list[dict] = []
        self._cache: FleetCriteriaCache | None = None

    def attach(self, fleet: FleetState) -> None:
        """Adopt ``fleet`` as a live, delta-maintained snapshot: subsequent
        ``select`` calls that receive this exact object reuse the
        incrementally synced decision-matrix cache instead of rebuilding
        the pod's (N, C) matrix from scratch."""
        self._cache = FleetCriteriaCache(fleet, self.carbon_signal)

    def weights(self, nodes) -> np.ndarray:
        carbon = self.carbon_signal is not None
        if not self.adaptive:
            return weights_for(self.scheme, carbon=carbon)
        util = float(np.mean(_as_table(nodes).cpu_util))
        return adaptive_weights(self.scheme, util, carbon=carbon)

    def select(self, pod: Pod, nodes, now: float = 0.0, exclude=None,
               explain: bool = False):
        """Best node for one pod; ``exclude`` optionally masks nodes the
        engine forbids this round (ASLEEP nodes, or WAKING nodes whose
        ready time would start a deferrable pod past its deadline) — they
        are treated exactly like capacity-infeasible nodes. With
        ``explain=True`` (or the scheduler constructed with it) the
        decision's per-criterion attribution (``topsis.explain_np``) is
        appended to ``self.explanations`` and returned in the diagnostics
        — numpy backend only (the jax/pallas engines do not expose the
        weighted intermediates)."""
        explain = explain or self.explain
        if explain and self.backend != "numpy":
            raise ValueError(
                f"explain=True needs backend='numpy', not "
                f"{self.backend!r}: only the numpy path exposes the "
                f"weighted separation terms the attribution decomposes")
        w = None
        with telemetry.active().span("scheduler_decision",
                                     scheduler=self.name,
                                     backend=self.backend) as sp:
            table = _as_table(nodes)
            valid = table.fits(pod.cpu, pod.mem)
            if exclude is not None:
                valid = valid & ~np.asarray(exclude, dtype=bool)
            if not valid.any():
                return None, {"reason": "unschedulable"}
            if self._cache is not None and table is self._cache.fleet:
                kind_idx, _, _, _ = self._cache.sync([pod], now)
                mat = self._cache.mats[kind_idx[0]]
            else:
                inten = (self.carbon_signal.intensities(table.region, now)
                         if self.carbon_signal is not None else None)
                mat = decision_matrix_table(pod.cpu, pod.mem,
                                            pod.workload.base_time_s, table,
                                            carbon_intensity=inten)
            w = self.weights(table)
            cc = _score(mat, w, valid, self.backend, benefit=self._benefit)
            idx = int(np.argmax(cc))   # first max — same tie-break as a
            #                            stable sort
        dt = sp.duration_s
        diag = {"closeness": cc, "scheduling_time_s": dt, "matrix": mat}
        if explain:
            exp = topsis.explain_np(mat, w, self._benefit, valid,
                                    criteria_names=[c.name
                                                    for c in self.criteria])
            exp.update(pod=pod.uid, t=now, node=table.names[idx],
                       runner_up_node=(table.names[exp["runner_up"]]
                                       if exp["runner_up"] is not None
                                       else None))
            self.explanations.append(exp)
            diag["explanation"] = exp
        self.decision_log.append({"pod": pod.uid, "node": table.names[idx],
                                  "time_s": dt})
        return idx, diag


class BatchScheduler:
    """Fleet-scale batched TOPSIS: one scoring pass per arrival burst.

    ``select_many`` builds the (P, N, 5) decision tensor by broadcasting,
    scores every pod against the same fleet snapshot on the configured
    backend, then commits placements greedily in queue order against a
    capacity ledger (each pod takes its best-ranked node that still fits).
    Snapshot scoring is the throughput trade-off vs. the per-pod scheduler's
    rescore-after-every-bind: one engine call amortizes dispatch over the
    whole queue, which is what wins at 1000+ nodes (see
    benchmarks/scheduling_time.py). Input nodes are never mutated — the
    caller binds from the returned assignments.
    """

    name = "topsis-batch"

    def __init__(self, scheme: str = "energy_centric", adaptive: bool = False,
                 backend: str = "jax",
                 carbon_signal: CarbonSignal | None = None,
                 explain: bool = False):
        _check_carbon_scheme(scheme, carbon_signal)
        self.scheme = scheme
        self.adaptive = adaptive
        self.backend = backend
        self.carbon_signal = carbon_signal
        self.criteria = greenpod_criteria(carbon=carbon_signal is not None)
        self._benefit = benefit_mask(self.criteria)
        self.decision_log: list[dict] = []
        self.explain = explain
        self.explanations: list[dict] = []
        self._cache: FleetCriteriaCache | None = None
        self._dev = None          # device-resident (K, N, C) float32 mirror

    def attach(self, fleet: FleetState) -> None:
        """Adopt ``fleet`` as a live, delta-maintained snapshot. Scoring
        calls that receive this exact object take the incremental path:
        only dirty node columns are recomputed, and (jax backend) the
        per-kind criteria tensor stays device-resident across rounds —
        dirty columns are scattered into the donated buffer and a round is
        one fused gather+closeness dispatch."""
        self._cache = FleetCriteriaCache(fleet, self.carbon_signal)
        self._dev = None

    def weights(self, table: NodeTable) -> np.ndarray:
        carbon = self.carbon_signal is not None
        if not self.adaptive:
            return weights_for(self.scheme, carbon=carbon)
        return adaptive_weights(self.scheme, float(np.mean(table.cpu_util)),
                                carbon=carbon)

    def score_queue(self, pods: Sequence[Pod], nodes,
                    now: float = 0.0, exclude=None) -> np.ndarray:
        """(P, N) closeness matrix for the whole queue on one snapshot
        (infeasible nodes are -inf per pod). ``now`` is the decision time
        the carbon column is evaluated at (ignored without a signal).
        ``exclude`` — (N,) or (P, N) bool — masks nodes the engine forbids
        (sleeping nodes; per-pod deadline-late WAKING nodes), folded into
        the validity mask every backend already honors.

        When ``nodes`` is the attached :class:`FleetState` this takes the
        incremental path; any other input scores through the full-rebuild
        path below, which is kept verbatim as the reference oracle
        (tests/test_fleet_state.py asserts the two agree bitwise)."""
        table = _as_table(nodes)
        if self._cache is not None and table is self._cache.fleet:
            telemetry.active().inc("scheduler_score_queue",
                                   path="incremental")
            return self._score_queue_incremental(pods, table, now, exclude)
        telemetry.active().inc("scheduler_score_queue", path="rebuild")
        inten = (self.carbon_signal.intensities(table.region, now)
                 if self.carbon_signal is not None else None)
        mats = decision_matrix_batch(pods, table, carbon_intensity=inten)
        valid = table.fits(np.asarray([p.cpu for p in pods])[:, None],
                           np.asarray([p.mem for p in pods])[:, None])
        if exclude is not None:
            valid = valid & ~np.asarray(exclude, dtype=bool)
        w = self.weights(table)
        ws = np.broadcast_to(w, (len(pods), w.shape[0]))
        if self.backend == "numpy":
            return topsis.batched_closeness_np(mats, ws, self._benefit, valid)
        if self.backend == "jax":
            import jax.numpy as jnp
            # jit caches by shape: pad the pod axis to the next power of two
            # so shrinking retry bursts (P, P-1, ...) hit the cache instead
            # of recompiling per queue length. Padding rows are all-invalid,
            # so they score -inf and are sliced off.
            p = len(pods)
            p_pad = 1 << max(p - 1, 1).bit_length()
            if p_pad != p:
                pad = p_pad - p
                mats = np.concatenate(
                    [mats, np.zeros((pad,) + mats.shape[1:])])
                ws = np.concatenate([ws, np.ones((pad, ws.shape[-1]))])
                valid = np.concatenate(
                    [valid, np.zeros((pad, valid.shape[-1]), bool)])
            cc = topsis.batched_closeness_cc(
                jnp.asarray(mats), jnp.asarray(ws),
                jnp.asarray(self._benefit), jnp.asarray(valid))
            return np.asarray(cc[:p])
        if self.backend == "pallas":
            from repro.kernels import ops
            return np.asarray(ops.topsis_closeness_batched(
                mats, ws, self._benefit, valid=valid))
        raise ValueError(f"unknown backend {self.backend!r}; "
                         f"choose from {BACKENDS}")

    def _score_queue_incremental(self, pods: Sequence[Pod],
                                 fleet: FleetState, now: float,
                                 exclude) -> np.ndarray:
        """The one-dispatch round over the attached fleet: sync the
        per-kind criteria cache (dirty columns only), then score every pod
        as a row gather — numpy reads zero-copy views, jax gathers from
        the device-resident mirror, pallas streams kind blocks through the
        scalar-prefetch kernel."""
        cache = self._cache
        kind_idx, dirty, carbon_moved, grew = cache.sync(pods, now)
        valid = fleet.fits(np.asarray([p.cpu for p in pods])[:, None],
                           np.asarray([p.mem for p in pods])[:, None])
        if exclude is not None:
            valid = valid & ~np.asarray(exclude, dtype=bool)
        w = self.weights(fleet)
        ws = np.broadcast_to(w, (len(pods), w.shape[0]))
        if self.backend == "numpy":
            return np.stack([
                np.asarray(topsis.closeness_np(cache.mats[k], ws[i],
                                               self._benefit,
                                               valid[i]).closeness)
                for i, k in enumerate(kind_idx)])
        if self.backend == "jax":
            import jax.numpy as jnp
            _jit_helpers()
            self._sync_device(cache, dirty, carbon_moved, grew)
            # same pod-axis pow2 padding as the rebuild path (jit caches by
            # shape; shrinking retry bursts reuse the trace). Padding rows
            # gather kind 0 but are all-invalid -> -inf, sliced off.
            p = len(pods)
            p_pad = _pow2_pad_len(p)
            if p_pad != p:
                pad = p_pad - p
                kind_idx = np.concatenate(
                    [kind_idx, np.zeros(pad, dtype=kind_idx.dtype)])
                ws = np.concatenate([ws, np.ones((pad, ws.shape[-1]))])
                valid = np.concatenate(
                    [valid, np.zeros((pad, valid.shape[-1]), bool)])
            cc = _closeness_from_kinds(
                self._dev, jnp.asarray(kind_idx), jnp.asarray(ws),
                jnp.asarray(self._benefit), jnp.asarray(valid))
            telemetry.active().inc("cache_fused_dispatches", backend="jax")
            return np.asarray(cc[:p])
        if self.backend == "pallas":
            from repro.kernels import ops
            return np.asarray(ops.topsis_closeness_kinds(
                cache.mats, kind_idx, ws, self._benefit, valid=valid))
        raise ValueError(f"unknown backend {self.backend!r}; "
                         f"choose from {BACKENDS}")

    def _sync_device(self, cache: FleetCriteriaCache, dirty: np.ndarray,
                     carbon_moved: bool, grew: bool) -> None:
        """Mirror this round's cache delta onto the device tensor. Growth
        (a kind first seen — at most once per workload kind per run)
        re-uploads the whole (K, N, C) tensor; otherwise the dirty node
        columns are scattered into the donated buffer (idx padded to a
        power of two with repeats so the scatter trace is shape-stable),
        and the carbon column is rewritten only when decision time moved."""
        import jax.numpy as jnp
        tel = telemetry.active()
        if self._dev is None or grew:
            tel.inc("cache_device_reuploads",
                    reason="growth" if self._dev is not None else "first")
            self._dev = jnp.asarray(cache.mats.astype(np.float32))
            return
        if dirty.size:
            tel.inc("cache_device_scatters")
            d_pad = _pow2_pad_len(dirty.size)
            idx = np.concatenate(
                [dirty, np.full(d_pad - dirty.size, dirty[0],
                                dtype=dirty.dtype)])
            block = cache.mats[:, idx, :].astype(np.float32)
            self._dev = _scatter_node_cols(self._dev, jnp.asarray(idx),
                                           jnp.asarray(block))
        if carbon_moved and self.carbon_signal is not None:
            tel.inc("cache_device_carbon_updates")
            col = cache.mats[:, :, -1].astype(np.float32)
            self._dev = _set_carbon_col(self._dev, jnp.asarray(col))

    def _weight_grid(self, schemes) -> np.ndarray:
        """Resolve ``schemes`` — a sequence of scheme names or an (S, C)
        array of weight vectors — into a validated (S, C) float64 grid
        matching this scheduler's criteria count. Name rows go through
        :func:`weights_for` (so the paper schemes stay bitwise identical to
        the scalar path); raw vectors must pass
        :func:`repro.core.weighting.validate_weights`, and 5-weight rows
        are padded with a zero carbon weight when a signal is attached —
        the same inert extension the named schemes get."""
        carbon = self.carbon_signal is not None
        seq = list(schemes) if not isinstance(schemes, np.ndarray) else None
        if seq is not None and seq and all(isinstance(s, str) for s in seq):
            for s in seq:
                _check_carbon_scheme(s, self.carbon_signal)
            return scheme_grid(tuple(seq), carbon=carbon)
        ws = validate_weights(np.atleast_2d(np.asarray(schemes,
                                                      dtype=np.float64)),
                              name="schemes")
        c = len(self._benefit)
        if ws.shape[-1] == 5 and c == 6:
            ws = np.concatenate([ws, np.zeros((ws.shape[0], 1))], axis=-1)
        if ws.shape[-1] != c:
            raise ValueError(
                f"scheme grid has {ws.shape[-1]} weights but this "
                f"scheduler scores {c} criteria "
                f"({'with' if carbon else 'without'} a carbon signal)")
        return ws

    def score_queue_grid(self, pods: Sequence[Pod], nodes, schemes,
                         now: float = 0.0, exclude=None) -> np.ndarray:
        """(S, P, N) closeness tensor: the whole queue scored under every
        weighting scheme in ONE engine dispatch (the Pareto-sweep path —
        see ``repro.core.pareto``). ``schemes`` is a list of scheme names
        or an (S, C) weight grid (:meth:`_weight_grid`); row ``s`` equals
        what :meth:`score_queue` returns with ``ws[s]`` as the scheme.
        ``now`` / ``exclude`` behave exactly as in :meth:`score_queue`;
        the feasibility mask is scheme-independent and shared.

        When ``nodes`` is the attached :class:`FleetState` this takes the
        incremental path — dirty-column sync plus (jax) one fused
        gather+grid-closeness dispatch against the device-resident kind
        tensor, with no re-upload per scheme."""
        table = _as_table(nodes)
        ws = self._weight_grid(schemes)
        if self._cache is not None and table is self._cache.fleet:
            telemetry.active().inc("scheduler_score_grid",
                                   path="incremental")
            return self._score_grid_incremental(pods, table, ws, now,
                                                exclude)
        telemetry.active().inc("scheduler_score_grid", path="rebuild")
        inten = (self.carbon_signal.intensities(table.region, now)
                 if self.carbon_signal is not None else None)
        mats = decision_matrix_batch(pods, table, carbon_intensity=inten)
        valid = table.fits(np.asarray([p.cpu for p in pods])[:, None],
                           np.asarray([p.mem for p in pods])[:, None])
        if exclude is not None:
            valid = valid & ~np.asarray(exclude, dtype=bool)
        if self.backend == "numpy":
            return topsis.closeness_grid_np(mats, ws, self._benefit, valid)
        if self.backend == "jax":
            cc = topsis.closeness_grid(mats, ws, self._benefit, valid)
            return np.asarray(cc)
        if self.backend == "pallas":
            from repro.kernels import ops
            return np.asarray(ops.topsis_closeness_grid(
                mats, ws, self._benefit, valid=valid))
        raise ValueError(f"unknown backend {self.backend!r}; "
                         f"choose from {BACKENDS}")

    def _score_grid_incremental(self, pods: Sequence[Pod],
                                fleet: FleetState, ws: np.ndarray,
                                now: float, exclude) -> np.ndarray:
        """Grid round over the attached fleet: one dirty-column sync, then
        the per-backend (S, P, N) scoring — numpy loops scheme x pod over
        the zero-copy cache views (the reference), jax fuses gather + grid
        closeness into one dispatch on the device mirror, pallas streams
        the (P, N, C) gather through the weight-grid kernel."""
        cache = self._cache
        kind_idx, dirty, carbon_moved, grew = cache.sync(pods, now)
        valid = fleet.fits(np.asarray([p.cpu for p in pods])[:, None],
                           np.asarray([p.mem for p in pods])[:, None])
        if exclude is not None:
            valid = valid & ~np.asarray(exclude, dtype=bool)
        if self.backend == "numpy":
            return np.stack([
                np.stack([
                    np.asarray(topsis.closeness_np(cache.mats[k], w,
                                                   self._benefit,
                                                   valid[i]).closeness)
                    for i, k in enumerate(kind_idx)])
                for w in ws])
        if self.backend == "jax":
            import jax.numpy as jnp
            _jit_helpers()
            self._sync_device(cache, dirty, carbon_moved, grew)
            p = len(pods)
            p_pad = _pow2_pad_len(p)
            if p_pad != p:
                pad = p_pad - p
                kind_idx = np.concatenate(
                    [kind_idx, np.zeros(pad, dtype=kind_idx.dtype)])
                valid = np.concatenate(
                    [valid, np.zeros((pad, valid.shape[-1]), bool)])
            cc = _closeness_grid_from_kinds(
                self._dev, jnp.asarray(kind_idx), jnp.asarray(ws),
                jnp.asarray(self._benefit), jnp.asarray(valid))
            telemetry.active().inc("cache_fused_dispatches", backend="jax")
            return np.asarray(cc[:, :p])
        if self.backend == "pallas":
            from repro.kernels import ops
            return np.asarray(ops.topsis_closeness_grid(
                cache.mats[kind_idx], ws, self._benefit, valid=valid))
        raise ValueError(f"unknown backend {self.backend!r}; "
                         f"choose from {BACKENDS}")

    def select_many_grid(self, pods: Sequence[Pod], nodes, schemes,
                         now: float = 0.0, exclude=None):
        """What-if placement of one queue under every scheme: returns
        ``(assignments, diagnostics)`` where ``assignments[s][i]`` is the
        node index pods[i] would take under scheme ``s`` (or None). One
        fused :meth:`score_queue_grid` dispatch scores all schemes; each
        scheme's greedy capacity-ledger walk then starts from the SAME
        fresh snapshot (``_greedy_assign``) — the per-scheme placements are
        independent hypotheticals, identical to running
        :meth:`select_many` once per scheme, which is what the frontier
        layer compares. Input nodes are never mutated."""
        with telemetry.active().span("scheduler_grid",
                                     scheduler=self.name,
                                     backend=self.backend) as sp:
            table = _as_table(nodes)
            n_s = len(schemes)
            if not len(pods):
                return ([[] for _ in range(n_s)],
                        {"closeness": np.zeros((n_s, 0, len(table))),
                         "scheduling_time_s": 0.0, "per_scheme_time_s": 0.0})
            cc = self.score_queue_grid(pods, table, schemes, now=now,
                                       exclude=exclude)
            assignments = [_greedy_assign(cc[s], pods, table)
                           for s in range(cc.shape[0])]
        dt = sp.duration_s
        return assignments, {"closeness": cc, "scheduling_time_s": dt,
                             "per_scheme_time_s": dt / cc.shape[0]}

    def _explain_batch(self, pods, table, now, exclude, assignments) -> None:
        """Per-pod attribution for one batch round (numpy path): rebuild
        each pod's (N, C) matrix and validity exactly as ``score_queue``
        saw them and decompose winner vs runner-up. ``node`` records the
        greedy ledger's actual commit — it can differ from the scoring
        ``winner`` when an earlier pod took the capacity."""
        names = [c.name for c in self.criteria]
        if self._cache is not None and table is self._cache.fleet:
            # fleet untouched since the scoring sync -> dirty is empty and
            # these are the same cache rows score_queue just read
            kind_idx, _, _, _ = self._cache.sync(pods, now)
            mats = [self._cache.mats[k] for k in kind_idx]
        else:
            inten = (self.carbon_signal.intensities(table.region, now)
                     if self.carbon_signal is not None else None)
            mats = decision_matrix_batch(pods, table, carbon_intensity=inten)
        valid = table.fits(np.asarray([p.cpu for p in pods])[:, None],
                           np.asarray([p.mem for p in pods])[:, None])
        if exclude is not None:
            valid = valid & ~np.asarray(exclude, dtype=bool)
        w = self.weights(table)
        for i, (pod, idx) in enumerate(zip(pods, assignments)):
            exp = topsis.explain_np(mats[i], w, self._benefit, valid[i],
                                    criteria_names=names)
            exp.update(pod=pod.uid, t=now,
                       node=table.names[idx] if idx is not None else None,
                       runner_up_node=(table.names[exp["runner_up"]]
                                       if exp["runner_up"] is not None
                                       else None))
            self.explanations.append(exp)

    def select_many(self, pods: Sequence[Pod], nodes, now: float = 0.0,
                    blocked: "Sequence[int | None] | None" = None,
                    exclude=None, explain: bool = False):
        """Place a queue: returns (assignments, diagnostics) where
        ``assignments[i]`` is the node index for ``pods[i]`` or None.
        ``blocked[i]`` optionally names one node index ``pods[i]`` must not
        take this pass (a node it was just preempted off) — skipped inside
        the greedy ledger walk, so a blocked top choice falls through to
        the next-ranked node without phantom capacity charges. ``exclude``
        ((N,) or (P, N) bool) hard-masks nodes out of the scoring validity
        instead (sleeping / deadline-late nodes, see :meth:`score_queue`).
        ``explain=True`` (numpy backend only, like
        :meth:`GreenPodScheduler.select`) appends a per-criterion
        attribution per placed pod to ``self.explanations``."""
        explain = explain or self.explain
        if explain and self.backend != "numpy":
            raise ValueError(
                f"explain=True needs backend='numpy', not "
                f"{self.backend!r}: only the numpy path exposes the "
                f"weighted separation terms the attribution decomposes")
        with telemetry.active().span("scheduler_batch",
                                     scheduler=self.name,
                                     backend=self.backend) as sp:
            table = _as_table(nodes)
            if not len(pods):
                return [], {"closeness": np.zeros((0, len(table))),
                            "scheduling_time_s": 0.0, "per_pod_time_s": 0.0}
            cc = self.score_queue(pods, table, now=now, exclude=exclude)
            assignments = _greedy_assign(cc, pods, table, blocked=blocked)
        dt = sp.duration_s
        per_pod = dt / len(pods)
        if explain:
            self._explain_batch(pods, table, now, exclude, assignments)
        for pod, idx in zip(pods, assignments):
            self.decision_log.append(
                {"pod": pod.uid,
                 "node": table.names[idx] if idx is not None else None,
                 "time_s": per_pod})
        return assignments, {"closeness": cc, "scheduling_time_s": dt,
                             "per_pod_time_s": per_pod}


class DefaultK8sScheduler:
    """Upstream kube-scheduler default scoring (the paper's baseline).

    LeastRequestedPriority: ((capacity - requested) / capacity) * 100,
    averaged over cpu and memory.
    BalancedResourceAllocation: 100 - |cpu_fraction - mem_fraction| * 100.
    Total = mean of the two plugins (equal default plugin weights).
    """

    name = "default"

    def __init__(self):
        self.decision_log: list[dict] = []

    def select(self, pod: Pod, nodes, now: float = 0.0, exclude=None):
        """Vectorized over ``NodeTable`` columns (``nodes`` may be a Node
        list or a prebuilt table): one broadcast pass scores the whole
        fleet, infeasible nodes score -1. Identical plugin arithmetic to
        the upstream per-node loop; ties resolve to the lowest node index
        (the loop's running-max-with-epsilon tie-break, which only diverges
        for score gaps below 1e-12 — see tests/test_scheduler.py pinning).
        ``now`` is accepted for engine-call symmetry and ignored — the
        baseline is carbon-blind. ``exclude`` masks engine-forbidden nodes
        (sleeping capacity) exactly like capacity infeasibility."""
        with telemetry.active().span("scheduler_decision",
                                     scheduler=self.name,
                                     backend="numpy") as sp:
            table = _as_table(nodes)
            fits = table.fits(pod.cpu, pod.mem)
            if exclude is not None:
                fits = fits & ~np.asarray(exclude, dtype=bool)
            if not fits.any():
                return None, {"reason": "unschedulable"}
            cpu_frac = (table.reserved_cpu + table.used_cpu
                        + pod.cpu) / table.vcpus
            mem_frac = (table.reserved_mem + table.used_mem
                        + pod.mem) / table.mem_gb
            least = 100.0 * ((1.0 - cpu_frac) + (1.0 - mem_frac)) / 2.0
            balanced = 100.0 * (1.0 - np.abs(cpu_frac - mem_frac))
            scores = np.where(fits, (least + balanced) / 2.0, -1.0)
            best = int(np.argmax(scores))
        dt = sp.duration_s
        self.decision_log.append({"pod": pod.uid, "node": table.names[best],
                                  "time_s": dt})
        return best, {"scores": scores, "scheduling_time_s": dt}

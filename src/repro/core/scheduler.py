"""Schedulers: GreenPod (TOPSIS), its fleet-scale batched variant, and the
default-K8s baseline.

Per-pod schedulers expose ``select(pod, nodes) -> (node_index | None,
diagnostics)`` over a list of ``repro.cluster.node.Node`` (or a prebuilt
``NodeTable``). ``BatchScheduler.select_many(pods, nodes)`` scores a whole
queue of pods against one fleet snapshot in a single call — the 1000+-node
path. The baseline reimplements the upstream kube-scheduler scoring
pipeline the paper compares against: filter (PodFitsResources) → score
(LeastRequestedPriority + BalancedResourceAllocation) → bind to max score.

Backends (scoring engines, identical semantics — tests assert equivalence):

  numpy   — ``topsis.closeness_np``; lowest latency for single decisions
            (no device dispatch) and the semantic reference.
  jax     — jitted jnp engine; ``BatchScheduler`` vmaps it over the pod
            queue (``topsis.batched_closeness``) for throughput.
  pallas  — the tiled TPU kernel via ``repro.kernels.ops`` (interpret mode
            on CPU, Mosaic on TPU); for fleets large enough to tile.
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from repro.core import topsis
from repro.core.carbon import CarbonSignal
from repro.core.criteria import benefit_mask, greenpod_criteria
from repro.core.energy import (predicted_power_w_np,
                               predicted_task_energy_joules,
                               predicted_task_energy_joules_np)
from repro.core.weighting import CARBON_SCHEMES, adaptive_weights, weights_for
from repro.cluster.node import Node, NodeTable
from repro.cluster.workload import Pod

_BENEFIT = benefit_mask()

BACKENDS = ("numpy", "jax", "pallas")


def predict_exec_time(pod: Pod, node: Node) -> float:
    """Energy-profiling module prediction: runtime scales inversely with the
    node class's per-core speed (requests are guaranteed, no oversubscription
    past the filter)."""
    return pod.workload.base_time_s / node.speed


def predict_energy(pod: Pod, node: Node) -> float:
    awake = node.used_cpu > 1e-9
    return predicted_task_energy_joules(
        node.node_class, predict_exec_time(pod, node), pod.cpu, awake)


def _as_table(nodes) -> NodeTable:
    return nodes if isinstance(nodes, NodeTable) else NodeTable.from_nodes(nodes)


def decision_matrix_table(cpu, mem, base_time_s, table: NodeTable,
                          carbon_intensity=None) -> np.ndarray:
    """(..., N, C) GreenPod decision matrix by broadcasting over the fleet's
    column arrays (criteria.CRITERIA_NAMES order) — no per-node Python loop.

    ``cpu`` / ``mem`` / ``base_time_s`` are scalars for one pod (→ (N, C))
    or ``(P, 1)`` arrays for a queue (→ (P, N, C)). C is 5, or 6 when
    ``carbon_intensity`` (the (N,) gCO2/kWh column for the fleet's regions
    at decision time) is given — the sixth column is the placement's
    emission rate: power draw (dynamic for the request, plus the idle power
    a sleeping node would newly wake) x regional intensity."""
    exec_t = base_time_s / table.speed
    energy = predicted_task_energy_joules_np(
        table.dyn_power_per_vcpu, table.idle_power, exec_t, cpu, table.awake)
    cpu_after = (table.reserved_cpu + table.used_cpu + cpu) / table.vcpus
    mem_after = (table.reserved_mem + table.used_mem + mem) / table.mem_gb
    rows = [
        np.broadcast_to(exec_t, cpu_after.shape),
        energy,
        np.maximum(1.0 - cpu_after, 0.0),    # core availability
        np.maximum(1.0 - mem_after, 0.0),    # memory availability
        1.0 - np.abs(cpu_after - mem_after),
    ]
    if carbon_intensity is not None:
        power_w = predicted_power_w_np(table.dyn_power_per_vcpu,
                                       table.idle_power, cpu, table.awake)
        rows.append(power_w * np.asarray(carbon_intensity, dtype=np.float64))
    return np.stack(rows, axis=-1).astype(np.float64, copy=False)


def decision_matrix(pod: Pod, nodes, carbon_intensity=None) -> np.ndarray:
    """(N, C) decision matrix for one pod; ``nodes`` is a Node list or a
    NodeTable."""
    table = _as_table(nodes)
    return decision_matrix_table(pod.cpu, pod.mem, pod.workload.base_time_s,
                                 table, carbon_intensity=carbon_intensity)


def decision_matrix_batch(pods: Sequence[Pod], nodes,
                          carbon_intensity=None) -> np.ndarray:
    """(P, N, C) decision tensor for a queue of pods against one fleet
    snapshot (every pod scored on identical cluster state)."""
    table = _as_table(nodes)
    col = lambda xs: np.asarray(xs, dtype=np.float64)[:, None]
    return decision_matrix_table(col([p.cpu for p in pods]),
                                 col([p.mem for p in pods]),
                                 col([p.workload.base_time_s for p in pods]),
                                 table, carbon_intensity=carbon_intensity)


def _score(mat: np.ndarray, weights: np.ndarray, valid: np.ndarray,
           backend: str, benefit: np.ndarray = _BENEFIT) -> np.ndarray:
    """(N,) closeness for one decision matrix on the given backend
    (invalid rows are -inf)."""
    if backend == "numpy":
        return np.asarray(topsis.closeness_np(mat, weights, benefit,
                                              valid).closeness)
    if backend == "jax":
        return np.asarray(topsis.closeness(mat, weights, benefit,
                                           valid).closeness)
    if backend == "pallas":
        from repro.kernels import ops
        return np.asarray(ops.topsis_closeness(mat, weights, benefit,
                                               valid=valid))
    raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")


def _check_carbon_scheme(scheme: str, carbon_signal) -> None:
    if scheme in CARBON_SCHEMES and carbon_signal is None:
        raise ValueError(
            f"scheme {scheme!r} weights the carbon-rate criterion; "
            f"construct the scheduler with a carbon_signal "
            f"(repro.core.carbon.CarbonSignal) to use it")


class GreenPodScheduler:
    """TOPSIS-based multi-criteria scheduler (paper §III).

    With a ``carbon_signal`` attached the decision matrix gains the sixth
    carbon-rate column (node power x regional grid intensity at ``now``) and
    weight vectors are the 6-criteria form — paper schemes carry a zero
    carbon weight, so their rankings are bitwise unchanged."""

    name = "topsis"

    def __init__(self, scheme: str = "energy_centric", adaptive: bool = False,
                 backend: str = "numpy",
                 carbon_signal: CarbonSignal | None = None):
        _check_carbon_scheme(scheme, carbon_signal)
        self.scheme = scheme
        self.adaptive = adaptive
        self.backend = backend
        self.carbon_signal = carbon_signal
        self.criteria = greenpod_criteria(carbon=carbon_signal is not None)
        self._benefit = benefit_mask(self.criteria)
        self.decision_log: list[dict] = []

    def weights(self, nodes) -> np.ndarray:
        carbon = self.carbon_signal is not None
        if not self.adaptive:
            return weights_for(self.scheme, carbon=carbon)
        util = float(np.mean(_as_table(nodes).cpu_util))
        return adaptive_weights(self.scheme, util, carbon=carbon)

    def select(self, pod: Pod, nodes, now: float = 0.0, exclude=None):
        """Best node for one pod; ``exclude`` optionally masks nodes the
        engine forbids this round (ASLEEP nodes, or WAKING nodes whose
        ready time would start a deferrable pod past its deadline) — they
        are treated exactly like capacity-infeasible nodes."""
        t0 = time.perf_counter()
        table = _as_table(nodes)
        valid = table.fits(pod.cpu, pod.mem)
        if exclude is not None:
            valid = valid & ~np.asarray(exclude, dtype=bool)
        if not valid.any():
            return None, {"reason": "unschedulable"}
        inten = (self.carbon_signal.intensities(table.region, now)
                 if self.carbon_signal is not None else None)
        mat = decision_matrix_table(pod.cpu, pod.mem,
                                    pod.workload.base_time_s, table,
                                    carbon_intensity=inten)
        cc = _score(mat, self.weights(table), valid, self.backend,
                    benefit=self._benefit)
        idx = int(np.argmax(cc))   # first max — same tie-break as a stable sort
        dt = time.perf_counter() - t0
        diag = {"closeness": cc, "scheduling_time_s": dt, "matrix": mat}
        self.decision_log.append({"pod": pod.uid, "node": table.names[idx],
                                  "time_s": dt})
        return idx, diag


class BatchScheduler:
    """Fleet-scale batched TOPSIS: one scoring pass per arrival burst.

    ``select_many`` builds the (P, N, 5) decision tensor by broadcasting,
    scores every pod against the same fleet snapshot on the configured
    backend, then commits placements greedily in queue order against a
    capacity ledger (each pod takes its best-ranked node that still fits).
    Snapshot scoring is the throughput trade-off vs. the per-pod scheduler's
    rescore-after-every-bind: one engine call amortizes dispatch over the
    whole queue, which is what wins at 1000+ nodes (see
    benchmarks/scheduling_time.py). Input nodes are never mutated — the
    caller binds from the returned assignments.
    """

    name = "topsis-batch"

    def __init__(self, scheme: str = "energy_centric", adaptive: bool = False,
                 backend: str = "jax",
                 carbon_signal: CarbonSignal | None = None):
        _check_carbon_scheme(scheme, carbon_signal)
        self.scheme = scheme
        self.adaptive = adaptive
        self.backend = backend
        self.carbon_signal = carbon_signal
        self.criteria = greenpod_criteria(carbon=carbon_signal is not None)
        self._benefit = benefit_mask(self.criteria)
        self.decision_log: list[dict] = []

    def weights(self, table: NodeTable) -> np.ndarray:
        carbon = self.carbon_signal is not None
        if not self.adaptive:
            return weights_for(self.scheme, carbon=carbon)
        return adaptive_weights(self.scheme, float(np.mean(table.cpu_util)),
                                carbon=carbon)

    def score_queue(self, pods: Sequence[Pod], nodes,
                    now: float = 0.0, exclude=None) -> np.ndarray:
        """(P, N) closeness matrix for the whole queue on one snapshot
        (infeasible nodes are -inf per pod). ``now`` is the decision time
        the carbon column is evaluated at (ignored without a signal).
        ``exclude`` — (N,) or (P, N) bool — masks nodes the engine forbids
        (sleeping nodes; per-pod deadline-late WAKING nodes), folded into
        the validity mask every backend already honors."""
        table = _as_table(nodes)
        inten = (self.carbon_signal.intensities(table.region, now)
                 if self.carbon_signal is not None else None)
        mats = decision_matrix_batch(pods, table, carbon_intensity=inten)
        valid = table.fits(np.asarray([p.cpu for p in pods])[:, None],
                           np.asarray([p.mem for p in pods])[:, None])
        if exclude is not None:
            valid = valid & ~np.asarray(exclude, dtype=bool)
        w = self.weights(table)
        ws = np.broadcast_to(w, (len(pods), w.shape[0]))
        if self.backend == "numpy":
            return topsis.batched_closeness_np(mats, ws, self._benefit, valid)
        if self.backend == "jax":
            import jax.numpy as jnp
            # jit caches by shape: pad the pod axis to the next power of two
            # so shrinking retry bursts (P, P-1, ...) hit the cache instead
            # of recompiling per queue length. Padding rows are all-invalid,
            # so they score -inf and are sliced off.
            p = len(pods)
            p_pad = 1 << max(p - 1, 1).bit_length()
            if p_pad != p:
                pad = p_pad - p
                mats = np.concatenate(
                    [mats, np.zeros((pad,) + mats.shape[1:])])
                ws = np.concatenate([ws, np.ones((pad, ws.shape[-1]))])
                valid = np.concatenate(
                    [valid, np.zeros((pad, valid.shape[-1]), bool)])
            cc = topsis.batched_closeness_cc(
                jnp.asarray(mats), jnp.asarray(ws),
                jnp.asarray(self._benefit), jnp.asarray(valid))
            return np.asarray(cc[:p])
        if self.backend == "pallas":
            from repro.kernels import ops
            return np.asarray(ops.topsis_closeness_batched(
                mats, ws, self._benefit, valid=valid))
        raise ValueError(f"unknown backend {self.backend!r}; "
                         f"choose from {BACKENDS}")

    def select_many(self, pods: Sequence[Pod], nodes, now: float = 0.0,
                    blocked: "Sequence[int | None] | None" = None,
                    exclude=None):
        """Place a queue: returns (assignments, diagnostics) where
        ``assignments[i]`` is the node index for ``pods[i]`` or None.
        ``blocked[i]`` optionally names one node index ``pods[i]`` must not
        take this pass (a node it was just preempted off) — skipped inside
        the greedy ledger walk, so a blocked top choice falls through to
        the next-ranked node without phantom capacity charges. ``exclude``
        ((N,) or (P, N) bool) hard-masks nodes out of the scoring validity
        instead (sleeping / deadline-late nodes, see :meth:`score_queue`)."""
        t0 = time.perf_counter()
        table = _as_table(nodes)
        if not len(pods):
            return [], {"closeness": np.zeros((0, len(table))),
                        "scheduling_time_s": 0.0, "per_pod_time_s": 0.0}
        cc = self.score_queue(pods, table, now=now, exclude=exclude)
        order = np.argsort(-cc, kind="stable", axis=-1)
        free_cpu = table.free_cpu.copy()
        free_mem = table.free_mem.copy()
        assignments: list[int | None] = []
        for i, pod in enumerate(pods):
            forbid = blocked[i] if blocked is not None else None
            chosen = None
            for j in order[i]:
                if np.isneginf(cc[i, j]):
                    break               # rest of the ranking is infeasible
                if forbid is not None and int(j) == forbid:
                    continue
                if free_cpu[j] >= pod.cpu - 1e-9 \
                        and free_mem[j] >= pod.mem - 1e-9:
                    chosen = int(j)
                    free_cpu[j] -= pod.cpu
                    free_mem[j] -= pod.mem
                    break
            assignments.append(chosen)
        dt = time.perf_counter() - t0
        per_pod = dt / len(pods)
        for pod, idx in zip(pods, assignments):
            self.decision_log.append(
                {"pod": pod.uid,
                 "node": table.names[idx] if idx is not None else None,
                 "time_s": per_pod})
        return assignments, {"closeness": cc, "scheduling_time_s": dt,
                             "per_pod_time_s": per_pod}


class DefaultK8sScheduler:
    """Upstream kube-scheduler default scoring (the paper's baseline).

    LeastRequestedPriority: ((capacity - requested) / capacity) * 100,
    averaged over cpu and memory.
    BalancedResourceAllocation: 100 - |cpu_fraction - mem_fraction| * 100.
    Total = mean of the two plugins (equal default plugin weights).
    """

    name = "default"

    def __init__(self):
        self.decision_log: list[dict] = []

    def select(self, pod: Pod, nodes, now: float = 0.0, exclude=None):
        """Vectorized over ``NodeTable`` columns (``nodes`` may be a Node
        list or a prebuilt table): one broadcast pass scores the whole
        fleet, infeasible nodes score -1. Identical plugin arithmetic to
        the upstream per-node loop; ties resolve to the lowest node index
        (the loop's running-max-with-epsilon tie-break, which only diverges
        for score gaps below 1e-12 — see tests/test_scheduler.py pinning).
        ``now`` is accepted for engine-call symmetry and ignored — the
        baseline is carbon-blind. ``exclude`` masks engine-forbidden nodes
        (sleeping capacity) exactly like capacity infeasibility."""
        t0 = time.perf_counter()
        table = _as_table(nodes)
        fits = table.fits(pod.cpu, pod.mem)
        if exclude is not None:
            fits = fits & ~np.asarray(exclude, dtype=bool)
        if not fits.any():
            return None, {"reason": "unschedulable"}
        cpu_frac = (table.reserved_cpu + table.used_cpu + pod.cpu) / table.vcpus
        mem_frac = (table.reserved_mem + table.used_mem + pod.mem) / table.mem_gb
        least = 100.0 * ((1.0 - cpu_frac) + (1.0 - mem_frac)) / 2.0
        balanced = 100.0 * (1.0 - np.abs(cpu_frac - mem_frac))
        scores = np.where(fits, (least + balanced) / 2.0, -1.0)
        best = int(np.argmax(scores))
        dt = time.perf_counter() - t0
        self.decision_log.append({"pod": pod.uid, "node": table.names[best],
                                  "time_s": dt})
        return best, {"scores": scores, "scheduling_time_s": dt}

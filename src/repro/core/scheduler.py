"""Schedulers: GreenPod (TOPSIS) and the default-K8s baseline.

Both expose ``select(pod, nodes) -> (node_index | None, diagnostics)`` over a
list of ``repro.cluster.node.Node``. The baseline reimplements the upstream
kube-scheduler scoring pipeline the paper compares against:
filter (PodFitsResources) → score (LeastRequestedPriority +
BalancedResourceAllocation) → bind to max score.
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from repro.core import topsis
from repro.core.criteria import benefit_mask
from repro.core.energy import predicted_task_energy_joules
from repro.core.weighting import adaptive_weights, weights_for
from repro.cluster.node import Node
from repro.cluster.workload import Pod

_BENEFIT = benefit_mask()


def predict_exec_time(pod: Pod, node: Node) -> float:
    """Energy-profiling module prediction: runtime scales inversely with the
    node class's per-core speed (requests are guaranteed, no oversubscription
    past the filter)."""
    return pod.workload.base_time_s / node.speed


def predict_energy(pod: Pod, node: Node) -> float:
    awake = node.used_cpu > 1e-9
    return predicted_task_energy_joules(
        node.node_class, predict_exec_time(pod, node), pod.cpu, awake)


def decision_matrix(pod: Pod, nodes: Sequence[Node]) -> np.ndarray:
    """(N, 5) GreenPod decision matrix (criteria.CRITERIA_NAMES order)."""
    rows = []
    for n in nodes:
        cpu_after = (n.reserved_cpu + n.used_cpu + pod.cpu) / n.vcpus
        mem_after = (n.reserved_mem + n.used_mem + pod.mem) / n.mem_gb
        rows.append([
            predict_exec_time(pod, n),
            predict_energy(pod, n),
            max(1.0 - cpu_after, 0.0),   # core availability (fraction free)
            max(1.0 - mem_after, 0.0),   # memory availability (fraction free)
            1.0 - abs(cpu_after - mem_after),
        ])
    return np.asarray(rows, dtype=np.float64)


class GreenPodScheduler:
    """TOPSIS-based multi-criteria scheduler (paper §III)."""

    name = "topsis"

    def __init__(self, scheme: str = "energy_centric", adaptive: bool = False,
                 backend: str = "numpy"):
        self.scheme = scheme
        self.adaptive = adaptive
        # "numpy" for low-latency single decisions on host; "jax" exercises
        # the jittable path (identical semantics, used for fleet-scale
        # batched scoring and on-TPU scheduling).
        self.backend = backend
        self.decision_log: list[dict] = []

    def weights(self, nodes: Sequence[Node]) -> np.ndarray:
        if not self.adaptive:
            return weights_for(self.scheme)
        util = float(np.mean([n.cpu_util for n in nodes]))
        return adaptive_weights(self.scheme, util)

    def select(self, pod: Pod, nodes: Sequence[Node]):
        t0 = time.perf_counter()
        valid = np.array([n.fits(pod.cpu, pod.mem) for n in nodes])
        if not valid.any():
            return None, {"reason": "unschedulable"}
        mat = decision_matrix(pod, nodes)
        fn = topsis.closeness_np if self.backend == "numpy" else topsis.closeness
        res = fn(mat, self.weights(nodes), _BENEFIT, valid)
        idx = int(res.ranking[0])
        dt = time.perf_counter() - t0
        diag = {"closeness": np.asarray(res.closeness),
                "scheduling_time_s": dt, "matrix": mat}
        self.decision_log.append({"pod": pod.uid, "node": nodes[idx].name,
                                  "time_s": dt})
        return idx, diag


class DefaultK8sScheduler:
    """Upstream kube-scheduler default scoring (the paper's baseline).

    LeastRequestedPriority: ((capacity - requested) / capacity) * 100,
    averaged over cpu and memory.
    BalancedResourceAllocation: 100 - |cpu_fraction - mem_fraction| * 100.
    Total = mean of the two plugins (equal default plugin weights).
    """

    name = "default"

    def __init__(self):
        self.decision_log: list[dict] = []

    def select(self, pod: Pod, nodes: Sequence[Node]):
        t0 = time.perf_counter()
        best, best_score = None, -1.0
        scores = []
        for i, n in enumerate(nodes):
            if not n.fits(pod.cpu, pod.mem):
                scores.append(-1.0)
                continue
            cpu_frac = (n.reserved_cpu + n.used_cpu + pod.cpu) / n.vcpus
            mem_frac = (n.reserved_mem + n.used_mem + pod.mem) / n.mem_gb
            least = 100.0 * ((1.0 - cpu_frac) + (1.0 - mem_frac)) / 2.0
            balanced = 100.0 * (1.0 - abs(cpu_frac - mem_frac))
            score = (least + balanced) / 2.0
            scores.append(score)
            if score > best_score + 1e-12:
                best, best_score = i, score
        dt = time.perf_counter() - t0
        if best is None:
            return None, {"reason": "unschedulable"}
        self.decision_log.append({"pod": pod.uid, "node": nodes[best].name,
                                  "time_s": dt})
        return best, {"scores": np.asarray(scores), "scheduling_time_s": dt}

"""Energy models.

1. ``blade_power`` — the blade-server power model of Dayarathna et al. [32],
   the exact model the paper uses for its real-world extrapolation (§V.E):

     P = 14.45 + 0.236*u_cpu - 4.47e-8*u_mem + 0.00281*u_disk + 3.1e-8*u_net  [W]

   with u_cpu in percent, u_mem in memory accesses/s, u_disk in IO ops/s,
   u_net in network ops/s.

2. Per-node-class *dynamic* energy profiles for the cluster simulator
   (DESIGN.md §7 calibration): each node class has a speed factor and a
   dynamic power per allocated vCPU. Energy attributed to a task is
   dynamic power x runtime, matching the paper's 'energy consumption from
   scheduling decisions' metric (Table IV).

3. ``chip_energy`` — TPU-side model for the beyond-paper fleet scheduler:
   energy = step_time x chips x (idle + (TDP-idle) x mfu-ish utilization).

4. ``PowerTimeline`` — per-node power-state timeline for the event-driven
   simulator: every committed placement adds a task segment (node, scheduler,
   start, runtime, dynamic power); idle attribution is the per-node union of
   a scheduler's busy intervals (same decomposition the legacy post-hoc
   ``_union_length`` accounting produced), and the same segments yield
   piecewise-constant power / cumulative energy *series* over time, which a
   scalar union cannot express.
"""
from __future__ import annotations

import dataclasses


def blade_power(u_cpu_pct: float, u_mem_acc_per_s: float = 0.0,
                u_disk_iops: float = 0.0, u_net_ops: float = 0.0) -> float:
    """Dayarathna et al. [32] blade server power (Watts)."""
    return (14.45 + 0.236 * u_cpu_pct - 4.47e-8 * u_mem_acc_per_s
            + 0.00281 * u_disk_iops + 3.1e-8 * u_net_ops)


def paper_job_energy_kwh(runtime_min: float = 34.0, pue: float = 1.45,
                         u_cpu_pct: float = 60.0,
                         u_mem_acc_per_s: float = 8e6,
                         u_disk_iops: float = 350.0,
                         u_net_ops: float = 3e6) -> float:
    """Average job energy exactly as computed in paper §V.E (≈0.024 kWh)."""
    p_watts = blade_power(u_cpu_pct, u_mem_acc_per_s, u_disk_iops, u_net_ops)
    return p_watts * pue * (runtime_min / 60.0) / 1000.0


# --- Cluster-simulator node energy profiles (calibrated, DESIGN.md §7) -----
# speed: relative per-core throughput; dyn_power_per_vcpu: Watts drawn per
# allocated vCPU while a task runs; idle_power: Watts the node draws whenever
# a scheduler's pods keep it awake (static/uncore power). Class A (e2-medium)
# is slow but frugal, class C (n2-standard-4) fast but power-hungry — the
# heterogeneity axis the paper's §V.D allocation analysis relies on.
# Consolidating onto one frugal node avoids paying several nodes' idle power,
# which is the physical mechanism behind the paper's 30-39% energy savings.
# Values fit to paper Table VI by scripts/calibrate.py (err metric in
# scripts/calibrated_params.json); see EXPERIMENTS.md §Repro for the match.
NODE_ENERGY_PROFILES: dict[str, dict[str, float]] = {
    "A": {"speed": 0.7500, "dyn_power_per_vcpu": 6.0000, "idle_power": 6.2321},
    "B": {"speed": 1.1000, "dyn_power_per_vcpu": 10.0000, "idle_power": 9.5953},
    "C": {"speed": 1.3417, "dyn_power_per_vcpu": 27.0570, "idle_power": 14.0000},
    "default": {"speed": 0.7000, "dyn_power_per_vcpu": 11.7709,
                "idle_power": 14.9153},
}


def task_energy_joules(node_class: str, runtime_s: float,
                       cpu_request: float) -> float:
    """Dynamic (CPU-proportional) energy of one task."""
    prof = NODE_ENERGY_PROFILES[node_class]
    return prof["dyn_power_per_vcpu"] * cpu_request * runtime_s


def predicted_task_energy_joules(node_class: str, runtime_s: float,
                                 cpu_request: float, node_awake: bool) -> float:
    """Energy-profiling-module prediction used in the decision matrix:
    dynamic energy plus — if the node is currently asleep — the idle power
    the placement would newly wake up for the task's duration. Marginal idle
    cost of an already-awake node is zero, which is what makes energy-centric
    TOPSIS consolidate (paper §V.D)."""
    e = task_energy_joules(node_class, runtime_s, cpu_request)
    if not node_awake:
        e += NODE_ENERGY_PROFILES[node_class]["idle_power"] * runtime_s
    return e


def predicted_task_energy_joules_np(dyn_power_per_vcpu, idle_power,
                                    runtime_s, cpu_request, awake):
    """Vectorized :func:`predicted_task_energy_joules` over node columns.

    All arguments broadcast (numpy arrays or scalars); ``awake`` is a bool
    mask. Same arithmetic and operand order as the scalar form, so the two
    agree bitwise on float64 inputs — the batched scheduler's decision
    matrix must rank identically to the per-pod path. (This is
    :func:`predicted_power_w_np` x runtime, kept in the legacy operand
    order for bitwise golden stability.)
    """
    import numpy as np
    e = dyn_power_per_vcpu * cpu_request * runtime_s
    return e + np.where(awake, 0.0, idle_power * runtime_s)


def predicted_power_w_np(dyn_power_per_vcpu, idle_power, cpu_request, awake):
    """Marginal power draw (W) of a placement, vectorized over node
    columns: dynamic power for the requested vCPUs plus — if the node is
    asleep — the idle power the placement newly wakes. The single source
    of the marginal-power rule; the carbon-rate criterion is this times
    grid intensity, and :func:`predicted_task_energy_joules_np` is this
    times runtime."""
    import numpy as np
    return dyn_power_per_vcpu * cpu_request + np.where(awake, 0.0,
                                                       idle_power)


# --- Per-node power-state timeline (event-driven simulator) -----------------
def merge_intervals(intervals: list[tuple[float, float]]
                    ) -> list[tuple[float, float]]:
    """Union of [start, end) intervals as a sorted list of disjoint
    intervals."""
    merged: list[tuple[float, float]] = []
    if not intervals:
        return merged
    ivs = sorted(intervals)
    cur_s, cur_e = ivs[0]
    for s, e in ivs[1:]:
        if s > cur_e:
            merged.append((cur_s, cur_e))
            cur_s, cur_e = s, e
        else:
            cur_e = max(cur_e, e)
    merged.append((cur_s, cur_e))
    return merged


def union_length(intervals: list[tuple[float, float]]) -> float:
    """Total length of the union of [start, end) intervals (same merge
    order and summation order as the legacy simulator ``_union_length``,
    so totals agree bitwise)."""
    return sum(e - s for s, e in merge_intervals(intervals))


@dataclasses.dataclass(frozen=True)
class StateInterval:
    """One node's stay in a non-task power state (elastic fleet subsystem,
    ``repro.core.elastic``): the node draws ``power_w`` on
    ``[start_s, end_s)`` while IDLE (awake, empty), ASLEEP (suspended
    residual), or WAKING (booting back up). Task-occupancy (ACTIVE) power is
    not recorded here — it stays attributed to schedulers via the busy-union
    idle accounting, so the two ledgers never double count."""

    node: str
    node_class: str
    state: str             # "idle" | "asleep" | "waking"
    start_s: float
    end_s: float
    power_w: float

    @property
    def energy_j(self) -> float:
        return self.power_w * (self.end_s - self.start_s)


@dataclasses.dataclass(frozen=True)
class WakeTransition:
    """One ASLEEP→awake transition's surge energy, posted as a lump at the
    wake-request instant ``t_s`` (the latency's baseline draw is a WAKING
    ``StateInterval``; this is the extra spin-up cost on top)."""

    node: str
    node_class: str
    t_s: float
    energy_j: float


@dataclasses.dataclass(frozen=True)
class PowerSegment:
    """One task's occupancy of one node: draws ``dyn_power_w`` on
    ``[start_s, start_s + runtime_s)`` and keeps the node awake (idle power
    attributed to ``scheduler``) for that interval."""

    node: str
    node_class: str
    scheduler: str
    start_s: float
    runtime_s: float
    dyn_power_w: float

    @property
    def end_s(self) -> float:
        return self.start_s + self.runtime_s

    @property
    def energy_j(self) -> float:
        return self.dyn_power_w * self.runtime_s


class PowerTimeline:
    """Per-node power-state timeline: the simulator's energy ledger.

    Segments are appended in commit order. Scalar totals (``energy_kj``)
    reproduce the legacy union-of-intervals decomposition — dynamic power x
    runtime per task, plus each node's idle power for the union time a
    scheduler's tasks keep it awake — while :meth:`power_series` /
    :meth:`energy_series` expose the same ledger as time-resolved
    piecewise-constant power and cumulative energy, per scheduler.

    Carbon accounting (``carbon_signal`` + per-node ``node_region``
    attached): every constant-power piece of the ledger is integrated
    against its region's time-varying grid intensity —
    :meth:`total_carbon_g` and :meth:`carbon_series` are exact (the signal
    supplies analytic interval integrals), not time-stepped. A preempted
    task's segment is cut at the eviction instant via :meth:`truncate`, so
    its energy/carbon interval splits between the partial run and the
    requeued one.

    State ledger (elastic fleet subsystem, ``repro.core.elastic``): with an
    ``AutoscalePolicy`` on the run, the fleet's non-task power draw lands
    here as :class:`StateInterval` entries (IDLE / ASLEEP / WAKING) plus
    :class:`WakeTransition` surge lumps. ``fleet_idle_energy_kj`` /
    ``fleet_energy_kj`` / ``fleet_carbon_g`` combine both ledgers; the
    per-scheduler views above are untouched (a run without a policy records
    no state intervals and reproduces the legacy accounting bitwise).
    """

    def __init__(self, segments: list[PowerSegment] | None = None,
                 carbon_signal=None,
                 node_region: "dict[str, str] | None" = None):
        self.segments: list[PowerSegment] = list(segments or [])
        self.state_intervals: list[StateInterval] = []
        self.wake_transitions: list[WakeTransition] = []
        self.carbon_signal = carbon_signal
        self.node_region: dict[str, str] = dict(node_region or {})

    def add(self, node: str, node_class: str, scheduler: str, start_s: float,
            runtime_s: float, dyn_power_w: float) -> None:
        self.segments.append(PowerSegment(node, node_class, scheduler,
                                          start_s, runtime_s, dyn_power_w))

    def add_state(self, node: str, node_class: str, state: str,
                  start_s: float, end_s: float, power_w: float) -> None:
        """Post one node-state stay to the state ledger (empty intervals are
        dropped, so lazy materialization can emit degenerate bounds)."""
        if end_s > start_s:
            self.state_intervals.append(
                StateInterval(node, node_class, state, start_s, end_s,
                              power_w))

    def add_wake(self, node: str, node_class: str, t_s: float,
                 energy_j: float) -> None:
        self.wake_transitions.append(
            WakeTransition(node, node_class, t_s, energy_j))

    def truncate(self, index: int, end_s: float) -> None:
        """Cut segment ``index`` short at ``end_s`` (task preempted): its
        dynamic power and the node-awake attribution both stop there."""
        seg = self.segments[index]
        self.segments[index] = dataclasses.replace(
            seg, runtime_s=max(end_s - seg.start_s, 0.0))

    def _segs(self, scheduler: str | None) -> list[PowerSegment]:
        if scheduler is None:
            return self.segments
        return [s for s in self.segments if s.scheduler == scheduler]

    def dynamic_energy_j(self, scheduler: str | None = None) -> float:
        """Sum of per-task dynamic energy, in segment (commit) order —
        identical arithmetic to summing ``PodRecord.energy_j``."""
        return sum(s.energy_j for s in self._segs(scheduler))

    def busy_intervals(self, scheduler: str | None = None
                       ) -> dict[str, list[tuple[float, float]]]:
        """Per-node busy intervals attributed to ``scheduler``."""
        by_node: dict[str, list[tuple[float, float]]] = {}
        for s in self._segs(scheduler):
            by_node.setdefault(s.node, []).append((s.start_s, s.end_s))
        return by_node

    def idle_energy_j(self, scheduler: str | None = None) -> float:
        """Idle (static) energy: each node's idle power x the union time the
        scheduler's tasks keep it awake — the legacy decomposition."""
        classes = {s.node: s.node_class for s in self._segs(scheduler)}
        return sum(NODE_ENERGY_PROFILES[classes[node]]["idle_power"]
                   * union_length(ivs)
                   for node, ivs in self.busy_intervals(scheduler).items())

    def energy_kj(self, scheduler: str | None = None) -> float:
        return (self.dynamic_energy_j(scheduler)
                + self.idle_energy_j(scheduler)) / 1000.0

    def power_series(self, scheduler: str | None = None):
        """Piecewise-constant total power: ``(edges, watts)`` with
        ``watts[k]`` drawn on ``[edges[k], edges[k+1])`` — dynamic power of
        every running task plus idle power of every node the scheduler keeps
        awake. ``len(watts) == len(edges) - 1``; empty timelines return
        ``([], [])``."""
        import numpy as np
        segs = self._segs(scheduler)
        if not segs:
            return np.zeros(0), np.zeros(0)
        edges = np.unique(np.asarray(
            [s.start_s for s in segs] + [s.end_s for s in segs]))
        idx = {t: i for i, t in enumerate(edges.tolist())}
        delta = np.zeros(len(edges))
        for s in segs:                       # dynamic power while running
            delta[idx[s.start_s]] += s.dyn_power_w
            delta[idx[s.end_s]] -= s.dyn_power_w
        classes = {s.node: s.node_class for s in segs}
        for node, ivs in self.busy_intervals(scheduler).items():
            p = NODE_ENERGY_PROFILES[classes[node]]["idle_power"]
            for lo, hi in merge_intervals(ivs):  # idle power while awake
                delta[idx[lo]] += p
                delta[idx[hi]] -= p
        return edges, np.cumsum(delta)[:-1]

    def energy_series(self, scheduler: str | None = None):
        """Cumulative energy over time: ``(edges, joules)`` with
        ``joules[k]`` the energy consumed up to ``edges[k]`` (``joules[0]``
        is 0). The final value equals ``energy_kj() * 1000`` up to float
        summation order."""
        import numpy as np
        edges, watts = self.power_series(scheduler)
        if not len(edges):
            return edges, np.zeros(0)
        return edges, np.concatenate(
            [[0.0], np.cumsum(watts * np.diff(edges))])

    # --- carbon accounting (power x grid intensity over the timeline) -------
    def _require_signal(self):
        if self.carbon_signal is None:
            raise ValueError(
                "timeline has no carbon signal attached; construct "
                "PowerTimeline(carbon_signal=..., node_region=...) or run "
                "the scenario with a CarbonPolicy")

    def _power_pieces(self, scheduler: str | None = None
                      ) -> "list[tuple[float, float, float, str]]":
        """The ledger as constant-power pieces ``(start, end, watts, node)``:
        one dynamic piece per task segment plus one idle piece per merged
        busy interval per node — the exact decomposition ``energy_kj``
        sums, exposed for intensity-weighted integration."""
        segs = self._segs(scheduler)
        pieces = [(s.start_s, s.end_s, s.dyn_power_w, s.node)
                  for s in segs if s.runtime_s > 0.0]
        classes = {s.node: s.node_class for s in segs}
        for node, ivs in self.busy_intervals(scheduler).items():
            p = NODE_ENERGY_PROFILES[classes[node]]["idle_power"]
            pieces.extend((lo, hi, p, node)
                          for lo, hi in merge_intervals(ivs) if hi > lo)
        return pieces

    def region_of(self, node: str) -> str:
        return self.node_region.get(node, "default")

    def total_carbon_g(self, scheduler: str | None = None) -> float:
        """Operational carbon (grams CO2) attributed to a scheduler:
        ∫ power x intensity dt over every piece of the ledger, using the
        signal's exact interval integrals."""
        from repro.core.carbon import J_PER_KWH
        self._require_signal()
        sig = self.carbon_signal
        return sum(p * sig.integral(self.region_of(node), lo, hi)
                   for lo, hi, p, node in self._power_pieces(scheduler)
                   ) / J_PER_KWH

    def carbon_series(self, scheduler: str | None = None):
        """Cumulative carbon over time: ``(edges, grams)`` with ``grams[k]``
        the CO2 emitted up to ``edges[k]`` (``grams[0]`` is 0; the final
        value equals :meth:`total_carbon_g` up to summation order). Edges
        are the power-state change points; within each edge interval the
        power is constant and the intensity integral is exact."""
        import numpy as np
        from repro.core.carbon import J_PER_KWH
        self._require_signal()
        sig = self.carbon_signal
        pieces = self._power_pieces(scheduler)
        if not pieces:
            return np.zeros(0), np.zeros(0)
        edges = np.unique(np.asarray(
            [lo for lo, _, _, _ in pieces] + [hi for _, hi, _, _ in pieces]))
        # accumulate each piece's integral split at its own interior edges
        # (piece endpoints are edges, so searchsorted brackets exactly) —
        # no all-pieces scan per interval
        delta = np.zeros(len(edges) - 1)
        for lo, hi, p, node in pieces:
            region = self.region_of(node)
            i0 = int(np.searchsorted(edges, lo))
            i1 = int(np.searchsorted(edges, hi))
            for k in range(i0, i1):
                delta[k] += p * sig.integral(region, edges[k], edges[k + 1])
        return edges, np.concatenate([[0.0], np.cumsum(delta / J_PER_KWH)])

    # --- state ledger (elastic fleet subsystem) ------------------------------
    def state_energy_j(self, state: str | None = None) -> float:
        """Non-task baseline energy from the state ledger: idle power while
        IDLE or WAKING, residual draw while ASLEEP (``state`` filters to one
        state; None sums all). Zero on runs without an AutoscalePolicy."""
        return sum(iv.energy_j for iv in self.state_intervals
                   if state is None or iv.state == state)

    def wake_transition_energy_j(self) -> float:
        """Total wake-surge energy (one lump per ASLEEP→awake transition)."""
        return sum(w.energy_j for w in self.wake_transitions)

    def fleet_idle_energy_kj(self) -> float:
        """Every joule the fleet drew that is not task dynamic power:
        busy-union idle (attributed to schedulers) + state-ledger draw +
        wake surges — the quantity an idle-timeout policy exists to cut."""
        return (self.idle_energy_j(None) + self.state_energy_j()
                + self.wake_transition_energy_j()) / 1000.0

    def fleet_energy_kj(self) -> float:
        """Whole-fleet energy over the run: task dynamic energy plus
        :meth:`fleet_idle_energy_kj`."""
        return self.dynamic_energy_j(None) / 1000.0 + self.fleet_idle_energy_kj()

    def state_carbon_g(self) -> float:
        """Operational carbon of the state ledger: each interval's constant
        power integrated against its region's intensity (exact), plus each
        wake lump at the intensity of its instant."""
        from repro.core.carbon import J_PER_KWH
        self._require_signal()
        sig = self.carbon_signal
        total = sum(iv.power_w * sig.integral(self.region_of(iv.node),
                                              iv.start_s, iv.end_s)
                    for iv in self.state_intervals)
        total += sum(w.energy_j * sig.intensity(self.region_of(w.node), w.t_s)
                     for w in self.wake_transitions)
        return total / J_PER_KWH

    def fleet_carbon_g(self) -> float:
        """Whole-fleet carbon: the task-attributed total plus the state
        ledger's (requires a carbon signal, like :meth:`total_carbon_g`)."""
        return self.total_carbon_g(None) + self.state_carbon_g()

    # --- telemetry (observer-only rollups) -----------------------------------
    def publish_telemetry(self, tel) -> None:
        """Roll the energy ledgers up into gauges on ``tel``: per-node
        dynamic (task) energy, per-node per-state ledger energy and
        residency seconds, per-node wake-surge energy, and the fleet
        totals. Read-only over both ledgers — callers guard on
        ``tel.enabled`` so disabled runs never pay the walk."""
        dyn: dict[str, float] = {}
        for s in self.segments:
            dyn[s.node] = dyn.get(s.node, 0.0) + s.energy_j
        for node, e in dyn.items():
            tel.set_gauge("node_dynamic_energy_j", e, node=node)
        state_e: dict[tuple[str, str], float] = {}
        state_s: dict[tuple[str, str], float] = {}
        for iv in self.state_intervals:
            key = (iv.node, iv.state)
            state_e[key] = state_e.get(key, 0.0) + iv.energy_j
            state_s[key] = state_s.get(key, 0.0) + (iv.end_s - iv.start_s)
        for (node, state), e in state_e.items():
            tel.set_gauge("node_state_energy_j", e, node=node, state=state)
            tel.set_gauge("node_state_seconds", state_s[(node, state)],
                          node=node, state=state)
        wake: dict[str, float] = {}
        for w in self.wake_transitions:
            wake[w.node] = wake.get(w.node, 0.0) + w.energy_j
        for node, e in wake.items():
            tel.set_gauge("node_wake_energy_j", e, node=node)
        tel.set_gauge("fleet_dynamic_energy_kj",
                      self.dynamic_energy_j(None) / 1000.0)
        tel.set_gauge("fleet_idle_energy_kj", self.fleet_idle_energy_kj())
        tel.set_gauge("fleet_energy_kj", self.fleet_energy_kj())

    def publish_series(self, tel) -> None:
        """Expose the ledgers as sim-time :class:`~repro.core.telemetry.
        TimeSeries` on ``tel``: per-scheduler cumulative energy
        (``scheduler_energy_cum_kj``), per-state fleet baseline power
        (``state_power_w``), and — when a carbon signal is attached —
        per-region cumulative carbon (``region_carbon_cum_g``). Like
        :meth:`publish_telemetry` this is read-only over the ledgers and
        deterministic: every sample is derived from committed segments, so
        backends with bitwise-identical placements record identical series."""
        import numpy as np
        for sched in sorted({s.scheduler for s in self.segments}):
            edges, joules = self.energy_series(sched)
            for t, j in zip(edges.tolist(), joules.tolist()):
                tel.record("scheduler_energy_cum_kj", t, j / 1000.0,
                           scheduler=sched)
        states = sorted({iv.state for iv in self.state_intervals})
        for state in states:
            ivs = [iv for iv in self.state_intervals if iv.state == state]
            edges = np.unique(np.asarray(
                [iv.start_s for iv in ivs] + [iv.end_s for iv in ivs]))
            idx = {t: i for i, t in enumerate(edges.tolist())}
            delta = np.zeros(len(edges))
            for iv in ivs:
                delta[idx[iv.start_s]] += iv.power_w
                delta[idx[iv.end_s]] -= iv.power_w
            watts = np.cumsum(delta)
            for t, w in zip(edges.tolist(), watts.tolist()):
                tel.record("state_power_w", t, w, state=state)
        if self.carbon_signal is not None:
            from repro.core.carbon import J_PER_KWH
            sig = self.carbon_signal
            by_region: dict[str, list[tuple[float, float, float, str]]] = {}
            for piece in self._power_pieces(None):
                by_region.setdefault(self.region_of(piece[3]),
                                     []).append(piece)
            for region in sorted(by_region):
                pieces = by_region[region]
                edges = np.unique(np.asarray(
                    [lo for lo, _, _, _ in pieces]
                    + [hi for _, hi, _, _ in pieces]))
                delta = np.zeros(len(edges) - 1)
                for lo, hi, p, _node in pieces:
                    i0 = int(np.searchsorted(edges, lo))
                    i1 = int(np.searchsorted(edges, hi))
                    for k in range(i0, i1):
                        delta[k] += p * sig.integral(region, edges[k],
                                                     edges[k + 1])
                grams = np.concatenate(
                    [[0.0], np.cumsum(delta / J_PER_KWH)])
                for t, g in zip(edges.tolist(), grams.tolist()):
                    tel.record("region_carbon_cum_g", t, g, region=region)


# --- TPU fleet (beyond-paper) ----------------------------------------------
TPU_V5E_TDP_W = 250.0        # per-chip board power envelope
TPU_V5E_IDLE_W = 70.0


def chip_energy_joules(step_time_s: float, chips: int,
                       utilization: float) -> float:
    p = TPU_V5E_IDLE_W + (TPU_V5E_TDP_W - TPU_V5E_IDLE_W) * utilization
    return step_time_s * chips * p

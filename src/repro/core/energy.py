"""Energy models.

1. ``blade_power`` — the blade-server power model of Dayarathna et al. [32],
   the exact model the paper uses for its real-world extrapolation (§V.E):

     P = 14.45 + 0.236*u_cpu - 4.47e-8*u_mem + 0.00281*u_disk + 3.1e-8*u_net  [W]

   with u_cpu in percent, u_mem in memory accesses/s, u_disk in IO ops/s,
   u_net in network ops/s.

2. Per-node-class *dynamic* energy profiles for the cluster simulator
   (DESIGN.md §7 calibration): each node class has a speed factor and a
   dynamic power per allocated vCPU. Energy attributed to a task is
   dynamic power x runtime, matching the paper's 'energy consumption from
   scheduling decisions' metric (Table IV).

3. ``chip_energy`` — TPU-side model for the beyond-paper fleet scheduler:
   energy = step_time x chips x (idle + (TDP-idle) x mfu-ish utilization).
"""
from __future__ import annotations


def blade_power(u_cpu_pct: float, u_mem_acc_per_s: float = 0.0,
                u_disk_iops: float = 0.0, u_net_ops: float = 0.0) -> float:
    """Dayarathna et al. [32] blade server power (Watts)."""
    return (14.45 + 0.236 * u_cpu_pct - 4.47e-8 * u_mem_acc_per_s
            + 0.00281 * u_disk_iops + 3.1e-8 * u_net_ops)


def paper_job_energy_kwh(runtime_min: float = 34.0, pue: float = 1.45,
                         u_cpu_pct: float = 60.0,
                         u_mem_acc_per_s: float = 8e6,
                         u_disk_iops: float = 350.0,
                         u_net_ops: float = 3e6) -> float:
    """Average job energy exactly as computed in paper §V.E (≈0.024 kWh)."""
    p_watts = blade_power(u_cpu_pct, u_mem_acc_per_s, u_disk_iops, u_net_ops)
    return p_watts * pue * (runtime_min / 60.0) / 1000.0


# --- Cluster-simulator node energy profiles (calibrated, DESIGN.md §7) -----
# speed: relative per-core throughput; dyn_power_per_vcpu: Watts drawn per
# allocated vCPU while a task runs; idle_power: Watts the node draws whenever
# a scheduler's pods keep it awake (static/uncore power). Class A (e2-medium)
# is slow but frugal, class C (n2-standard-4) fast but power-hungry — the
# heterogeneity axis the paper's §V.D allocation analysis relies on.
# Consolidating onto one frugal node avoids paying several nodes' idle power,
# which is the physical mechanism behind the paper's 30-39% energy savings.
# Values fit to paper Table VI by scripts/calibrate.py (err metric in
# scripts/calibrated_params.json); see EXPERIMENTS.md §Repro for the match.
NODE_ENERGY_PROFILES: dict[str, dict[str, float]] = {
    "A": {"speed": 0.7500, "dyn_power_per_vcpu": 6.0000, "idle_power": 6.2321},
    "B": {"speed": 1.1000, "dyn_power_per_vcpu": 10.0000, "idle_power": 9.5953},
    "C": {"speed": 1.3417, "dyn_power_per_vcpu": 27.0570, "idle_power": 14.0000},
    "default": {"speed": 0.7000, "dyn_power_per_vcpu": 11.7709,
                "idle_power": 14.9153},
}


def task_energy_joules(node_class: str, runtime_s: float,
                       cpu_request: float) -> float:
    """Dynamic (CPU-proportional) energy of one task."""
    prof = NODE_ENERGY_PROFILES[node_class]
    return prof["dyn_power_per_vcpu"] * cpu_request * runtime_s


def predicted_task_energy_joules(node_class: str, runtime_s: float,
                                 cpu_request: float, node_awake: bool) -> float:
    """Energy-profiling-module prediction used in the decision matrix:
    dynamic energy plus — if the node is currently asleep — the idle power
    the placement would newly wake up for the task's duration. Marginal idle
    cost of an already-awake node is zero, which is what makes energy-centric
    TOPSIS consolidate (paper §V.D)."""
    e = task_energy_joules(node_class, runtime_s, cpu_request)
    if not node_awake:
        e += NODE_ENERGY_PROFILES[node_class]["idle_power"] * runtime_s
    return e


def predicted_task_energy_joules_np(dyn_power_per_vcpu, idle_power,
                                    runtime_s, cpu_request, awake):
    """Vectorized :func:`predicted_task_energy_joules` over node columns.

    All arguments broadcast (numpy arrays or scalars); ``awake`` is a bool
    mask. Same arithmetic and operand order as the scalar form, so the two
    agree bitwise on float64 inputs — the batched scheduler's decision
    matrix must rank identically to the per-pod path.
    """
    import numpy as np
    e = dyn_power_per_vcpu * cpu_request * runtime_s
    return e + np.where(awake, 0.0, idle_power * runtime_s)


# --- TPU fleet (beyond-paper) ----------------------------------------------
TPU_V5E_TDP_W = 250.0        # per-chip board power envelope
TPU_V5E_IDLE_W = 70.0


def chip_energy_joules(step_time_s: float, chips: int,
                       utilization: float) -> float:
    p = TPU_V5E_IDLE_W + (TPU_V5E_TDP_W - TPU_V5E_IDLE_W) * utilization
    return step_time_s * chips * p

"""Vectorized, jittable TOPSIS engine — the paper's core contribution.

TOPSIS (Technique for Order Preference by Similarity to Ideal Solution)
ranks N alternatives (cluster nodes / TPU slices) over C criteria:

  1. vector-normalize the decision matrix column-wise,
  2. apply criterion weights,
  3. form the ideal (A+) and anti-ideal (A-) alternatives,
  4. compute Euclidean distances d+ and d-,
  5. closeness coefficient  CC_i = d-_i / (d+_i + d-_i)  in [0, 1],
  6. rank descending by CC.

Everything here is pure jnp so it jits, vmaps (batched pods), and lowers to
TPU. A Pallas kernel for the tiled hot-path lives in
``repro.kernels.topsis_pallas``; this module is its semantic reference for the
*whole* pipeline (the kernel consumes precomputed column norms).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


class TopsisResult(NamedTuple):
    closeness: jax.Array      # (N,) closeness coefficient per alternative
    ranking: jax.Array        # (N,) indices, best alternative first
    d_pos: jax.Array          # (N,) distance to ideal
    d_neg: jax.Array          # (N,) distance to anti-ideal
    weighted: jax.Array       # (N, C) weighted normalized matrix


def normalize_matrix(matrix: jax.Array) -> jax.Array:
    """Column-wise vector normalization: r_ij = x_ij / ||x_:j||_2.

    Zero columns normalize to zero (all alternatives equal on that
    criterion → it contributes nothing to the ranking).
    """
    norms = jnp.sqrt(jnp.sum(matrix * matrix, axis=-2, keepdims=True))
    return matrix / jnp.maximum(norms, _EPS)


def ideal_points(weighted: jax.Array, benefit: jax.Array):
    """Ideal / anti-ideal rows. ``benefit`` is a (C,) bool mask:
    True → higher is better (max enters A+), False → cost criterion."""
    col_max = jnp.max(weighted, axis=-2)
    col_min = jnp.min(weighted, axis=-2)
    a_pos = jnp.where(benefit, col_max, col_min)
    a_neg = jnp.where(benefit, col_min, col_max)
    return a_pos, a_neg


def masked_ideal_points(weighted: jax.Array, benefit: jax.Array,
                        valid: jax.Array | None):
    """Ideal / anti-ideal rows with infeasible alternatives excluded from
    BOTH reference points: invalid rows are replaced with the worst possible
    value for A+ and the best possible value for A- so they can never define
    either extreme. The single source of this rule — the Pallas wrappers in
    ``repro.kernels.ops`` share it (``closeness_np`` mirrors it in numpy)."""
    if valid is None:
        return ideal_points(weighted, benefit)
    worst = jnp.where(benefit, -jnp.inf, jnp.inf)
    best = jnp.where(benefit, jnp.inf, -jnp.inf)
    a_pos, _ = ideal_points(jnp.where(valid[..., None], weighted, worst),
                            benefit)
    _, a_neg = ideal_points(jnp.where(valid[..., None], weighted, best),
                            benefit)
    return a_pos, a_neg


def closeness(matrix: jax.Array, weights: jax.Array, benefit: jax.Array,
              valid: jax.Array | None = None) -> TopsisResult:
    """Full TOPSIS pipeline on a (N, C) decision matrix.

    ``valid`` is an optional (N,) bool mask for alternatives that survived
    filtering (infeasible nodes). Invalid rows are excluded from the ideal
    points and receive closeness -inf so they never rank first.
    """
    weights = weights / jnp.maximum(jnp.sum(weights), _EPS)
    r = normalize_matrix(matrix)
    v = r * weights

    a_pos, a_neg = masked_ideal_points(v, benefit, valid)

    d_pos = jnp.sqrt(jnp.sum((v - a_pos) ** 2, axis=-1))
    d_neg = jnp.sqrt(jnp.sum((v - a_neg) ** 2, axis=-1))
    cc = d_neg / jnp.maximum(d_pos + d_neg, _EPS)
    # Degenerate case: single feasible alternative or all-equal matrix.
    cc = jnp.where(d_pos + d_neg <= _EPS, 0.5, cc)
    if valid is not None:
        cc = jnp.where(valid, cc, -jnp.inf)
    ranking = jnp.argsort(-cc, axis=-1)
    return TopsisResult(cc, ranking, d_pos, d_neg, v)


@functools.partial(jax.jit, static_argnames=())
def closeness_jit(matrix, weights, benefit, valid):
    return closeness(matrix, weights, benefit, valid)


def select(matrix: jax.Array, weights: jax.Array, benefit: jax.Array,
           valid: jax.Array | None = None) -> jax.Array:
    """Index of the best alternative (argmax closeness)."""
    return closeness(matrix, weights, benefit, valid).ranking[..., 0]


# Batched form: P concurrent pods, each with its own (N, C) matrix + weights.
batched_closeness = jax.vmap(closeness, in_axes=(0, 0, None, 0))

@jax.jit
def batched_closeness_cc(mats, ws, benefit, valids):
    """Closeness coefficients only, (P, N). Returning just the scores lets
    XLA drop the ranking sort and the (P, N, C) weighted tensor from the
    program — at N=8k the scheduler only reads closeness, and hauling the
    full TopsisResult back to host dominates the batch runtime."""
    return batched_closeness(mats, ws, benefit, valids).closeness


def batched_closeness_np(mats, ws, benefit, valids=None) -> "np.ndarray":
    """(P, N) closeness via a per-pod :func:`closeness_np` loop — the
    reference semantics the batched jax/pallas backends must match."""
    import numpy as np
    out = [closeness_np(m, w, benefit,
                        None if valids is None else valids[i]).closeness
           for i, (m, w) in enumerate(zip(mats, ws))]
    return np.stack(out, axis=0)


# --- Weight-scheme grid: one dispatch over (S schemes x P pods x N nodes) ---
@jax.jit
def _closeness_grid_jit(mats, ws, benefit, valids):
    def one_scheme(w):
        wp = jnp.broadcast_to(w, (mats.shape[0], w.shape[-1]))
        return batched_closeness(mats, wp, benefit, valids).closeness
    return jax.vmap(one_scheme)(ws)


def closeness_grid(mats: jax.Array, ws: jax.Array, benefit: jax.Array,
                   valids: jax.Array | None = None) -> jax.Array:
    """(S, P, N) closeness for a (P, N, C) queue tensor under an (S, C)
    weight-scheme grid — :func:`closeness` vmapped over the scheme axis and
    jitted as ONE program, so sweeping thousands of weighting schemes costs
    one dispatch instead of S (the Pareto-frontier scoring path,
    ``repro.core.pareto``). Row ``s`` computes exactly what
    :func:`batched_closeness` computes for ``ws[s]``: the (P, N, C)
    normalization is weight-independent and is shared across schemes by XLA,
    while ideal points and distances are per scheme. ``valids`` is the
    usual (P, N) feasibility mask (shared by all schemes; invalid -> -inf).
    """
    if valids is None:
        # all-true mask is bitwise inert (masked ideal points and the -inf
        # fill both reduce to the unmasked pipeline) and keeps one trace
        valids = jnp.ones(mats.shape[:2], dtype=bool)
    return _closeness_grid_jit(mats, ws, benefit, valids)


def closeness_grid_np(mats, ws, benefit, valids=None) -> "np.ndarray":
    """(S, P, N) numpy reference for :func:`closeness_grid`: a per-scheme
    loop of :func:`batched_closeness_np`, so row ``s`` is bitwise equal to
    scoring the queue under ``ws[s]`` alone — the oracle the jax and pallas
    grid paths are verified against (1e-5, float32 device math)."""
    import numpy as np
    ws = np.asarray(ws, dtype=np.float64)
    p = len(mats)
    return np.stack([
        batched_closeness_np(mats, np.broadcast_to(w, (p, w.shape[-1])),
                             benefit, valids)
        for w in ws], axis=0)


def _weighted_and_ideals_np(matrix, weights, benefit, valid):
    """The numpy pipeline up to the distance step: weighted normalized
    matrix plus the (masked) ideal / anti-ideal rows — shared verbatim by
    :func:`closeness_np` and :func:`explain_np` so the explanation is an
    exact decomposition of the scores the scheduler acted on."""
    import numpy as np
    matrix = np.asarray(matrix, dtype=np.float64)
    weights = np.asarray(weights, dtype=np.float64)
    weights = weights / max(weights.sum(), _EPS)
    benefit = np.asarray(benefit, dtype=bool)
    norms = np.sqrt((matrix * matrix).sum(axis=0, keepdims=True))
    v = matrix / np.maximum(norms, _EPS) * weights
    if valid is not None:
        valid = np.asarray(valid, dtype=bool)
        worst = np.where(benefit, -np.inf, np.inf)
        best = np.where(benefit, np.inf, -np.inf)
        vw = np.where(valid[:, None], v, worst)
        vb = np.where(valid[:, None], v, best)
        a_pos = np.where(benefit, vw.max(axis=0), vw.min(axis=0))
        a_neg = np.where(benefit, vb.min(axis=0), vb.max(axis=0))
    else:
        a_pos = np.where(benefit, v.max(axis=0), v.min(axis=0))
        a_neg = np.where(benefit, v.min(axis=0), v.max(axis=0))
    return v, a_pos, a_neg, valid


def closeness_np(matrix, weights, benefit, valid=None):
    """NumPy mirror of :func:`closeness` for latency-critical single
    decisions on CPU (the per-pod scheduler hot path, where jnp dispatch
    overhead dominates the 4-node matrices of the paper's cluster).
    Semantics are identical; tests assert equivalence."""
    import numpy as np
    v, a_pos, a_neg, valid = _weighted_and_ideals_np(matrix, weights,
                                                     benefit, valid)
    # inf/inf -> nan is expected when NO row is valid (both ideals are
    # +-inf); the nan closeness is masked to -inf below
    with np.errstate(invalid="ignore"):
        d_pos = np.sqrt(((v - a_pos) ** 2).sum(axis=1))
        d_neg = np.sqrt(((v - a_neg) ** 2).sum(axis=1))
        cc = d_neg / np.maximum(d_pos + d_neg, _EPS)
        cc = np.where(d_pos + d_neg <= _EPS, 0.5, cc)
    if valid is not None:
        cc = np.where(valid, cc, -np.inf)
    return TopsisResult(cc, np.argsort(-cc), d_pos, d_neg, v)


def _cc_row_np(row, a_pos, a_neg):
    """Closeness of one weighted-normalized row against fixed ideal points
    (same arithmetic and degenerate rule as :func:`closeness_np`)."""
    import numpy as np
    d_pos = float(np.sqrt(((row - a_pos) ** 2).sum()))
    d_neg = float(np.sqrt(((row - a_neg) ** 2).sum()))
    if d_pos + d_neg <= _EPS:
        return 0.5
    return d_neg / max(d_pos + d_neg, _EPS)


def explain_np(matrix, weights, benefit, valid=None, criteria_names=None):
    """Per-criterion attribution of the winner-vs-runner-up closeness gap.

    Telescoping decomposition: starting from the runner-up's weighted
    normalized row, swap one criterion at a time to the winner's value
    (criteria order) and recompute closeness against the *fixed* ideal
    points of the actual decision. Each swap's closeness delta is that
    criterion's contribution; the deltas sum exactly (up to float
    round-off) to ``cc_winner - cc_runner_up``, so "why did TOPSIS pick
    this node" reads off as C signed numbers. Numpy path only — the
    jax/pallas engines return closeness without the weighted
    intermediates.

    Returns a dict: winner / runner-up indices and closeness, the gap,
    and one ``{criterion, delta_cc, winner_value, runner_up_value}``
    entry per criterion (raw decision-matrix values, not the normalized
    ones). With fewer than two feasible alternatives ``runner_up`` is
    None and ``contributions`` is empty.
    """
    import numpy as np
    matrix = np.asarray(matrix, dtype=np.float64)
    res = closeness_np(matrix, weights, benefit, valid)
    v, a_pos, a_neg, _ = _weighted_and_ideals_np(matrix, weights, benefit,
                                                 valid)
    n_c = matrix.shape[-1]
    if criteria_names is None:
        criteria_names = [f"criterion_{j}" for j in range(n_c)]
    # first max on both picks — the scheduler's argmax tie-break, which
    # res.ranking (unstable argsort) does not guarantee on exact ties
    winner = int(np.argmax(res.closeness))
    feasible = int(np.isfinite(res.closeness).sum())
    if feasible < 2:
        return {"winner": winner, "runner_up": None,
                "closeness_winner": float(res.closeness[winner]),
                "closeness_runner_up": None, "gap": None,
                "contributions": []}
    rest = res.closeness.copy()
    rest[winner] = -np.inf
    runner = int(np.argmax(rest))
    row = v[runner].copy()
    cc_prev = _cc_row_np(row, a_pos, a_neg)
    contributions = []
    for j in range(n_c):
        row[j] = v[winner, j]
        cc_j = _cc_row_np(row, a_pos, a_neg)
        contributions.append({
            "criterion": str(criteria_names[j]),
            "delta_cc": cc_j - cc_prev,
            "winner_value": float(matrix[winner, j]),
            "runner_up_value": float(matrix[runner, j]),
        })
        cc_prev = cc_j
    return {"winner": winner, "runner_up": runner,
            "closeness_winner": float(res.closeness[winner]),
            "closeness_runner_up": float(res.closeness[runner]),
            "gap": float(res.closeness[winner] - res.closeness[runner]),
            "contributions": contributions}

"""Grid carbon-intensity signals and the carbon-aware scheduling policy.

GreenPod optimizes *energy*; the sustainability metric operators report is
*carbon*, which varies by grid region and hour. This module supplies the
time-varying signal layer the carbon-aware scheduling stack consumes:

1. ``CarbonSignal`` — gCO2/kWh as a function of ``(region, t)``, with three
   implementations mirroring the ``ArrivalProcess`` family in
   ``repro.cluster.workload``:

     * ``ConstantCarbon``   — flat per-region intensities (annual averages),
     * ``SinusoidalCarbon`` — diurnal sinusoid with per-region phase offsets
       (solar-heavy grids dip mid-day at their local noon),
     * ``TraceCarbon``      — replayable piecewise-constant JSON traces
       (e.g. recorded electricityMaps / WattTime series).

   Every signal exposes exact interval integrals (``integral``), which is
   what lets ``PowerTimeline`` integrate power x intensity over a run
   without time-stepping error.

2. ``CarbonPolicy`` — the knobs the event-driven engine consumes: the
   signal itself, a deferral threshold (deferrable pods wait, bounded by
   their deadline, until the fleet-minimum intensity dips below it), an
   optional preemption threshold (a running deferrable task is evicted and
   requeued when its node's regional signal spikes above it), and the
   cadence of carbon-check wake events.

Carbon from energy: grams = joules x (gCO2/kWh) / 3.6e6 (``carbon_grams``).
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import math
import os
from typing import Sequence

import numpy as np

from repro.core import telemetry
from repro.core.policy import CARBON_CHECK, Event, SchedulingPolicy

J_PER_KWH = 3.6e6

# Default fleet regions: synthetic fleets spread nodes round-robin across
# these (cluster.node.make_fleet / make_scenario_cluster); the paper's 4-node
# cluster keeps the single "default" region, so paper-mode runs see a flat
# signal axis and reproduce bitwise.
DEFAULT_REGIONS: tuple[str, ...] = ("us-east", "us-west", "eu-west",
                                    "ap-south")


def carbon_grams(energy_j: float, intensity_g_per_kwh: float) -> float:
    """Operational carbon of ``energy_j`` joules drawn at a (constant)
    grid intensity."""
    return energy_j * intensity_g_per_kwh / J_PER_KWH


class CarbonSignal:
    """Grid carbon intensity (gCO2/kWh) per region over time.

    Implementations must be deterministic pure functions of ``(region, t)``
    so scenario runs replay exactly, and must provide *exact* interval
    integrals: ``integral(region, t0, t1)`` returns ``∫ I(region, t) dt``
    in gCO2·s/kWh, which multiplied by a constant power (W) and divided by
    ``J_PER_KWH`` yields grams — the primitive ``PowerTimeline`` carbon
    accounting is built on.
    """

    def intensity(self, region: str, t: float) -> float:
        raise NotImplementedError

    def integral(self, region: str, t0: float, t1: float) -> float:
        """Exact ``∫_{t0}^{t1} intensity(region, t) dt`` (gCO2·s/kWh)."""
        raise NotImplementedError

    def intensities(self, regions: Sequence[str], t: float) -> np.ndarray:
        """(N,) intensity column for a fleet's per-node regions (one
        evaluation per *unique* region, broadcast to the node axis)."""
        cache = {r: self.intensity(r, t) for r in set(regions)}
        return np.asarray([cache[r] for r in regions], dtype=np.float64)

    def fleet_min(self, regions: Sequence[str], t: float) -> float:
        """Lowest current intensity over a set of regions — the engine's
        'is there a dip anywhere' deferral test."""
        return min(self.intensity(r, t) for r in set(regions))


class ConstantCarbon(CarbonSignal):
    """Flat intensities: one default value plus optional per-region
    overrides. The degenerate signal — carbon-aware scoring under it
    reduces to power-aware scoring."""

    def __init__(self, intensity: float = 400.0,
                 per_region: dict[str, float] | None = None):
        if intensity < 0.0:
            raise ValueError(f"intensity must be >= 0, got {intensity}")
        self.default = float(intensity)
        self.per_region = {k: float(v) for k, v in (per_region or {}).items()}
        for r, v in self.per_region.items():
            if v < 0.0:
                raise ValueError(f"intensity for region {r!r} must be >= 0, "
                                 f"got {v}")

    def intensity(self, region: str, t: float) -> float:
        return self.per_region.get(region, self.default)

    def integral(self, region: str, t0: float, t1: float) -> float:
        return self.intensity(region, t0) * (t1 - t0)


class SinusoidalCarbon(CarbonSignal):
    """Diurnal sinusoid: ``base + amplitude * sin(2π (t + phase) / period)``
    with a per-region phase offset (regions peak at different wall-clock
    hours). ``amplitude <= base`` keeps the signal non-negative, which in
    turn keeps the analytic integral exact (no clipping)."""

    def __init__(self, base: float = 300.0, amplitude: float = 200.0,
                 period_s: float = 86400.0, phase_s: float = 0.0,
                 region_phase_s: dict[str, float] | None = None):
        if period_s <= 0.0:
            raise ValueError(f"period_s must be positive, got {period_s}")
        if not 0.0 <= amplitude <= base:
            raise ValueError("need 0 <= amplitude <= base for a non-negative "
                             f"signal, got amplitude={amplitude} base={base}")
        self.base = float(base)
        self.amplitude = float(amplitude)
        self.period_s = float(period_s)
        self.phase_s = float(phase_s)
        self.region_phase_s = {k: float(v)
                               for k, v in (region_phase_s or {}).items()}

    def _phase(self, region: str) -> float:
        return self.phase_s + self.region_phase_s.get(region, 0.0)

    def intensity(self, region: str, t: float) -> float:
        w = 2.0 * math.pi / self.period_s
        return self.base + self.amplitude * math.sin(w * (t + self._phase(region)))

    def integral(self, region: str, t0: float, t1: float) -> float:
        # ∫ base + A sin(w (t + φ)) dt = base Δt - (A/w)[cos(w(t1+φ)) - cos(w(t0+φ))]
        w = 2.0 * math.pi / self.period_s
        phi = self._phase(region)
        return (self.base * (t1 - t0)
                - self.amplitude / w * (math.cos(w * (t1 + phi))
                                        - math.cos(w * (t0 + phi))))


def diurnal_fleet_signal(regions: Sequence[str] = DEFAULT_REGIONS,
                         base: float = 300.0, amplitude: float = 200.0,
                         period_s: float = 86400.0, phase_s: float = 0.0,
                         stagger_s: float | None = None) -> SinusoidalCarbon:
    """Convenience: one diurnal sinusoid with region phases staggered by
    ``stagger_s`` (default: evenly around the period) — the multi-timezone
    fleet a carbon-aware scheduler can chase the sun across."""
    if stagger_s is None:
        stagger_s = period_s / max(len(regions), 1)
    return SinusoidalCarbon(
        base=base, amplitude=amplitude, period_s=period_s, phase_s=phase_s,
        region_phase_s={r: i * stagger_s for i, r in enumerate(regions)})


class TraceCarbon(CarbonSignal):
    """Replayable piecewise-constant intensity trace: entries
    ``{"t": float, "intensity": float, "region": str}`` (region defaults to
    ``"default"``). Each region's intensity holds its most recent reading;
    before a region's first reading the first value applies. Regions absent
    from the trace fall back to the ``"default"`` region's series.

    Mirrors ``TraceArrivals``: :meth:`from_file` loads a JSON list (``str``
    or ``pathlib.Path``), entries are validated up front with messages
    naming the offending entry's index (and the source file when loaded
    from one), and a fixed trace replays to the identical signal every run.
    """

    def __init__(self, entries: "list[dict]", source: str | None = None):
        prefix = f"{source}: " if source else ""
        series: dict[str, list[tuple[float, float]]] = {}
        for i, e in enumerate(entries):
            where = f"{prefix}carbon trace entry {i} ({e!r})"
            if not isinstance(e, dict):
                raise ValueError(f"{where}: expected an object with 't' "
                                 f"and 'intensity' fields")
            try:
                t_ok = math.isfinite(float(e["t"])) and float(e["t"]) >= 0.0
            except (KeyError, TypeError, ValueError):
                t_ok = False
            if not t_ok:
                raise ValueError(
                    f"{where}: needs a finite non-negative 't'")
            try:
                i_ok = (math.isfinite(float(e["intensity"]))
                        and float(e["intensity"]) >= 0.0)
            except (KeyError, TypeError, ValueError):
                i_ok = False
            if not i_ok:
                raise ValueError(f"{where}: needs a finite non-negative "
                                 f"'intensity' (gCO2/kWh)")
            region = e.get("region", "default")
            if not isinstance(region, str) or not region:
                raise ValueError(f"{where}: 'region' must be a non-empty "
                                 f"string")
            series.setdefault(region, []).append(
                (float(e["t"]), float(e["intensity"])))
        if not series:
            raise ValueError(f"{prefix}carbon trace has no entries")
        self.series = {r: sorted(pts) for r, pts in series.items()}
        self._times = {r: [t for t, _ in pts] for r, pts in self.series.items()}

    @classmethod
    def from_file(cls, path) -> "TraceCarbon":
        """Load a JSON trace; ``path`` may be a ``str`` or any
        ``os.PathLike`` (``pathlib.Path``). Validation errors are prefixed
        with the file path and the offending entry's index."""
        with open(path) as f:
            return cls(json.load(f), source=os.fspath(path))

    def _pts(self, region: str) -> list[tuple[float, float]]:
        pts = self.series.get(region)
        if pts is None:
            pts = self.series.get("default")
        if pts is None:
            raise ValueError(f"region {region!r} not in carbon trace and no "
                             f"'default' region series to fall back to "
                             f"(have {sorted(self.series)})")
        return pts

    def intensity(self, region: str, t: float) -> float:
        pts = self._pts(region)
        times = self._times.get(region, self._times.get("default"))
        i = bisect.bisect_right(times, t) - 1
        return pts[max(i, 0)][1]

    def integral(self, region: str, t0: float, t1: float) -> float:
        pts = self._pts(region)
        # start at the piece containing t0 and stop once past t1 instead of
        # scanning the whole trace (hot path of timeline carbon accounting)
        times = self._times.get(region, self._times.get("default"))
        k0 = max(bisect.bisect_right(times, t0) - 1, 0)
        total = 0.0
        for k in range(k0, len(pts)):
            s, val = pts[k]
            e = pts[k + 1][0] if k + 1 < len(pts) else math.inf
            if k == 0:
                s = -math.inf          # first reading extends backwards
            lo, hi = max(s, t0), min(e, t1)
            if hi > lo:
                total += val * (hi - lo)
            if e >= t1:
                break
        return total


@dataclasses.dataclass(frozen=True)
class CarbonPolicy:
    """Carbon configuration for the event-driven engine
    (``repro.cluster.simulator.run_scenario``).

    * ``signal`` alone attaches the sixth (carbon-rate) criterion to the
      TOPSIS schedulers and carbon accounting to the run's
      ``PowerTimeline`` — placements of zero-carbon-weight schemes are
      bitwise unchanged.
    * ``defer_threshold``: while the fleet-minimum intensity exceeds it,
      deferrable pods wait (bounded by ``Pod.deadline_s`` past arrival)
      for a dip; the engine wakes every ``check_interval_s`` to re-test,
      and always exactly at a waiting pod's deadline.
    * ``preempt_threshold``: a running deferrable task whose node's
      regional intensity spikes above it is evicted and requeued (at most
      once per pod, never past its deadline); its timeline segment is
      truncated at the eviction instant.
    """

    signal: CarbonSignal
    defer_threshold: float = math.inf        # gCO2/kWh
    preempt_threshold: float | None = None   # gCO2/kWh
    check_interval_s: float = 300.0

    def __post_init__(self):
        if self.check_interval_s <= 0.0:
            raise ValueError(f"check_interval_s must be positive, "
                             f"got {self.check_interval_s}")
        if math.isnan(self.defer_threshold):
            # NaN would silently disable deferral (every > compares False)
            raise ValueError("defer_threshold must not be NaN; use the "
                             "default inf to turn deferral off")
        if self.preempt_threshold is not None and not (
                self.preempt_threshold >= 0.0):
            raise ValueError(f"preempt_threshold must be >= 0, "
                             f"got {self.preempt_threshold}")


class CarbonScheduling(SchedulingPolicy):
    """Carbon temporal shifting as a kernel policy: the engine-side logic
    of :class:`CarbonPolicy`, expressed through the
    :class:`~repro.core.policy.SchedulingPolicy` hook protocol.

    * ``on_arrival``     — rejects deferrable pods without a finite
      positive deadline (an unbounded deadline would let the wake loop
      spin forever under a never-dipping signal).
    * ``on_round_start`` — the *preemption* event: running deferrable
      tasks whose node's regional intensity spiked above
      ``preempt_threshold`` are evicted (at most once per pod, never past
      their deadline), their ledger entries truncated at ``t``, and the
      pods requeued with a same-node restart block for the instant.
    * ``filter_pending`` — the *deferral* event: while the fleet-minimum
      intensity exceeds ``defer_threshold``, deferrable pods sit the
      round out, bounded by their deadline.
    * ``next_wake_time`` — CARBON_CHECK events at the policy cadence
      while pods defer or preemptable tasks run, and exactly at every
      held pod's deadline (a deferred pod never starts past it).

    One instance drives one run (it accumulates the once-per-pod
    preemption set); ``run_scenario`` constructs a fresh one per call.

    The carbon_rate criterion itself needs no hook here: the schedulers'
    incremental caches (``repro.core.scheduler.FleetCriteriaCache``) cache
    the time-invariant power factor per node and refresh the intensity
    product whenever decision time moves — the column is never stale with
    respect to the signal, and eviction/requeue dirties the touched nodes
    through the FleetState mutators like any other capacity change.
    """

    def __init__(self, policy: CarbonPolicy):
        self.policy = policy
        self.preempted: set[int] = set()   # uids evicted once already
        self.fleet_regions: list[str] = []

    @property
    def carbon_signal(self) -> CarbonSignal:
        return self.policy.signal

    def bind(self, sim) -> None:
        self.fleet_regions = sorted({n.region for n in sim.state.nodes})

    def on_clock(self, sim, t: float) -> None:
        tel = telemetry.active()
        if tel.enabled:
            # observer-only: the grid-intensity timeline each region saw,
            # sampled at the clock instants the engine actually visited
            for region in self.fleet_regions:
                tel.record("carbon_intensity_g_per_kwh", t,
                           self.policy.signal.intensity(region, t),
                           region=region)

    def on_arrival(self, sim, pod, t: float) -> None:
        if pod.deferrable and not (math.isfinite(pod.deadline_s)
                                   and pod.deadline_s > 0.0):
            raise ValueError(
                f"deferrable pod {pod.uid} needs a finite positive "
                f"deadline_s, got {pod.deadline_s}")

    def _preemptable(self, sim, task, t: float) -> bool:
        """Still-running deferrable task, not yet preempted, deadline
        ahead — the class the preemption spike test applies to."""
        return (task.end_s > t and task.pod.deferrable
                and task.pod.uid not in self.preempted
                and t < sim.deadline(task.pod))

    def on_round_start(self, sim, t: float) -> None:
        pol = self.policy
        if pol.preempt_threshold is None:
            return
        st = sim.state
        victims = [task for task in st.running
                   if self._preemptable(sim, task, t)
                   and pol.signal.intensity(st.nodes[task.node_index].region,
                                            t) > pol.preempt_threshold]
        if not victims:
            return
        st.pending.extend(sim.evict(victims, t))
        for task in victims:
            self.preempted.add(task.uid)
            sim.block_restart(task.uid, task.node_index, t)
        st.preemptions += len(victims)
        telemetry.active().inc("policy_preemptions",
                               value=float(len(victims)),
                               policy=type(self).__name__)

    def filter_pending(self, sim, pods, t: float):
        pol = self.policy
        if not any(p.deferrable for p in pods):
            return []
        if pol.signal.fleet_min(self.fleet_regions, t) <= pol.defer_threshold:
            return []
        return [p for p in pods
                if p.deferrable and t < sim.deadline(p) - 1e-12]

    def next_wake_time(self, sim, t: float, held) -> Event | None:
        pol = self.policy
        cands = [sim.deadline(p) for p in held]
        if held:
            cands.append(t + pol.check_interval_s)
        if pol.preempt_threshold is not None and any(
                self._preemptable(sim, task, t)
                for task in sim.state.running):
            cands.append(t + pol.check_interval_s)
        cands = [c for c in cands if c > t]
        return Event.make(min(cands), CARBON_CHECK) if cands else None

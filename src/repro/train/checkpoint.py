"""Sharded checkpointing with async save and ELASTIC restore.

Format: <dir>/step_<N>/
  manifest.json        — tree structure, shapes, dtypes, step
  <leaf-path>.npy      — one file per leaf (host-gathered)

Design notes for 1000+ nodes: in multi-host production each host writes only
its addressable shards (path scheme includes the shard index) — here we run
single-process, so leaves are gathered whole; the restore path is the
interesting part and is fully elastic: a checkpoint taken on mesh M1 restores
onto any mesh M2 by device_put-ing each leaf with M2's sharding rules
(re-sharding happens device-side). Async save snapshots to host in the main
thread (cheap) and writes files on a background thread.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np

_SEP = "__"
_save_seq = __import__("itertools").count()


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        flat[_SEP.join(keys)] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True):
    """Save pytree; returns a join() handle when blocking=False.

    The staging dir is writer-unique so a blocking save and a still-running
    async save of the same step never collide; os.replace publishes
    atomically and the loser's rename is a no-op failure we swallow."""
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + f".tmp.{os.getpid()}.{next(_save_seq)}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}   # snapshot now
    manifest = {"step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in host.items()}}

    def write():
        for k, v in host.items():
            np.save(os.path.join(tmp, k + ".npy"), v)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        try:
            os.replace(tmp, out)  # atomic publish
        except OSError:
            # another writer already published this step
            import shutil
            shutil.rmtree(tmp, ignore_errors=True)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1].split(".")[0]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and ".tmp" not in d]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`. `shardings` (same tree of
    NamedSharding, possibly for a DIFFERENT mesh than the checkpoint was
    saved from) enables elastic re-sharding on load."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    flat_like = _flatten(like_tree)
    shard_flat = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for k, like in flat_like.items():
        v = np.load(os.path.join(src, k + ".npy"))
        assert tuple(v.shape) == tuple(np.shape(like)), (k, v.shape,
                                                         np.shape(like))
        if k in shard_flat:
            loaded[k] = jax.device_put(v, shard_flat[k])
        else:
            loaded[k] = jax.device_put(v.astype(np.asarray(like).dtype)
                                       if hasattr(like, "dtype") else v)
    # unflatten back into like_tree's structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    treedef = jax.tree_util.tree_structure(like_tree)
    ordered = []
    for path, _ in leaves_paths:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        ordered.append(loaded[_SEP.join(keys)])
    return jax.tree_util.tree_unflatten(treedef, ordered)

"""Training step factory: pjit'd train step with microbatched gradient
accumulation (lax.scan), global-norm clipping, AdamW (optionally int8
moments), ZeRO-1 state sharding, and an optional shard_map data-parallel
path with int8 error-feedback gradient compression.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.lm import LM
from repro.optim import adamw, compress
from repro.sharding import rules


def microbatch_grads(model: LM, params, batch, n_micro: int,
                     grad_specs=None, grad_dtype=None):
    """Mean loss + grads accumulated over n_micro microbatches via scan
    (bounds activation memory; MoE dispatch buffers size with the microbatch).

    grad_specs: optional pytree of PartitionSpec — pins the accumulator
    sharding to the parameter sharding so the per-layer grads stacked by the
    scan's backward never get re-sharded inside the loop (§Perf: deepseek-v3
    spent 20 TB/device of collectives on exactly that).
    grad_dtype: accumulator dtype; f32 default, bf16 halves the accumulator
    HBM for 100B+ models (error absorbed by AdamW's f32 moments).
    """
    def constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_specs)

    if n_micro == 1:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        return loss, metrics, constrain(grads)

    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    mbs = jax.tree.map(split, batch)
    acc_dt = grad_dtype or jnp.float32

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, mb)
        grads = constrain(grads)
        acc = constrain(jax.tree.map(
            lambda a, g: a + g.astype(a.dtype), acc, grads))
        return (acc, loss_acc + loss), None

    zeros = constrain(jax.tree.map(
        lambda p: jnp.zeros(p.shape, acc_dt), params))
    (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.zeros(())), mbs)
    inv = 1.0 / n_micro
    grads = jax.tree.map(lambda g: (g * inv).astype(jnp.float32), gsum)
    return lsum * inv, {}, grads


def make_train_fn(model: LM, opt_cfg: adamw.AdamWConfig, n_micro: int = 1,
                  grad_specs=None, grad_dtype=None):
    """Pure (un-jitted) train_step(params, opt_state, batch) ->
    (params, opt_state, metrics). Used by make_train_step and by
    launch/dryrun.py (which jits with explicit shardings)."""

    def train_step(params, opt_state, batch):
        loss, _, grads = microbatch_grads(model, params, batch, n_micro,
                                          grad_specs=grad_specs,
                                          grad_dtype=grad_dtype)
        new_params, new_state, m = adamw.update(opt_cfg, grads, opt_state,
                                                params)
        m = dict(m, loss=loss)
        return new_params, new_state, m

    return train_step


def _state_spec_for(path, leaf, mesh, opt_cfg, fsdp):
    names = [p.key for p in path
             if isinstance(p, jax.tree_util.DictKey)]
    if not hasattr(leaf, "ndim") or not names:   # step counter / static aux
        return P()
    if opt_cfg.quantized_state and names[-1] in ("q", "scale"):
        # Shape-preserving QTensor leaves: q mirrors the param's dims, so it
        # takes the PARAM's spec (the optimizer update is collective-free);
        # scale drops the last-dim sharding (its block dim is tiny).
        pspec = rules.param_spec(path[:-1], leaf, mesh, fsdp=fsdp)
        spec = list(pspec) + [None] * (leaf.ndim - len(pspec))
        if names[-1] == "scale":
            spec[-1] = None
        else:
            # q's padded last dim must still divide the assigned axis
            ax = spec[-1]
            sizes = {a: mesh.shape[a] for a in mesh.shape}
            def ax_ok(a):
                if a is None:
                    return True
                n = 1
                for x in (a if isinstance(a, tuple) else (a,)):
                    n *= sizes[x]
                return leaf.shape[-1] % n == 0
            if not ax_ok(ax):
                spec[-1] = None
        pspec = P(*spec)
        return rules.zero1_state_spec(pspec, leaf.shape, mesh)
    pspec = rules.param_spec(path, leaf, mesh, fsdp=fsdp)
    return rules.zero1_state_spec(pspec, leaf.shape, mesh)


def state_shardings(opt_cfg: adamw.AdamWConfig, params_shape, mesh: Mesh,
                    *, fsdp: bool = False):
    """NamedShardings for the optimizer state (ZeRO-1 over data; quantized
    moments flat-sharded over data x model)."""
    state_shape = jax.eval_shape(lambda p: adamw.init(opt_cfg, p),
                                 params_shape)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, _state_spec_for(path, leaf, mesh, opt_cfg, fsdp)),
        state_shape)


def make_train_step(model: LM, opt_cfg: adamw.AdamWConfig, mesh: Mesh,
                    n_micro: int = 1, donate: bool = True,
                    fsdp: bool = False):
    """Returns (jitted train_step, shardings dict). train_step(params,
    opt_state, batch) -> (params, opt_state, metrics)."""
    train_step = make_train_fn(model, opt_cfg, n_micro)

    def shardings(params_shape):
        pshard = rules.params_shardings(params_shape, mesh, fsdp=fsdp)
        sshard = state_shardings(opt_cfg, params_shape, mesh, fsdp=fsdp)
        return pshard, sshard

    jitted = jax.jit(train_step,
                     donate_argnums=(0, 1) if donate else ())
    return jitted, shardings


# --- shard_map DP path with int8 gradient compression ---------------------------
def make_compressed_dp_step(model: LM, opt_cfg: adamw.AdamWConfig,
                            mesh: Mesh):
    """Pure data-parallel train step where the gradient all-reduce goes
    through int8 error-feedback compression (optim.compress). Params are
    replicated; batch is sharded over 'data'. Demonstrates/tests the
    compression path; TP models use make_train_step."""
    axis = "data"

    def local_step(params, opt_state, err, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        grads, new_err = compress.tree_compressed_psum(grads, err, axis)
        loss = jax.lax.pmean(loss, axis)
        new_params, new_state, m = adamw.update(opt_cfg, grads, opt_state,
                                                params)
        return new_params, new_state, new_err, dict(m, loss=loss)

    rep = P()
    from jax.experimental.shard_map import shard_map
    smapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(rep, rep, rep, P(axis)),
        out_specs=(rep, rep, rep, rep),
        check_rep=False)
    return jax.jit(smapped)

"""Fault tolerance: supervised training with checkpoint/restart, straggler
detection, and TOPSIS-driven re-placement on degradation.

The supervisor wraps the train loop; any step may raise (hardware fault is
simulated by an injected callback in tests). Recovery = restore latest
checkpoint (elastically, onto whatever mesh is now available) and continue.
Straggler mitigation: per-step wall times feed an EWMA; a step slower than
`straggler_factor` x EWMA raises a StragglerAlert that the fleet layer
answers by re-running TOPSIS placement with a degraded health criterion for
the slow slice (repro.launch.fleet.replace_slice).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.train import checkpoint


class StragglerAlert(RuntimeError):
    def __init__(self, step: int, t: float, ewma: float):
        super().__init__(f"step {step}: {t:.3f}s vs ewma {ewma:.3f}s")
        self.step, self.t, self.ewma = step, t, ewma


@dataclasses.dataclass
class Supervisor:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    on_straggler: Callable[[StragglerAlert], None] | None = None

    def run(self, *, state: dict[str, Any], step_fn, data_fn, n_steps: int,
            fault_hook=None, shardings=None):
        """state: {"params": ..., "opt_state": ...}; step_fn(params,
        opt_state, batch) -> (params, opt_state, metrics); data_fn(step) ->
        batch. Returns (final state, history). fault_hook(step) may raise to
        simulate node failure."""
        restarts = 0
        pending: list = []
        history: list[dict] = []
        start = checkpoint.latest_step(self.ckpt_dir)
        step = 0
        if start is not None:
            state = checkpoint.restore(self.ckpt_dir, start, state,
                                       shardings)
            step = start
        ewma = None
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if fault_hook is not None:
                    fault_hook(step)
                batch = data_fn(step)
                p, o, m = step_fn(state["params"], state["opt_state"], batch)
                state = {"params": p, "opt_state": o}
                dt = time.perf_counter() - t0
                ewma = dt if ewma is None else (
                    self.ewma_alpha * dt + (1 - self.ewma_alpha) * ewma)
                if dt > self.straggler_factor * ewma and step > 2:
                    alert = StragglerAlert(step, dt, ewma)
                    if self.on_straggler:
                        self.on_straggler(alert)
                history.append({"step": step, "time_s": dt,
                                **{k: float(v) for k, v in m.items()}})
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    pending.append(checkpoint.save(
                        self.ckpt_dir, step, state, blocking=False))
            except StragglerAlert:
                raise
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                # land in-flight async saves before looking for the latest
                # checkpoint, else a crash right after a non-blocking save
                # restarts from a stale (or no) checkpoint
                for t in pending:
                    if t is not None:
                        t.join()
                pending.clear()
                last = checkpoint.latest_step(self.ckpt_dir)
                if last is not None:
                    state = checkpoint.restore(self.ckpt_dir, last, state,
                                               shardings)
                    step = last
                else:
                    step = 0
        # drain async writers, then a final blocking checkpoint so
        # restore-after-run is deterministic
        for t in pending:
            if t is not None:
                t.join()
        checkpoint.save(self.ckpt_dir, step, state, blocking=True)
        return state, history

"""Exporters: JSON snapshot, Prometheus text exposition, Perfetto trace.

Three operator-facing views of one run:

* :func:`json_snapshot` — the :class:`~repro.core.telemetry.Telemetry`
  registry as a JSON-ready dict (counters, gauges, histograms, optionally
  the raw span log).
* :func:`prometheus_text` — the same registry in the Prometheus text
  exposition format (the format the ROADMAP's online-serving status
  surface will serve); :func:`parse_prometheus` is the matching reader,
  used by the round-trip tests and usable by any scraper-side tooling.
* :func:`perfetto_trace` — a whole simulation timeline
  (:class:`~repro.cluster.engine.SimResult`) as Chrome trace-event JSON
  loadable in ``ui.perfetto.dev``: one track group per node carrying its
  task spans (one lane per concurrency level) and power-state intervals,
  plus one track per policy carrying its processed events as instants and
  counter tracks ("C" events) for the recorded power / queue / carbon
  series. :func:`validate_trace` checks the trace-event schema invariants
  the tests pin (known phases, sorted timestamps, matched B/E pairs per
  track, strictly increasing numeric counter samples).

Everything here reads sim state and telemetry; nothing writes back — the
exporters sit strictly on the observer side of the pure-observer
invariant.
"""
from __future__ import annotations

import json
import math
import re

_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Which policy track each kernel event kind belongs to (unknown kinds get
# a track of their own, so future policies' events surface unchanged).
_KIND_TRACKS = {
    "arrival": "kernel",
    "completion": "kernel",
    "carbon_check": "carbon",
    "wake_done": "autoscale",
    "consolidate_tick": "autoscale",
}


# --- JSON snapshot -----------------------------------------------------------
def json_snapshot(tel, include_spans: bool = False) -> dict:
    """The registry as a JSON-ready dict. ``include_spans`` appends the raw
    span log (name, labels, start offset, duration, nesting depth) — useful
    for debugging, omitted by default because it grows with the run."""
    out = tel.snapshot()
    if include_spans:
        out["span_log"] = list(tel.spans)
    return out


# --- Prometheus text exposition ----------------------------------------------
def _esc(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _labels_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_esc(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"metric name {name!r} is not a valid Prometheus "
                         f"name ([a-zA-Z_][a-zA-Z0-9_]*)")
    return name


def _fmt(value: float) -> str:
    if value == math.inf:
        return "+Inf"
    if value == -math.inf:
        return "-Inf"
    return repr(float(value))


def prometheus_text(tel) -> str:
    """The registry in the Prometheus text exposition format (version
    0.0.4): counters, gauges, and histograms with cumulative ``le``
    buckets plus ``_sum`` / ``_count`` series."""
    lines: list[str] = []
    seen_type: set[str] = set()

    def typeline(name: str, kind: str) -> None:
        if name not in seen_type:
            lines.append(f"# TYPE {_check_name(name)} {kind}")
            seen_type.add(name)

    for name, labels, value in tel.counters.values():
        typeline(name, "counter")
        lines.append(f"{name}{_labels_str(labels)} {_fmt(value)}")
    for g in tel.gauges.values():
        typeline(g.name, "gauge")
        lines.append(f"{g.name}{_labels_str(g.labels)} {_fmt(g.value)}")
    # gauge min/max/samples envelopes as companion families (each family
    # contiguous, per the exposition format's grouping rule)
    for suffix, attr in (("_min", "min"), ("_max", "max"),
                         ("_samples", "samples")):
        for g in tel.gauges.values():
            if not g.samples:
                continue
            typeline(f"{g.name}{suffix}", "gauge")
            lines.append(f"{g.name}{suffix}{_labels_str(g.labels)} "
                         f"{_fmt(getattr(g, attr))}")
    for h in tel.histograms.values():
        typeline(h.name, "histogram")
        ls = dict(h.labels)
        cum = h.cumulative()
        for edge, c in zip(h.edges, cum):
            lines.append(f"{h.name}_bucket"
                         f"{_labels_str({**ls, 'le': _fmt(edge)})} {c}")
        lines.append(f"{h.name}_bucket{_labels_str({**ls, 'le': '+Inf'})} "
                     f"{cum[-1]}")
        lines.append(f"{h.name}_sum{_labels_str(ls)} {_fmt(h.sum)}")
        lines.append(f"{h.name}_count{_labels_str(ls)} {h.count}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_][a-zA-Z0-9_]*)'
    r'(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)$')
_LABEL_RE = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)='
                       r'"(?P<v>(?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> dict:
    """Parse a text exposition back into ``{(name, ((k, v), ...)): value}``
    — the inverse of :func:`prometheus_text` (used by the round-trip tests;
    histogram series appear under their ``_bucket`` / ``_sum`` / ``_count``
    names)."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable exposition line: {line!r}")
        labels = {}
        if m.group("labels"):
            for lm in _LABEL_RE.finditer(m.group("labels")):
                labels[lm.group("k")] = (lm.group("v")
                                         .replace(r'\"', '"')
                                         .replace(r'\n', "\n")
                                         .replace(r'\\', "\\"))
        raw = m.group("value")
        value = math.inf if raw == "+Inf" else (
            -math.inf if raw == "-Inf" else float(raw))
        out[(m.group("name"), tuple(sorted(labels.items())))] = value
    return out


# --- Perfetto / Chrome trace-event export ------------------------------------
def _assign_lanes(spans: list[tuple[float, float, object]]) -> list[int]:
    """Greedy interval partitioning: spans (start, end, payload) sorted by
    start are packed into the fewest lanes such that no lane's spans
    overlap — each lane then carries strictly sequential spans, so B/E
    pairs nest trivially."""
    lane_end: list[float] = []
    lanes: list[int] = []
    for start, end, _ in spans:
        for li, le in enumerate(lane_end):
            if le <= start:
                lane_end[li] = end
                lanes.append(li)
                break
        else:
            lane_end.append(end)
            lanes.append(len(lane_end) - 1)
    return lanes


def perfetto_trace(result, trace_name: str = "scenario", tel=None) -> dict:
    """A :class:`~repro.cluster.engine.SimResult` as Chrome trace-event /
    Perfetto JSON (load at ``ui.perfetto.dev``).

    Layout: one process per node — thread 0 is its power-state track
    (IDLE / ASLEEP / WAKING intervals from the elastic state ledger, wake
    surges as instants), threads 1..L are task lanes (every record a B/E
    span named ``pod <uid> (<scheduler>)``, concurrency split across
    lanes so pairs always nest) — plus one "policies" process with one
    thread per policy track (kernel / carbon / autoscale) carrying the
    processed event log as instants, and one "counters" process whose "C"
    events render power / queue / carbon as Perfetto counter tracks.
    Counter values come from ``tel``'s recorded :class:`TimeSeries` when a
    telemetry registry is passed; otherwise the fleet power and carbon
    series are derived from the result's ledger, so every trace carries at
    least the power counter. Timestamps are simulation microseconds; the
    export never mutates the result."""
    timeline = result._timeline()
    node_names: set[str] = {r.node for r in result.records}
    node_names.update(iv.node for iv in timeline.state_intervals)
    node_names.update(w.node for w in timeline.wake_transitions)
    nodes = sorted(node_names)
    pid_of = {n: i + 1 for i, n in enumerate(nodes)}

    meta: list[dict] = []
    events: list[dict] = []

    def us(t: float) -> float:
        return t * 1e6

    def span(pid: int, tid: int, name: str, start: float, end: float,
             cat: str, args: dict | None = None) -> None:
        events.append({"ph": "B", "ts": us(start), "pid": pid, "tid": tid,
                       "name": name, "cat": cat, "args": args or {}})
        events.append({"ph": "E", "ts": us(end), "pid": pid, "tid": tid,
                       "name": name, "cat": cat})

    def instant(pid: int, tid: int, name: str, t: float, cat: str,
                args: dict | None = None) -> None:
        events.append({"ph": "i", "s": "t", "ts": us(t), "pid": pid,
                       "tid": tid, "name": name, "cat": cat,
                       "args": args or {}})

    for n in nodes:
        pid = pid_of[n]
        meta.append({"ph": "M", "pid": pid, "name": "process_name",
                     "args": {"name": f"node {n}"}})
        meta.append({"ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
                     "args": {"name": "power state"}})

    # task spans: one lane per concurrency level per node
    by_node: dict[str, list[tuple[float, float, object]]] = {}
    for r in result.records:
        if r.runtime_s > 0.0:
            by_node.setdefault(r.node, []).append(
                (r.start_s, r.start_s + r.runtime_s, r))
    for n, spans in by_node.items():
        spans.sort(key=lambda s: (s[0], s[1]))
        lanes = _assign_lanes(spans)
        for li in range(max(lanes) + 1):
            meta.append({"ph": "M", "pid": pid_of[n], "tid": 1 + li,
                         "name": "thread_name",
                         "args": {"name": f"tasks (lane {li})"}})
        for (start, end, r), li in zip(spans, lanes):
            span(pid_of[n], 1 + li, f"pod {r.pod.uid} ({r.pod.scheduler})",
                 start, end, "task",
                 {"energy_j": r.energy_j, "node_class": r.node_class,
                  "deferrable": r.pod.deferrable})

    # power-state intervals + wake surges on each node's power track
    for iv in timeline.state_intervals:
        span(pid_of[iv.node], 0, iv.state, iv.start_s, iv.end_s, "state",
             {"power_w": iv.power_w})
    for w in timeline.wake_transitions:
        instant(pid_of[w.node], 0, "wake surge", w.t_s, "state",
                {"energy_j": w.energy_j})

    # one track per policy carrying its processed events
    pol_pid = len(nodes) + 1
    meta.append({"ph": "M", "pid": pol_pid, "name": "process_name",
                 "args": {"name": "policies"}})
    tracks: dict[str, int] = {}
    for t, kind, payload in (result.events or []):
        track = _KIND_TRACKS.get(kind, kind)
        tid = tracks.get(track)
        if tid is None:
            tid = tracks[track] = len(tracks)
            meta.append({"ph": "M", "pid": pol_pid, "tid": tid,
                         "name": "thread_name", "args": {"name": track}})
        instant(pol_pid, tid, kind, t, "event",
                {} if payload is None else {"payload": payload})

    # counter tracks ("C" events): one per recorded series (or the
    # ledger-derived power/carbon series when no registry is passed)
    cnt_pid = len(nodes) + 2
    counter_series: list[tuple[str, list[tuple[float, float]]]] = []
    if tel is not None and getattr(tel, "timeseries", None):
        for s in tel.timeseries.values():
            name = s.name + _labels_str(s.labels)
            counter_series.append((name, s.points()))
    else:
        edges, watts = timeline.power_series(None)
        if len(edges):
            pts = [(float(t), float(w))
                   for t, w in zip(edges[:-1], watts)]
            pts.append((float(edges[-1]), float(watts[-1])))
            counter_series.append(("fleet_power_w", pts))
        if timeline.carbon_signal is not None:
            c_edges, grams = timeline.carbon_series(None)
            if len(c_edges):
                counter_series.append(
                    ("fleet_carbon_cum_g",
                     [(float(t), float(g))
                      for t, g in zip(c_edges, grams)]))
    if counter_series:
        meta.append({"ph": "M", "pid": cnt_pid, "name": "process_name",
                     "args": {"name": "counters"}})
        for name, pts in counter_series:
            for t, v in pts:
                events.append({"ph": "C", "ts": us(t), "pid": cnt_pid,
                               "tid": 0, "name": name, "cat": "counter",
                               "args": {"value": float(v)}})

    # sorted timestamps; at equal instants close spans before opening the
    # next one so back-to-back B/E pairs on a lane stay matched
    events.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "E" else 1))
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"name": trace_name}}


def write_perfetto(result, path, trace_name: str = "scenario",
                   tel=None) -> str:
    """Write :func:`perfetto_trace` JSON to ``path`` (conventionally
    ``*.trace.json``); returns the path."""
    trace = perfetto_trace(result, trace_name=trace_name, tel=tel)
    with open(path, "w") as f:
        json.dump(trace, f)
    return str(path)


_PHASES = frozenset("BEiMC")


def validate_trace(trace) -> dict:
    """Check the trace-event schema invariants: known phases, numeric
    non-negative timestamps, timestamps sorted over the non-metadata
    stream, per (pid, tid) track B/E pairs that match like parentheses
    with equal names and are all closed at the end, and — per
    (pid, tid, name) counter track — "C" events carrying a non-empty dict
    of finite numeric args with strictly increasing timestamps. Raises
    ``ValueError`` on the first violation; returns summary counts."""
    events = trace["traceEvents"] if isinstance(trace, dict) else trace
    last_ts = -math.inf
    stacks: dict[tuple, list] = {}
    counter_ts: dict[tuple, float] = {}
    n_spans = n_instants = n_counters = 0
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in _PHASES:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) \
                or ts < 0.0:
            raise ValueError(f"event {i}: bad ts {ts!r}")
        if ts < last_ts:
            raise ValueError(f"event {i}: ts {ts} < previous {last_ts} "
                             f"(trace not sorted)")
        last_ts = ts
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append(ev)
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(f"event {i}: E with no open B on track "
                                 f"{key}")
            b = stack.pop()
            if b.get("name") != ev.get("name"):
                raise ValueError(
                    f"event {i}: E name {ev.get('name')!r} does not match "
                    f"open B name {b.get('name')!r} on track {key}")
            n_spans += 1
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                raise ValueError(f"event {i}: counter with no args")
            for k, v in args.items():
                if isinstance(v, bool) or not isinstance(v, (int, float)) \
                        or not math.isfinite(v):
                    raise ValueError(f"event {i}: counter arg {k}={v!r} "
                                     f"is not a finite number")
            track = (ev.get("pid"), ev.get("tid"), ev.get("name"))
            prev = counter_ts.get(track)
            if prev is not None and ts <= prev:
                raise ValueError(
                    f"event {i}: counter track {track} ts {ts} <= "
                    f"previous {prev} (must be strictly increasing)")
            counter_ts[track] = ts
            n_counters += 1
        else:
            n_instants += 1
    open_tracks = {k: len(v) for k, v in stacks.items() if v}
    if open_tracks:
        raise ValueError(f"unclosed B events at end of trace: {open_tracks}")
    return {"events": len(events), "spans": n_spans,
            "instants": n_instants, "counters": n_counters,
            "tracks": len(stacks)}

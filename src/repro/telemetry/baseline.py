"""Cross-run benchmark regression gating.

A recorded ``BENCH_*.json`` (see ``benchmarks/``) is a list of result
cells — one dict per swept configuration, mixing identity keys (profile,
backend, n_nodes, ...) with measured metrics (timings, energies, carbon).
:func:`compare_reports` diffs a freshly produced report cell-by-cell
against a committed baseline under per-metric relative thresholds and
returns a verdict dict; ``benchmarks/run.py --check`` drives it and exits
nonzero on any regression.

Metric kinds:

* ``timing`` — wall-clock measurements, inherently noisy and one-sided:
  only a slowdown beyond the threshold trips (default +75% relative);
  a comparable speedup is flagged ``improved`` (informational). Timing
  comparisons are **provenance-aware**: a pallas cell measured in
  interpret mode is never compared against a compiled baseline (and vice
  versa), and a report whose ``jax_platform`` differs from the baseline's
  skips timing metrics entirely — those numbers describe different
  machines.
* ``exact`` — deterministic simulation outputs (energy kJ, carbon g,
  counts). Any relative drift beyond 1e-6 trips, in either direction:
  the simulator is bitwise-reproducible, so a "better" energy number in
  a bench sweep still means the physics changed.

Unknown float-valued cell keys are never silently dropped: they are
excluded from cell identity and listed in the verdict's
``unchecked_metrics`` so a new metric gets a threshold assigned instead
of drifting unwatched. Cells present on only one side land in
``missing_in_current`` / ``missing_in_baseline`` (warnings, not
failures — sweep grids legitimately grow).

:func:`append_history` / :func:`history_entries` maintain the
``benchmarks/history/`` JSONL trajectory: one line per recorded sweep or
check verdict, so the bench history is a queryable series rather than a
single overwritten snapshot.
"""
from __future__ import annotations

import json
import os

TIMING_DEFAULT_REL = 0.75      # one-sided: trips when >75% slower
EXACT_DEFAULT_REL = 1e-6       # two-sided drift bound

# metric name -> (kind, relative threshold); every measured key in the
# four BENCH_*.json shapes appears here, so anything numeric that doesn't
# is surfaced as unchecked rather than silently compared or dropped
METRICS: dict[str, tuple[str, float]] = {
    # BENCH_scheduling.json
    "ms_total": ("timing", TIMING_DEFAULT_REL),
    "us_per_pod": ("timing", TIMING_DEFAULT_REL),
    # BENCH_scenarios.json
    "energy_topsis_kj": ("exact", EXACT_DEFAULT_REL),
    "energy_default_kj": ("exact", EXACT_DEFAULT_REL),
    "dyn_energy_topsis_j": ("exact", EXACT_DEFAULT_REL),
    "idle_energy_topsis_j": ("exact", EXACT_DEFAULT_REL),
    "unschedulable_rate": ("exact", EXACT_DEFAULT_REL),
    "energy_series_points": ("exact", EXACT_DEFAULT_REL),
    "mean_sched_time_topsis_ms": ("timing", TIMING_DEFAULT_REL),
    "mean_sched_time_default_ms": ("timing", TIMING_DEFAULT_REL),
    # BENCH_carbon.json
    "carbon_topsis_g": ("exact", EXACT_DEFAULT_REL),
    "carbon_default_g": ("exact", EXACT_DEFAULT_REL),
    "carbon_series_points": ("exact", EXACT_DEFAULT_REL),
    "mean_deferral_latency_s": ("exact", EXACT_DEFAULT_REL),
    "preemptions": ("exact", EXACT_DEFAULT_REL),
    # BENCH_autoscale.json
    "fleet_energy_kj": ("exact", EXACT_DEFAULT_REL),
    "fleet_idle_energy_kj": ("exact", EXACT_DEFAULT_REL),
    "fleet_carbon_g": ("exact", EXACT_DEFAULT_REL),
    "horizon_s": ("exact", EXACT_DEFAULT_REL),
    "mean_start_delay_s": ("exact", EXACT_DEFAULT_REL),
    "mean_exec_time_topsis_s": ("exact", EXACT_DEFAULT_REL),
    "migrations": ("exact", EXACT_DEFAULT_REL),
    "sleeps": ("exact", EXACT_DEFAULT_REL),
    "wakes": ("exact", EXACT_DEFAULT_REL),
    # BENCH_pareto.json — timings one-sided; frontier membership is
    # backend-independent float64 arithmetic, gated exactly
    "ms_fused": ("timing", TIMING_DEFAULT_REL),
    "ms_serial": ("timing", TIMING_DEFAULT_REL),
    "us_per_scheme_fused": ("timing", TIMING_DEFAULT_REL),
    "frontier_size": ("exact", EXACT_DEFAULT_REL),
    "frontier_checksum": ("exact", EXACT_DEFAULT_REL),
}

# per-cell annotations that are neither identity nor gated metrics
IGNORED_KEYS = frozenset({
    "interpret_mode",              # provenance flag, consumed by gating
    "speedup_vs_rebuild",          # derived ratio of two timings
    "speedup_fused_vs_serial",     # derived ratio of two timings
    "max_closeness_err_vs_numpy",  # pinned by its own sweep tolerance
})


def cell_key(cell: dict) -> tuple:
    """A cell's identity: its non-metric, non-ignored keys — the swept
    configuration axes. Float-valued unknowns are excluded (they are
    almost certainly unregistered metrics, and float identity would make
    every comparison a miss)."""
    return tuple(sorted(
        (k, v) for k, v in cell.items()
        if k not in METRICS and k not in IGNORED_KEYS
        and not isinstance(v, float)))


def _unknown_metrics(cell: dict) -> list[str]:
    return [k for k, v in cell.items()
            if k not in METRICS and k not in IGNORED_KEYS
            and isinstance(v, float)]


def _fmt_key(key: tuple) -> str:
    return "/".join(f"{k}={v}" for k, v in key)


def _interpret_flag(cell: dict, provenance: dict) -> bool:
    """Effective interpret-mode flag for a cell's timing metrics: the
    per-cell annotation when present, else the report-level pallas flag
    for pallas cells (non-pallas backends always compile)."""
    if "interpret_mode" in cell:
        return bool(cell["interpret_mode"])
    if cell.get("backend") == "pallas":
        return bool(provenance.get("pallas_interpret", False))
    return False


def compare_reports(current: dict, baseline: dict,
                    thresholds: dict | None = None) -> dict:
    """Diff a fresh benchmark report against a baseline.

    Both arguments are parsed BENCH_*.json dicts (``results`` list plus
    optional ``provenance``). ``thresholds`` overrides per-metric
    relative thresholds by name. Returns the verdict dict described in
    the module docstring; ``verdict["status"]`` is ``"regression"`` iff
    at least one gated metric tripped."""
    cur_prov = current.get("provenance") or {}
    base_prov = baseline.get("provenance") or {}
    platform_gate = None
    if (cur_prov.get("jax_platform") and base_prov.get("jax_platform")
            and cur_prov["jax_platform"] != base_prov["jax_platform"]):
        platform_gate = (f"jax_platform {cur_prov['jax_platform']} != "
                         f"baseline {base_prov['jax_platform']}")

    cur_cells = {cell_key(c): c for c in current.get("results") or []}
    base_cells = {cell_key(c): c for c in baseline.get("results") or []}
    rows: list[dict] = []
    unchecked: set[str] = set()
    regressions = 0
    for key in sorted(cur_cells, key=_fmt_key):
        cur = cur_cells[key]
        unchecked.update(_unknown_metrics(cur))
        base = base_cells.get(key)
        if base is None:
            continue
        interp_skip = None
        cur_flag = _interpret_flag(cur, cur_prov)
        base_flag = _interpret_flag(base, base_prov)
        if cur_flag != base_flag:
            interp_skip = (f"interpret_mode {cur_flag} vs baseline "
                           f"{base_flag}")
        for metric, (kind, default_rel) in METRICS.items():
            if metric not in cur or metric not in base:
                continue
            thr = (thresholds or {}).get(metric, default_rel)
            cv, bv = float(cur[metric]), float(base[metric])
            rel = (cv - bv) / max(abs(bv), 1e-12)
            row = {"cell": _fmt_key(key), "metric": metric,
                   "current": cv, "baseline": bv, "rel_delta": rel,
                   "threshold": thr, "kind": kind, "status": "ok",
                   "reason": None}
            if kind == "timing" and platform_gate:
                row["status"], row["reason"] = "skipped", platform_gate
            elif kind == "timing" and interp_skip:
                row["status"], row["reason"] = "skipped", interp_skip
            elif kind == "timing":
                if rel > thr:
                    row["status"] = "regression"
                elif rel < -thr:
                    row["status"] = "improved"
            else:
                if abs(rel) > thr:
                    row["status"] = "regression"
            if row["status"] == "regression":
                regressions += 1
            rows.append(row)
    return {
        "bench": current.get("bench") or baseline.get("bench"),
        "status": "regression" if regressions else "pass",
        "regressions": regressions,
        "rows": rows,
        "missing_in_current": sorted(
            _fmt_key(k) for k in base_cells.keys() - cur_cells.keys()),
        "missing_in_baseline": sorted(
            _fmt_key(k) for k in cur_cells.keys() - base_cells.keys()),
        "unchecked_metrics": sorted(unchecked),
    }


def format_verdict(verdict: dict, verbose: bool = False) -> str:
    """Human-readable verdict: one headline, then every non-ok row (all
    rows with ``verbose``)."""
    counts: dict[str, int] = {}
    for row in verdict["rows"]:
        counts[row["status"]] = counts.get(row["status"], 0) + 1
    head = (f"[{verdict['status'].upper()}] {verdict['bench']}: "
            + ", ".join(f"{n} {s}" for s, n in sorted(counts.items()))
            if verdict["rows"] else
            f"[{verdict['status'].upper()}] {verdict['bench']}: "
            f"no comparable cells")
    lines = [head]
    for row in verdict["rows"]:
        if row["status"] == "ok" and not verbose:
            continue
        lines.append(
            f"  {row['status']:>10}  {row['cell']} {row['metric']}: "
            f"{row['current']:.6g} vs {row['baseline']:.6g} "
            f"({row['rel_delta']:+.2%}, limit {row['threshold']:g})"
            + (f" [{row['reason']}]" if row["reason"] else ""))
    for name, keys in (("missing_in_current",
                        verdict["missing_in_current"]),
                       ("missing_in_baseline",
                        verdict["missing_in_baseline"])):
        if keys:
            lines.append(f"  note: {len(keys)} cell(s) {name}")
    if verdict["unchecked_metrics"]:
        lines.append("  note: unchecked metrics (no threshold "
                     "registered): "
                     + ", ".join(verdict["unchecked_metrics"]))
    return "\n".join(lines)


# --- benchmark history (JSONL trajectory) ------------------------------------
def append_history(entry: dict, path) -> str:
    """Append one JSON line to the history file at ``path`` (parent
    directories created); returns the path."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return str(path)


def history_entries(path) -> list[dict]:
    """Parse a history JSONL back into a list of dicts (missing file is
    an empty history; malformed lines raise)."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]

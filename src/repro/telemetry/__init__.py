"""Telemetry exporters for the flight recorder.

The registry itself lives in ``repro.core.telemetry`` (a leaf module the
instrumented hot paths import); this package holds the operator-facing
output formats — JSON snapshot, Prometheus text exposition, the Chrome
trace-event / Perfetto export of a simulation timeline
(``repro.telemetry.export``), the self-contained HTML run report
(``repro.telemetry.report``), and the benchmark regression gate +
history trajectory (``repro.telemetry.baseline``).
"""
from repro.telemetry.baseline import (append_history,  # noqa: F401
                                      compare_reports, format_verdict,
                                      history_entries)
from repro.telemetry.export import (json_snapshot, parse_prometheus,  # noqa: F401
                                    perfetto_trace, prometheus_text,
                                    validate_trace, write_perfetto)
from repro.telemetry.report import (html_report,  # noqa: F401
                                    write_html_report)

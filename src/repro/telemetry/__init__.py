"""Telemetry exporters for the flight recorder.

The registry itself lives in ``repro.core.telemetry`` (a leaf module the
instrumented hot paths import); this package holds the operator-facing
output formats — JSON snapshot, Prometheus text exposition, and the
Chrome trace-event / Perfetto export of a simulation timeline
(``repro.telemetry.export``).
"""
from repro.telemetry.export import (json_snapshot, parse_prometheus,  # noqa: F401
                                    perfetto_trace, prometheus_text,
                                    validate_trace, write_perfetto)

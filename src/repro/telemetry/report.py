"""Self-contained operator HTML report for one simulation run.

:func:`html_report` renders a :class:`~repro.core.telemetry.Telemetry`
registry (and optionally the :class:`~repro.cluster.engine.SimResult` it
observed) into a single dependency-free HTML document: inline-SVG line
charts of every recorded sim-time series, decision-latency histograms,
TOPSIS explanation tables, and the counter/gauge registry. No JavaScript,
no external assets — the file opens anywhere, including as a CI artifact.

The markup is deliberately well-formed XML (every tag closed, only the
five predefined entities), so ``xml.etree.ElementTree`` can parse the
whole document — the tests pin that, which keeps the report honest about
escaping. Everything here reads telemetry and sim state; nothing writes
back (pure-observer invariant).

Chart styling follows a fixed design spec: categorical colors assigned in
slot order (never cycled, at most 8 label variants per chart with the
rest folded into a note), 2px round-join lines, hairline solid gridlines,
a legend only when a chart carries two or more series, and all text in
ink tokens — identity always comes from the colored mark beside the text.
Light and dark palettes are both declared; the browser's color scheme
picks one.
"""
from __future__ import annotations

import html
import math

from repro.telemetry.export import _labels_str

# fixed categorical slots (light, dark) — assigned in order, never cycled
_SERIES_LIGHT = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100",
                 "#e87ba4", "#008300", "#4a3aa7", "#e34948")
_SERIES_DARK = ("#3987e5", "#d95926", "#199e70", "#c98500",
                "#d55181", "#008300", "#9085e9", "#e66767")
MAX_CHART_SERIES = 8          # fold further label variants into a note
_HOVER_POINT_CAP = 120        # per-polyline invisible hover targets

_CSS = """
:root { color-scheme: light dark; }
* { box-sizing: border-box; }
body { margin: 0; font-family: system-ui, -apple-system, "Segoe UI",
       sans-serif; }
.viz-root {
  color-scheme: light;
  --page: #f9f9f7; --surface-1: #fcfcfb;
  --text-primary: #0b0b0b; --text-secondary: #52514e;
  --text-muted: #898781;
  --gridline: #e1e0d9; --baseline: #c3c2b7;
  --ring: rgba(11,11,11,0.10);
"""
_CSS += "".join(f"  --series-{i + 1}: {c};\n"
                for i, c in enumerate(_SERIES_LIGHT))
_CSS += """}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) .viz-root {
    color-scheme: dark;
    --page: #0d0d0d; --surface-1: #1a1a19;
    --text-primary: #ffffff; --text-secondary: #c3c2b7;
    --text-muted: #898781;
    --gridline: #2c2c2a; --baseline: #383835;
    --ring: rgba(255,255,255,0.10);
"""
_CSS += "".join(f"    --series-{i + 1}: {c};\n"
                for i, c in enumerate(_SERIES_DARK))
_CSS += """  }
}
.viz-root { background: var(--page); color: var(--text-primary);
            padding: 24px; max-width: 1060px; margin: 0 auto; }
h1 { font-size: 22px; font-weight: 600; margin: 0 0 4px; }
h2 { font-size: 15px; font-weight: 600; margin: 28px 0 10px; }
.sub { color: var(--text-secondary); font-size: 13px; margin: 0 0 16px; }
.card { background: var(--surface-1); border: 1px solid var(--ring);
        border-radius: 8px; padding: 14px 16px; margin: 0 0 14px; }
.tiles { display: flex; flex-wrap: wrap; gap: 12px; }
.tile { background: var(--surface-1); border: 1px solid var(--ring);
        border-radius: 8px; padding: 10px 14px; min-width: 120px; }
.tile .label { font-size: 12px; color: var(--text-secondary); }
.tile .value { font-size: 22px; font-weight: 600; }
.chart-title { font-size: 13px; font-weight: 600; margin: 0 0 2px; }
.legend { display: flex; flex-wrap: wrap; gap: 4px 14px;
          font-size: 12px; color: var(--text-secondary);
          margin: 4px 0 6px; }
.legend .key { display: inline-block; width: 14px; height: 3px;
               border-radius: 2px; vertical-align: middle;
               margin-right: 5px; }
.note { font-size: 12px; color: var(--text-muted); margin: 4px 0 0; }
table { border-collapse: collapse; font-size: 12.5px; width: 100%; }
th { text-align: left; color: var(--text-secondary); font-weight: 600;
     border-bottom: 1px solid var(--baseline); padding: 4px 10px 4px 0; }
td { border-bottom: 1px solid var(--gridline); padding: 4px 10px 4px 0;
     font-variant-numeric: tabular-nums; }
svg text { font-family: system-ui, -apple-system, "Segoe UI", sans-serif; }
"""


def _esc(s) -> str:
    return html.escape(str(s), quote=True)


def _num(v: float) -> str:
    """Compact human number for labels and table cells."""
    if v is None or (isinstance(v, float) and not math.isfinite(v)):
        return "-"
    a = abs(v)
    if a >= 1e6:
        return f"{v / 1e6:.4g}M"
    if a >= 1e4:
        return f"{v / 1e3:.4g}K"
    if a != 0.0 and a < 1e-3:
        return f"{v:.2e}"
    return f"{v:.4g}"


def _slot(i: int) -> str:
    return f"var(--series-{i + 1})"


def _ticks(lo: float, hi: float, n: int = 4) -> list[float]:
    """Clean-ish tick values covering [lo, hi]."""
    if hi <= lo:
        return [lo]
    span = hi - lo
    step = 10.0 ** math.floor(math.log10(span / n))
    for mult in (1.0, 2.0, 2.5, 5.0, 10.0):
        if span / (step * mult) <= n:
            step *= mult
            break
    first = math.ceil(lo / step) * step
    out = []
    t = first
    while t <= hi + 1e-12 * span:
        out.append(t)
        t += step
    return out or [lo]


def _line_chart(title: str, variants: list[tuple[str, list[float],
                                                 list[float]]],
                unit_hint: str = "") -> str:
    """One inline-SVG line chart: ``variants`` is a list of
    ``(legend_label, times, values)`` with at most
    :data:`MAX_CHART_SERIES` entries (the caller folds the rest)."""
    W, H = 960, 230
    ml, mr, mt, mb = 56, 12, 8, 24
    pw, ph = W - ml - mr, H - mt - mb
    all_t = [t for _, ts, _ in variants for t in ts]
    all_v = [v for _, _, vs in variants for v in vs]
    t0, t1 = min(all_t), max(all_t)
    v0, v1 = min(all_v), max(all_v)
    if v1 <= v0:
        v0, v1 = v0 - 1.0, v1 + 1.0
    if t1 <= t0:
        t1 = t0 + 1.0
    v0 = min(v0, 0.0) if v0 > 0 and v0 < 0.25 * v1 else v0
    pad = 0.06 * (v1 - v0)
    v1 += pad
    if v0 != 0.0:
        v0 -= pad

    def x(t):
        return ml + pw * (t - t0) / (t1 - t0)

    def y(v):
        return mt + ph * (1.0 - (v - v0) / (v1 - v0))

    parts = [f'<svg viewBox="0 0 {W} {H}" width="100%" height="{H}" '
             f'role="img" aria-label="{_esc(title)}">']
    # hairline gridlines + y ticks (muted ink, never the series color)
    for tv in _ticks(v0, v1):
        yy = y(tv)
        parts.append(f'<line x1="{ml}" y1="{yy:.1f}" x2="{W - mr}" '
                     f'y2="{yy:.1f}" stroke="var(--gridline)" '
                     f'stroke-width="1"/>')
        parts.append(f'<text x="{ml - 6}" y="{yy + 3.5:.1f}" '
                     f'text-anchor="end" font-size="11" '
                     f'fill="var(--text-muted)">{_esc(_num(tv))}</text>')
    # x axis baseline + end ticks (sim seconds)
    parts.append(f'<line x1="{ml}" y1="{mt + ph}" x2="{W - mr}" '
                 f'y2="{mt + ph}" stroke="var(--baseline)" '
                 f'stroke-width="1"/>')
    for tt, anchor in ((t0, "start"), (t1, "end")):
        parts.append(f'<text x="{x(tt):.1f}" y="{H - 7}" '
                     f'text-anchor="{anchor}" font-size="11" '
                     f'fill="var(--text-muted)">'
                     f'{_esc(_num(tt))}s</text>')
    for si, (label, ts, vs) in enumerate(variants):
        color = _slot(si)
        pts = " ".join(f"{x(t):.1f},{y(v):.1f}" for t, v in zip(ts, vs))
        if len(ts) == 1:
            parts.append(f'<circle cx="{x(ts[0]):.1f}" '
                         f'cy="{y(vs[0]):.1f}" r="4" fill="{color}" '
                         f'stroke="var(--surface-1)" stroke-width="2"/>')
        else:
            parts.append(f'<polyline points="{pts}" fill="none" '
                         f'stroke="{color}" stroke-width="2" '
                         f'stroke-linejoin="round" '
                         f'stroke-linecap="round"/>')
            # end-marker with a surface ring so it reads over the line
            parts.append(f'<circle cx="{x(ts[-1]):.1f}" '
                         f'cy="{y(vs[-1]):.1f}" r="4" fill="{color}" '
                         f'stroke="var(--surface-1)" stroke-width="2"/>')
        # invisible hover targets carrying native tooltips
        stride = max(1, len(ts) // _HOVER_POINT_CAP)
        for t, v in list(zip(ts, vs))[::stride]:
            parts.append(f'<circle cx="{x(t):.1f}" cy="{y(v):.1f}" '
                         f'r="7" fill="transparent">'
                         f'<title>{_esc(label)}: {_esc(_num(v))}'
                         f'{_esc(unit_hint)} at t={_esc(_num(t))}s'
                         f'</title></circle>')
    parts.append("</svg>")
    return "".join(parts)


def _bar_chart(edges, counts) -> str:
    """Histogram bars over bucket index (log-spaced latency edges, plus
    the +Inf overflow bucket): rounded at the data end, square at the
    baseline."""
    W, H = 960, 170
    ml, mr, mt, mb = 56, 12, 8, 34
    pw, ph = W - ml - mr, H - mt - mb
    labels = [_num(e) for e in edges] + ["+Inf"] * (len(counts)
                                                    - len(edges))
    n = len(counts)
    peak = max(counts) or 1
    bw = min(24.0, pw / n - 2.0)
    parts = [f'<svg viewBox="0 0 {W} {H}" width="100%" height="{H}" '
             f'role="img" aria-label="latency histogram">']
    for tv in _ticks(0, peak, 3):
        yy = mt + ph * (1.0 - tv / peak)
        parts.append(f'<line x1="{ml}" y1="{yy:.1f}" x2="{W - mr}" '
                     f'y2="{yy:.1f}" stroke="var(--gridline)" '
                     f'stroke-width="1"/>')
        parts.append(f'<text x="{ml - 6}" y="{yy + 3.5:.1f}" '
                     f'text-anchor="end" font-size="11" '
                     f'fill="var(--text-muted)">{_esc(_num(tv))}</text>')
    parts.append(f'<line x1="{ml}" y1="{mt + ph}" x2="{W - mr}" '
                 f'y2="{mt + ph}" stroke="var(--baseline)" '
                 f'stroke-width="1"/>')
    lbl_stride = max(1, n // 8)
    for i, c in enumerate(counts):
        cx = ml + pw * (i + 0.5) / n
        x0 = cx - bw / 2.0
        h = ph * c / peak
        ytop = mt + ph - h
        if c:
            r = min(4.0, bw / 2.0, h)
            parts.append(
                f'<path d="M{x0:.1f},{mt + ph:.1f} '
                f'L{x0:.1f},{ytop + r:.1f} '
                f'Q{x0:.1f},{ytop:.1f} {x0 + r:.1f},{ytop:.1f} '
                f'L{x0 + bw - r:.1f},{ytop:.1f} '
                f'Q{x0 + bw:.1f},{ytop:.1f} {x0 + bw:.1f},{ytop + r:.1f} '
                f'L{x0 + bw:.1f},{mt + ph:.1f} Z" fill="{_slot(0)}">'
                f'<title>&#8804; {_esc(labels[i])}s: {c}</title>'
                f'</path>')
        if i % lbl_stride == 0:
            parts.append(f'<text x="{cx:.1f}" y="{H - 7}" '
                         f'text-anchor="middle" font-size="10" '
                         f'fill="var(--text-muted)">'
                         f'{_esc(labels[i])}</text>')
    parts.append("</svg>")
    return "".join(parts)


def _scatter_chart(xlabel: str, ylabel: str,
                   points: list[tuple[float, float, str, bool]]) -> str:
    """Inline-SVG scatter of one frontier: ``points`` is
    ``(x, y, tooltip, is_dominant)``; the dominant pick renders in the
    second categorical slot with a surface ring, everything else in the
    first."""
    W, H = 960, 230
    ml, mr, mt, mb = 56, 12, 8, 34
    pw, ph = W - ml - mr, H - mt - mb
    xs = [p[0] for p in points]
    ys = [p[1] for p in points]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    if x1 <= x0:
        x0, x1 = x0 - 1.0, x1 + 1.0
    if y1 <= y0:
        y0, y1 = y0 - 1.0, y1 + 1.0
    xpad, ypad = 0.05 * (x1 - x0), 0.08 * (y1 - y0)
    x0, x1 = x0 - xpad, x1 + xpad
    y0, y1 = y0 - ypad, y1 + ypad

    def x(v):
        return ml + pw * (v - x0) / (x1 - x0)

    def y(v):
        return mt + ph * (1.0 - (v - y0) / (y1 - y0))

    parts = [f'<svg viewBox="0 0 {W} {H}" width="100%" height="{H}" '
             f'role="img" aria-label="{_esc(xlabel)} vs {_esc(ylabel)} '
             f'frontier">']
    for tv in _ticks(y0, y1):
        yy = y(tv)
        parts.append(f'<line x1="{ml}" y1="{yy:.1f}" x2="{W - mr}" '
                     f'y2="{yy:.1f}" stroke="var(--gridline)" '
                     f'stroke-width="1"/>')
        parts.append(f'<text x="{ml - 6}" y="{yy + 3.5:.1f}" '
                     f'text-anchor="end" font-size="11" '
                     f'fill="var(--text-muted)">{_esc(_num(tv))}</text>')
    parts.append(f'<line x1="{ml}" y1="{mt + ph}" x2="{W - mr}" '
                 f'y2="{mt + ph}" stroke="var(--baseline)" '
                 f'stroke-width="1"/>')
    for tv in _ticks(x0, x1):
        parts.append(f'<text x="{x(tv):.1f}" y="{H - 18}" '
                     f'text-anchor="middle" font-size="11" '
                     f'fill="var(--text-muted)">{_esc(_num(tv))}</text>')
    parts.append(f'<text x="{ml + pw / 2:.1f}" y="{H - 4}" '
                 f'text-anchor="middle" font-size="11" '
                 f'fill="var(--text-secondary)">{_esc(xlabel)} &#8594; '
                 f'(lower is better; y: {_esc(ylabel)})</text>')
    # dominated-into-front ordering: plain points first, dominant on top
    for px, py, tip, dom in sorted(points, key=lambda p: p[3]):
        if dom:
            parts.append(f'<circle cx="{x(px):.1f}" cy="{y(py):.1f}" '
                         f'r="6" fill="{_slot(1)}" '
                         f'stroke="var(--surface-1)" stroke-width="2">'
                         f'<title>{_esc(tip)}</title></circle>')
        else:
            parts.append(f'<circle cx="{x(px):.1f}" cy="{y(py):.1f}" '
                         f'r="3.5" fill="{_slot(0)}" fill-opacity="0.8">'
                         f'<title>{_esc(tip)}</title></circle>')
    parts.append("</svg>")
    return "".join(parts)


_FRONTIER_TABLE_CAP = 20      # frontier rows shown per regime table


def _frontier_section(frontier: dict) -> list[str]:
    """The Pareto-frontier cards: per regime, a scatter of the frontier
    over the first two metric axes (dominant pick highlighted) and the
    top frontier rows. ``frontier`` is
    ``repro.core.pareto.FrontierAtlas.to_report()`` payload —
    regime -> {metrics, n_schemes, n_front, dominant, front}."""
    body: list[str] = []
    body.append("<h2>Pareto frontier</h2>")
    for regime, data in sorted(frontier.items()):
        metrics = list(data.get("metrics") or [])
        front = list(data.get("front") or [])
        dom = data.get("dominant") or {}
        body.append('<div class="card">')
        body.append(f'<p class="chart-title">{_esc(regime)}</p>')
        body.append(
            f'<p class="sub">{_esc(data.get("n_front", len(front)))} '
            f'Pareto-optimal of {_esc(data.get("n_schemes", "?"))} '
            f'schemes &#183; dominant pick: scheme '
            f'#{_esc(dom.get("index", "?"))}'
            + (f' ({_esc(dom["name"])})' if dom.get("name") else "")
            + "</p>")
        if len(metrics) >= 2 and front:
            mx, my = metrics[0], metrics[1]
            pts = []
            for p in front:
                pm = p.get("metrics") or {}
                tip = (f'#{p.get("index")} '
                       + " ".join(f"{k}={_num(float(v))}"
                                  for k, v in pm.items()))
                pts.append((float(pm[mx]), float(pm[my]), tip,
                            p.get("index") == dom.get("index")))
            body.append(_scatter_chart(mx, my, pts))
        if front:
            body.append("<table>")
            body.append("<tr><th>#</th><th>name</th><th>weights</th>"
                        + "".join(f"<th>{_esc(m)}</th>" for m in metrics)
                        + "</tr>")
            for p in front[:_FRONTIER_TABLE_CAP]:
                pm = p.get("metrics") or {}
                w = ", ".join(_num(float(v))
                              for v in (p.get("weights") or []))
                mark = " &#9733;" if p.get("index") == dom.get("index") \
                    else ""
                body.append(
                    f'<tr><td>{_esc(p.get("index"))}{mark}</td>'
                    f'<td>{_esc(p.get("name") or "-")}</td>'
                    f'<td>{_esc(w)}</td>'
                    + "".join(f"<td>{_esc(_num(float(pm.get(m))))}</td>"
                              if pm.get(m) is not None else "<td>-</td>"
                              for m in metrics)
                    + "</tr>")
            body.append("</table>")
            if len(front) > _FRONTIER_TABLE_CAP:
                body.append(f'<p class="note">showing '
                            f'{_FRONTIER_TABLE_CAP} of {len(front)} '
                            f'frontier schemes</p>')
        body.append("</div>")
    return body


def _series_groups(tel) -> dict[str, list]:
    groups: dict[str, list] = {}
    for s in tel.timeseries.values():
        groups.setdefault(s.name, []).append(s)
    return {name: sorted(cells, key=lambda s: sorted(s.labels.items()))
            for name, cells in sorted(groups.items())}


def _tiles(summary: dict) -> str:
    tiles = [("Pods placed", summary.get("pods")),
             ("Unschedulable rate", summary.get("unschedulable_rate")),
             ("Preemptions", summary.get("preemptions")),
             ("Migrations", summary.get("migrations")),
             ("Wakes", summary.get("wakes")),
             ("Sleeps", summary.get("sleeps"))]
    for sched, row in sorted(summary.get("schedulers", {}).items()):
        tiles.append((f"{sched} energy (kJ)", row.get("energy_kj")))
    out = ['<div class="tiles">']
    for label, value in tiles:
        if value is None:
            continue
        shown = _num(float(value)) if isinstance(value, (int, float)) \
            else _esc(value)
        out.append(f'<div class="tile"><div class="label">{_esc(label)}'
                   f'</div><div class="value">{shown}</div></div>')
    out.append("</div>")
    return "".join(out)


def html_report(tel=None, result=None, title: str = "GreenPod run report",
                provenance: dict | None = None,
                frontier: dict | None = None) -> str:
    """Render the run as one self-contained HTML document (returned as a
    string). ``tel`` supplies the recorded registry (series, histograms,
    counters, gauges); ``result`` supplies the summary tiles and TOPSIS
    explanations; ``frontier`` (a
    ``repro.core.pareto.FrontierAtlas.to_report()`` payload) adds a
    Pareto-frontier table + scatter section per regime. Any may be
    omitted; the corresponding sections collapse to a note."""
    body: list[str] = []
    body.append(f"<h1>{_esc(title)}</h1>")
    if provenance:
        keys = ("git_sha", "platform", "jax_platform", "utc_timestamp")
        frag = " &#183; ".join(f"{_esc(k)} {_esc(provenance[k])}"
                               for k in keys if provenance.get(k))
        body.append(f'<p class="sub">{frag}</p>')
    else:
        body.append('<p class="sub">Simulation-clock telemetry report '
                    '&#8212; all timestamps are sim seconds.</p>')

    if result is not None:
        body.append("<h2>Run summary</h2>")
        body.append(_tiles(result.summary()))

    if frontier:
        body.extend(_frontier_section(frontier))

    body.append("<h2>Timelines</h2>")
    groups = _series_groups(tel) if tel is not None else {}
    if not groups:
        body.append('<p class="note">No time series recorded (run with '
                    'telemetry enabled to capture timelines).</p>')
    for name, cells in groups.items():
        shown = cells[:MAX_CHART_SERIES]
        folded = len(cells) - len(shown)
        variants = []
        for s in shown:
            label = (_labels_str(s.labels)[1:-1] if s.labels
                     else name)
            variants.append((label, list(s.times), list(s.values)))
        body.append('<div class="card">')
        body.append(f'<p class="chart-title">{_esc(name)}</p>')
        if len(variants) >= 2:
            legend = "".join(
                f'<span><span class="key" style="background:{_slot(i)}">'
                f'</span>{_esc(label)}</span>'
                for i, (label, _, _) in enumerate(variants))
            body.append(f'<div class="legend">{legend}</div>')
        body.append(_line_chart(name, variants))
        if folded:
            body.append(f'<p class="note">+{folded} more label '
                        f'variant{"s" if folded > 1 else ""} not charted '
                        f'(see the series table below).</p>')
        body.append("</div>")

    hists = sorted(tel.histograms.values(),
                   key=lambda h: (h.name, sorted(h.labels.items()))) \
        if tel is not None else []
    if hists:
        body.append("<h2>Decision latency</h2>")
        for h in hists:
            body.append('<div class="card">')
            label = f"{h.name}{_labels_str(h.labels)}"
            body.append(f'<p class="chart-title">{_esc(label)}</p>')
            body.append(f'<p class="sub">count {h.count} &#183; mean '
                        f'{_esc(_num(h.sum / h.count if h.count else 0.0))}'
                        f's &#183; bucket upper bounds in seconds</p>')
            body.append(_bar_chart(list(h.edges), list(h.counts)))
            body.append("</div>")

    explanations = getattr(result, "explanations", None) if result else None
    if explanations:
        body.append("<h2>TOPSIS decisions</h2>")
        body.append('<div class="card"><table>')
        body.append("<tr><th>t (s)</th><th>pod</th><th>node</th>"
                    "<th>runner-up</th><th>gap</th>"
                    "<th>top criterion</th></tr>")
        for exp in explanations[:50]:
            contribs = exp.get("contributions") or []
            top = max(contribs, key=lambda c: abs(c["delta_cc"]),
                      default=None)
            top_s = (f"{top['criterion']} ({_num(top['delta_cc'])})"
                     if top else "-")
            body.append(
                f"<tr><td>{_esc(_num(exp.get('t', 0.0)))}</td>"
                f"<td>{_esc(exp.get('pod'))}</td>"
                f"<td>{_esc(exp.get('node'))}</td>"
                f"<td>{_esc(exp.get('runner_up_node') or '-')}</td>"
                f"<td>{_esc(_num(exp.get('gap')))}</td>"
                f"<td>{_esc(top_s)}</td></tr>")
        body.append("</table>")
        if len(explanations) > 50:
            body.append(f'<p class="note">showing 50 of '
                        f'{len(explanations)} decisions</p>')
        body.append("</div>")

    if tel is not None and (tel.counters or tel.gauges):
        body.append("<h2>Registry</h2>")
        body.append('<div class="card"><table>')
        body.append("<tr><th>metric</th><th>value</th><th>min</th>"
                    "<th>max</th><th>samples</th></tr>")
        for name, labels, value in sorted(
                tel.counters.values(),
                key=lambda c: (c[0], sorted(c[1].items()))):
            body.append(f"<tr><td>{_esc(name + _labels_str(labels))}</td>"
                        f"<td>{_esc(_num(value))}</td>"
                        f"<td>-</td><td>-</td><td>-</td></tr>")
        for g in sorted(tel.gauges.values(),
                        key=lambda g: (g.name, sorted(g.labels.items()))):
            body.append(
                f"<tr><td>{_esc(g.name + _labels_str(g.labels))}</td>"
                f"<td>{_esc(_num(g.value))}</td>"
                f"<td>{_esc(_num(g.min))}</td>"
                f"<td>{_esc(_num(g.max))}</td>"
                f"<td>{g.samples}</td></tr>")
        body.append("</table></div>")

    if groups:
        # the table view: every series, including folded variants
        body.append("<h2>Series table</h2>")
        body.append('<div class="card"><table>')
        body.append("<tr><th>series</th><th>points</th><th>samples</th>"
                    "<th>first t</th><th>last t</th><th>last value</th>"
                    "<th>min</th><th>max</th></tr>")
        for name, cells in groups.items():
            for s in cells:
                body.append(
                    f"<tr><td>{_esc(s.name + _labels_str(s.labels))}</td>"
                    f"<td>{len(s)}</td><td>{s.samples}</td>"
                    f"<td>{_esc(_num(s.times[0]))}</td>"
                    f"<td>{_esc(_num(s.times[-1]))}</td>"
                    f"<td>{_esc(_num(s.values[-1]))}</td>"
                    f"<td>{_esc(_num(min(s.values)))}</td>"
                    f"<td>{_esc(_num(max(s.values)))}</td></tr>")
        body.append("</table></div>")

    return ('<html><head><meta charset="utf-8"/>'
            f"<title>{_esc(title)}</title>"
            f"<style>{_CSS}</style></head>"
            f'<body><div class="viz-root">{"".join(body)}</div>'
            "</body></html>")


def write_html_report(path, tel=None, result=None,
                      title: str = "GreenPod run report",
                      provenance: dict | None = None,
                      frontier: dict | None = None) -> str:
    """Write :func:`html_report` to ``path``; returns the path."""
    doc = html_report(tel=tel, result=result, title=title,
                      provenance=provenance, frontier=frontier)
    with open(path, "w") as f:
        f.write(doc)
    return str(path)

"""JAX version compatibility shims.

The repo pins jax 0.4.37 (the jaxlib baked into the container); several
sharding APIs the model/launch layers rely on only exist in jax >= 0.5:

  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)``
  * ``jax.sharding.get_abstract_mesh()`` (ambient-mesh lookup)
  * ``jax.shard_map`` (top-level, with ``check_vma``)
  * ``jax.set_mesh``

Each shim dispatches on feature presence (never on version strings) and
degrades to the 0.4.x equivalent: the ``with mesh:`` thread-local for
ambient-mesh lookup and ``jax.experimental.shard_map`` for shard_map.
"""
from __future__ import annotations

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def axis_types_kwarg(n_axes: int) -> dict:
    """kwargs for ``jax.make_mesh``: explicit Auto axis types where the
    installed jax supports them, nothing otherwise (0.4.x meshes are
    implicitly Auto on every axis)."""
    if _HAS_AXIS_TYPE:
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def get_abstract_mesh():
    """Ambient mesh, or None when no mesh is installed.

    jax >= 0.5 exposes this directly (normalized here to None when the
    abstract mesh is empty); 0.4.x falls back to the mesh installed by the
    ``with mesh:`` context manager. Either way the result supports
    ``.axis_names`` and ``.shape[axis]``."""
    if _HAS_GET_ABSTRACT_MESH:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return None
        return m
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def set_mesh(mesh) -> None:
    """``jax.set_mesh`` where available; a no-op on 0.4.x, where the
    ``with mesh:`` context (which every caller also enters) is the only
    ambient-mesh mechanism."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)


def shard_map(f, *, mesh=None, in_specs, out_specs):
    """``jax.shard_map(..., check_vma=False)`` on new jax;
    ``jax.experimental.shard_map.shard_map(..., check_rep=False)`` on 0.4.x
    (same replication-check escape hatch under its earlier name).

    ``mesh=None`` uses the ambient mesh."""
    if _HAS_TOP_LEVEL_SHARD_MAP:
        kw = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             check_vma=False, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if mesh is None:
        mesh = get_abstract_mesh()
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)

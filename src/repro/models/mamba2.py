"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrence for decode. Used by zamba2's hybrid stack.

State-space: h_t = exp(A*dt_t) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t h_t + D x_t
with per-head scalar A (Mamba2 restriction), B/C shared across heads
(n_groups=1), head dim P, state dim N.

Train/prefill uses the SSD chunked algorithm (intra-chunk quadratic attention
form + inter-chunk state recurrence via scan over chunks), which maps to MXU
matmuls instead of a length-S sequential scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, dot, rmsnorm


def mamba2_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_init(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    d_inner, H, P, N = mamba2_dims(cfg)
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    # input projections split by TP semantics: z/x shard over heads (model
    # axis), B/C are head-shared (replicated), dt is per-head.
    return {
        "in_zx": dense_init(ks[0], D, 2 * d_inner, dt),
        "in_bc": dense_init(ks[5], D, 2 * N, dt),
        "in_dt": dense_init(ks[3], D, H, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner + 2 * N),
                                     jnp.float32) * 0.2).astype(dt),
        "a_log": jnp.zeros((H,), jnp.float32),        # A = -exp(a_log)
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_g": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, D, dt),
    }


def _ssd_chunked(x, dt_, A, B, C, chunk: int):
    """SSD chunked scan.
    x: (b, s, h, p); dt_: (b, s, h) >0; A: (h,) <0; B, C: (b, s, n).
    Returns y: (b, s, h, p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt_.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    da = dtc * A                                    # (b,nc,l,h) log-decay
    cum = jnp.cumsum(da, axis=2)                    # within-chunk cumsum
    # intra-chunk ("attention") term: L[i,j] = exp(cum_i - cum_j) for i>=j
    li = cum[:, :, :, None, :]                      # (b,nc,i,1,h)
    lj = cum[:, :, None, :, :]                      # (b,nc,1,j,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    Lmat = jnp.where(mask, jnp.exp(li - lj), 0.0)   # (b,nc,i,j,h)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                    preferred_element_type=jnp.float32)      # (b,nc,i,j)
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                         CB, Lmat, dtc, xc.astype(jnp.float32))

    # chunk-final states: S_c = sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (b,nc,l,h)
    states = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchnp",
                        decay_to_end, dtc, Bc, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))               # (b,nc,h)

    def step(h_prev, inp):
        st, dec = inp                                        # (b,h,n,p),(b,h)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    init = jnp.zeros((b, h, n, p), jnp.float32)
    h_final, h_in = jax.lax.scan(step,
                                 init,
                                 (states.transpose(1, 0, 2, 3, 4),
                                  chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                     # (b,nc,h,n,p)

    # inter-chunk contribution: y_i += C_i exp(cum_i) h_in
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cc, jnp.exp(cum), h_in)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), h_final


def mamba2_forward(p: Params, cfg: ModelConfig, x, *, chunk: int = 256,
                   state=None, return_state: bool = False):
    """x: (B, S, D). state None -> chunked parallel path (train; prefill when
    return_state=True, which also emits the post-prompt decode state);
    state dict -> single-step decode (S==1), returns (y, state')."""
    B, S, D = x.shape
    d_inner, H, P, N = mamba2_dims(cfg)
    zx = dot(x, p["in_zx"])
    z, xin = jnp.split(zx, [d_inner], axis=-1)
    bc = dot(x, p["in_bc"])
    Bc, Cc = jnp.split(bc, [N], axis=-1)
    dt_ = dot(x, p["in_dt"])
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)        # (B,S,din+2N)

    if state is None:
        # causal depthwise conv via explicit pad + stacked shifts
        k = cfg.ssm_conv
        padded = jnp.pad(conv_in, ((0, 0), (k - 1, 0), (0, 0)))
        conv = sum(padded[:, i:i + S, :] * p["conv_w"][i].astype(x.dtype)
                   for i in range(k))
        conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
        xin, Bc, Cc = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
        dt_ = jax.nn.softplus(dt_.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["a_log"])
        chunk = min(chunk, S)
        pad = (-S) % chunk
        if pad:
            xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
            Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
            Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
            dt_ = jnp.pad(dt_, ((0, 0), (0, pad), (0, 0)))
        xh = xin.reshape(B, S + pad, H, P)
        y, h_final = _ssd_chunked(xh, dt_, A, Bc, Cc, min(chunk, S + pad))
        y = y[:, :S]
        y = y + xh[:, :S] * p["d_skip"][None, None, :, None].astype(x.dtype)
        y = y.reshape(B, S, d_inner)
        y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    p["norm_g"], cfg.norm_eps)
        out = dot(y, p["out_proj"])
        if not return_state:
            return out, None
        # decode state after the prompt: final ssm state + last conv inputs.
        # h_final from the scan is the state AFTER the last chunk; with pad>0
        # the padded tail (x=0, dt>0) would spuriously decay it, so prefill
        # lengths must be chunk-aligned (all assigned shapes are).
        assert pad == 0, "prefill length must be a multiple of the ssd chunk"
        k = cfg.ssm_conv
        tail = conv_in[:, -(k):, :] if S >= k else jnp.pad(
            conv_in, ((0, 0), (k - S, 0), (0, 0)))
        return out, {"conv": tail, "ssm": h_final}

    # --- decode: S == 1, O(1) state update ---
    conv_buf = jnp.concatenate([state["conv"][:, 1:, :], conv_in], axis=1)
    conv = jnp.sum(conv_buf * p["conv_w"].astype(x.dtype)[None], axis=1,
                   keepdims=True)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xin, Bc, Cc = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
    dt1 = jax.nn.softplus(dt_.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["a_log"])
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    dec = jnp.exp(dt1 * A)                                   # (B,H)
    h_new = (state["ssm"] * dec[..., None, None]
             + jnp.einsum("bh,bn,bhp->bhnp", dt1, Bc[:, 0].astype(jnp.float32),
                          xh))
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), h_new)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_g"], cfg.norm_eps)
    return dot(y, p["out_proj"]), {"conv": conv_buf, "ssm": h_new}


def mamba2_init_state(cfg: ModelConfig, batch: int):
    d_inner, H, P, N = mamba2_dims(cfg)
    return {"conv": jnp.zeros((batch, cfg.ssm_conv, d_inner + 2 * N),
                              jnp.dtype(cfg.dtype)),
            "ssm": jnp.zeros((batch, H, N, P), jnp.float32)}

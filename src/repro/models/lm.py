"""Unified language-model builder for all assigned architectures.

A model is a sequence of SEGMENTS; each segment is n identical blocks whose
params are stacked on axis 0 and applied with jax.lax.scan (keeps the lowered
HLO size independent of depth — essential for compiling deepseek-v3's 61
layers x 512 devices). Segment kinds:

  dense      — GQA attention (+opt sliding window) + swiglu/geglu FFN
  moe        — GQA attention + capacity-based MoE FFN
  mla_dense  — deepseek MLA attention + dense FFN (leading layers)
  mla_moe    — deepseek MLA attention + MoE with shared expert
  vlm_group  — k-1 self-attn blocks + 1 gated cross-attn block (llama-vision)
  mamba      — Mamba2 (SSD) blocks (zamba2 tail)
  mamba_group— k Mamba2 blocks + one SHARED full-attn block (zamba2)
  rwkv       — RWKV6 time-mix + channel-mix
  enc / dec  — whisper encoder (bidirectional) / decoder (self+cross)

Public API (build(cfg) -> LM): init, loss, prefill, decode, init_cache,
input_specs-compatible batch conventions (see repro/launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import mamba2, moe, rwkv6
from repro.models.config import ModelConfig
from repro.models.layers import (attention, attn_init, dense_init, ffn,
                                 ffn_init, mla_attention, mla_init, rmsnorm)

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    kind: str
    n_blocks: int


def segments_for(cfg: ModelConfig) -> list[Segment]:
    if cfg.rwkv:
        return [Segment("rwkv", "rwkv", cfg.n_layers)]
    if cfg.enc_dec:
        return [Segment("enc", "enc", cfg.n_encoder_layers),
                Segment("dec", "dec", cfg.n_layers)]
    if cfg.ssm_state and cfg.attn_every:
        n_groups = cfg.n_layers // cfg.attn_every
        rest = cfg.n_layers - n_groups * cfg.attn_every
        segs = [Segment("mamba_group", "mamba_group", n_groups)]
        if rest:
            segs.append(Segment("mamba_tail", "mamba", rest))
        return segs
    if cfg.cross_attn_every:
        assert cfg.n_layers % cfg.cross_attn_every == 0
        return [Segment("vlm", "vlm_group",
                        cfg.n_layers // cfg.cross_attn_every)]
    if cfg.is_moe:
        segs = []
        if cfg.n_dense_layers:
            kind = "mla_dense" if cfg.use_mla else "dense"
            segs.append(Segment("dense_prefix", kind, cfg.n_dense_layers))
        kind = "mla_moe" if cfg.use_mla else "moe"
        segs.append(Segment("moe", kind, cfg.n_layers - cfg.n_dense_layers))
        return segs
    return [Segment("dense", "dense", cfg.n_layers)]


# --- per-block init -----------------------------------------------------------
def _block_init(kind: str, cfg: ModelConfig):
    D = cfg.d_model

    def norm():
        return jnp.ones((D,), jnp.float32)

    def init(key):
        ks = jax.random.split(key, 6)
        if kind == "dense":
            return {"ln1": norm(), "attn": attn_init(ks[0], cfg),
                    "ln2": norm(), "ffn": ffn_init(ks[1], cfg)}
        if kind == "moe":
            return {"ln1": norm(), "attn": attn_init(ks[0], cfg),
                    "ln2": norm(), "moe": moe.moe_init(ks[1], cfg)}
        if kind == "mla_dense":
            return {"ln1": norm(), "attn": mla_init(ks[0], cfg),
                    "ln2": norm(), "ffn": ffn_init(ks[1], cfg)}
        if kind == "mla_moe":
            return {"ln1": norm(), "attn": mla_init(ks[0], cfg),
                    "ln2": norm(), "moe": moe.moe_init(ks[1], cfg)}
        if kind == "vlm_group":
            k = cfg.cross_attn_every
            self_init = _block_init("dense", cfg)
            return {"selfs": jax.vmap(self_init)(jax.random.split(ks[0], k)),
                    "x_ln": norm(), "xattn": attn_init(ks[1], cfg),
                    "x_gate": jnp.zeros((), jnp.float32),
                    "x_ln2": norm(), "xffn": ffn_init(ks[2], cfg),
                    "xffn_gate": jnp.zeros((), jnp.float32)}
        if kind == "mamba":
            return {"ln1": norm(), "mamba": mamba2.mamba2_init(ks[0], cfg)}
        if kind == "mamba_group":
            k = cfg.attn_every
            m_init = _block_init("mamba", cfg)
            return {"mambas": jax.vmap(m_init)(jax.random.split(ks[0], k))}
        if kind == "rwkv":
            return {"ln1": norm(), "ln2": norm(),
                    "rwkv": rwkv6.rwkv6_init(ks[0], cfg)}
        if kind == "enc":
            return {"ln1": norm(), "attn": attn_init(ks[0], cfg),
                    "ln2": norm(), "ffn": ffn_init(ks[1], cfg)}
        if kind == "dec":
            return {"ln1": norm(), "attn": attn_init(ks[0], cfg),
                    "lnx": norm(), "xattn": attn_init(ks[1], cfg),
                    "ln2": norm(), "ffn": ffn_init(ks[2], cfg)}
        raise ValueError(kind)

    return init


# --- per-block cache ------------------------------------------------------------
def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.hd

    def kv():
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt)}

    if kind in ("dense", "moe", "enc"):
        return kv()
    if kind in ("mla_dense", "mla_moe"):
        return {"kv_c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
                "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim),
                                    dt)}
    if kind == "vlm_group":
        k = cfg.cross_attn_every
        return {"selfs": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (k,) + x.shape), kv())}
    if kind == "mamba":
        return {"m": mamba2.mamba2_init_state(cfg, batch)}
    if kind == "mamba_group":
        k = cfg.attn_every
        # each inner block's cache is {"m": state} — must match the
        # structure _apply_block("mamba") emits (dry-run out_shardings
        # compare pytree structures exactly)
        return {"mambas": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (k,) + x.shape),
            {"m": mamba2.mamba2_init_state(cfg, batch)}),
            "shared_kv": kv()}
    if kind == "rwkv":
        return rwkv6.rwkv6_block_state(cfg, batch)
    if kind == "dec":
        return kv()   # self-attn cache; cross k/v precomputed at prefill
    raise ValueError(kind)


# --- per-block apply --------------------------------------------------------------
def _apply_block(kind: str, cfg: ModelConfig, p: Params, x, *, mode: str,
                 cache, pos, extras: dict):
    """mode: 'full' (train/prefill, cache None or written via prefill path
    using functional attention without cache) or 'step' (decode, S==1).
    Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps

    def with_kv(pblk, xin, kv_cache, causal=True, use_rope=True, kv_src=None):
        c = None
        if kv_cache is not None:
            c = dict(kv_cache, len=pos)
        out, newc = attention(pblk, cfg, xin, positions=pos_arr(xin), causal=causal,
                              cache=c, use_rope=use_rope, kv_src=kv_src)
        if newc is not None:
            newc = {"k": newc["k"], "v": newc["v"]}
        return out, newc

    def pos_arr(xin):
        s = xin.shape[1]
        if mode == "step":
            return pos + jnp.arange(s)
        return jnp.arange(s)

    if kind in ("dense", "moe", "enc"):
        h, newc = with_kv(p["attn"], rmsnorm(x, p["ln1"], eps), cache,
                          causal=(kind != "enc"),
                          use_rope=not cfg.enc_dec)
        x = x + h
        y = rmsnorm(x, p["ln2"], eps)
        if kind == "moe":
            f, aux = moe.moe_ffn(p["moe"], cfg, y, extras["n_groups"])
        else:
            f = ffn(p["ffn"], cfg, y)
        return x + f, newc, aux

    if kind in ("mla_dense", "mla_moe"):
        c = dict(cache, len=pos) if cache is not None else None
        h, newc = mla_attention(p["attn"], cfg, rmsnorm(x, p["ln1"], eps),
                                positions=pos_arr(x), cache=c)
        if newc is not None:
            newc = {"kv_c": newc["kv_c"], "k_rope": newc["k_rope"]}
        x = x + h
        y = rmsnorm(x, p["ln2"], eps)
        if kind == "mla_moe":
            f, aux = moe.moe_ffn(p["moe"], cfg, y, extras["n_groups"])
        else:
            f = ffn(p["ffn"], cfg, y)
        return x + f, newc, aux

    if kind == "vlm_group":
        k = cfg.cross_attn_every
        caches = cache["selfs"] if cache is not None else None

        def body(i, x):
            blk = jax.tree.map(lambda a: a[i], p["selfs"])
            c_i = jax.tree.map(lambda a: a[i], caches) if caches is not None \
                else None
            x, newc, _ = _apply_block("dense", cfg, blk, x, mode=mode,
                                      cache=c_i, pos=pos, extras=extras)
            return x, newc

        new_selfs = []
        for i in range(k):     # unrolled: k is small (5)
            x, nc = body(i, x)
            new_selfs.append(nc)
        # gated cross-attention to vision tokens (cast: the f32 gate must
        # not promote the bf16 residual stream — scan carries fixed dtypes)
        h, _ = attention(p["xattn"], cfg, rmsnorm(x, p["x_ln"], eps),
                         kv_src=extras["vision"], use_rope=False)
        x = x + (jnp.tanh(p["x_gate"]) * h).astype(x.dtype)
        f = ffn(p["xffn"], cfg, rmsnorm(x, p["x_ln2"], eps))
        x = x + (jnp.tanh(p["xffn_gate"]) * f).astype(x.dtype)
        newc = None
        if caches is not None:
            newc = {"selfs": jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_selfs)}
        return x, newc, aux

    if kind == "mamba":
        xin = rmsnorm(x, p["ln1"], eps)
        if cache is None:                      # training
            h, new_st = mamba2.mamba2_forward(p["mamba"], cfg, xin)
        elif x.shape[1] > 1:                   # prefill: parallel + final state
            h, new_st = mamba2.mamba2_forward(p["mamba"], cfg, xin,
                                              return_state=True)
        else:                                  # decode: O(1) state update
            h, new_st = mamba2.mamba2_forward(p["mamba"], cfg, xin,
                                              state=cache["m"])
        return x + h, ({"m": new_st} if new_st is not None else None), aux

    if kind == "mamba_group":
        k = cfg.attn_every
        new_m = []
        for i in range(k):
            blk = jax.tree.map(lambda a: a[i], p["mambas"])
            c_i = (jax.tree.map(lambda a: a[i], cache["mambas"])
                   if cache is not None else None)
            x, nc, _ = _apply_block("mamba", cfg, blk, x, mode=mode,
                                    cache=c_i, pos=pos, extras=extras)
            new_m.append(nc)
        # SHARED attention block (same params every group — zamba2)
        sp = extras["shared_attn"]
        skv = cache["shared_kv"] if cache is not None else None
        h, new_skv = None, None
        c = dict(skv, len=pos) if skv is not None else None
        h, newc = attention(sp["attn"], cfg, rmsnorm(x, sp["ln1"], eps),
                            positions=(pos + jnp.arange(x.shape[1])
                                       if mode == "step"
                                       else jnp.arange(x.shape[1])),
                            cache=c)
        x = x + h
        f = ffn(sp["ffn"], cfg, rmsnorm(x, sp["ln2"], eps))
        x = x + f
        out_cache = None
        if cache is not None:
            out_cache = {"mambas": jax.tree.map(lambda *xs: jnp.stack(xs),
                                                *new_m),
                         "shared_kv": {"k": newc["k"], "v": newc["v"]}}
        return x, out_cache, aux

    if kind == "rwkv":
        st = cache if cache is not None else rwkv6.rwkv6_block_state(
            cfg, x.shape[0])
        h, tm_state = rwkv6.time_mix(p["rwkv"], cfg,
                                     rmsnorm(x, p["ln1"], eps), st)
        x = x + h
        h, cm_state = rwkv6.channel_mix(p["rwkv"], cfg,
                                        rmsnorm(x, p["ln2"], eps), st)
        x = x + h
        newc = {**tm_state, **cm_state} if cache is not None else None
        return x, newc, aux

    if kind == "dec":
        h, newc = with_kv(p["attn"], rmsnorm(x, p["ln1"], eps), cache,
                          use_rope=False)
        x = x + h
        hx, _ = attention(p["xattn"], cfg, rmsnorm(x, p["lnx"], eps),
                          kv_src=extras["enc_out"], use_rope=False)
        x = x + hx
        f = ffn(p["ffn"], cfg, rmsnorm(x, p["ln2"], eps))
        return x + f, newc, aux

    raise ValueError(kind)


# --- segment scan ------------------------------------------------------------------
def _scan_segment(seg: Segment, cfg: ModelConfig, params: Params, x, *,
                  mode: str, cache, pos, extras):
    """Scan blocks of one segment. params leaves stacked (n, ...); cache
    leaves stacked (n, ...) or None."""
    def body(carry, inp):
        x = carry
        blk, c = inp
        f = functools.partial(_apply_block, seg.kind, cfg, mode=mode,
                              pos=pos, extras=extras)
        if cfg.remat and mode == "full":
            f = jax.checkpoint(f)
        x, newc, aux = f(blk, x, cache=c)
        return x, (newc, aux)

    xs = (params, cache)
    x, (new_cache, auxs) = jax.lax.scan(body, x, xs)
    return x, new_cache, jnp.sum(auxs)


# --- positional embedding for enc-dec (whisper uses learned/sinusoid) -----------
def _sinusoid(max_len: int, d: int):
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000 ** (dim / d))
    pe = jnp.zeros((max_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


# --- top-level model ---------------------------------------------------------------
class LM(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable


def build(cfg: ModelConfig) -> LM:
    segs = segments_for(cfg)
    dt = jnp.dtype(cfg.dtype)

    def init(key) -> Params:
        ks = jax.random.split(key, len(segs) + 4)
        p: Params = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dt),
            "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab, dt)
        for i, seg in enumerate(segs):
            init_b = _block_init(seg.kind, cfg)
            p[seg.name] = jax.vmap(init_b)(
                jax.random.split(ks[2 + i], seg.n_blocks))
        if cfg.attn_every:   # zamba2 shared attention block
            kb = jax.random.split(ks[-1], 3)
            p["shared_attn"] = {
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": attn_init(kb[0], cfg),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                "ffn": ffn_init(kb[1], cfg)}
        return p

    def _extras(params, batch, mode, caches=None):
        ex: dict[str, Any] = {"n_groups": cfg.moe_groups}
        if cfg.cross_attn_every:
            ex["vision"] = (batch["vision"].astype(dt) if "vision" in batch
                            else caches["vision"])
        if cfg.attn_every:
            ex["shared_attn"] = params["shared_attn"]
        return ex

    def _embed(params, tokens):
        return params["embed"][tokens]

    def _unembed(params, x):
        x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    def _encoder(params, batch, ex):
        frames = batch["frames"].astype(dt)      # (B, Fr, D) stub frontend
        pe = _sinusoid(frames.shape[1], cfg.d_model).astype(dt)
        h = frames + pe[None]
        h, _, _ = _scan_segment(segs[0], cfg, params["enc"], h,
                                mode="full", cache=None, pos=0, extras=ex)
        return rmsnorm(h, params["final_ln"], cfg.norm_eps)

    def forward(params, batch, mode="full", caches=None, pos=0):
        """Returns (hidden (B,S,D), new_caches, aux)."""
        tokens = batch["tokens"]
        ex = _extras(params, batch, mode, caches)
        x = _embed(params, tokens)
        if cfg.enc_dec:
            if mode == "full" or "frames" in batch:     # train or prefill
                ex["enc_out"] = _encoder(params, batch, ex)
            else:                                       # decode
                ex["enc_out"] = caches["enc_out"]
            pe = _sinusoid(65536, cfg.d_model).astype(dt)
            s = tokens.shape[1]
            x = x + jax.lax.dynamic_slice_in_dim(pe, pos, s, 0)[None] \
                if mode == "step" else x + pe[None, :s]
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = {} if caches is not None else None
        body_segs = segs[1:] if cfg.enc_dec else segs
        for seg in body_segs:
            c = caches[seg.name] if caches is not None else None
            x, nc, aux = _scan_segment(seg, cfg, params[seg.name], x,
                                       mode=mode, cache=c, pos=pos, extras=ex)
            aux_total = aux_total + aux
            if new_caches is not None:
                new_caches[seg.name] = nc
        if new_caches is not None and cfg.enc_dec:
            new_caches["enc_out"] = ex["enc_out"]
        if new_caches is not None and cfg.cross_attn_every:
            new_caches["vision"] = ex["vision"]
        return x, new_caches, aux_total

    def _chunked_ce(params, hidden, targets, mask, chunk=1024):
        """Cross-entropy with S-chunked logit materialization."""
        B, S, D = hidden.shape
        chunk = min(chunk, S)
        pad = (-S) % chunk
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        n = hidden.shape[1] // chunk

        def ce(args):
            h, t, m = args
            logits = _unembed(params, h)                      # f32
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
            return jnp.sum((lse - gold) * m)

        hs = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
        ts = targets.reshape(B, n, chunk).transpose(1, 0, 2)
        ms = mask.reshape(B, n, chunk).transpose(1, 0, 2)
        totals = jax.lax.map(ce, (hs, ts, ms))
        return jnp.sum(totals) / jnp.maximum(jnp.sum(mask), 1.0)

    def loss(params, batch):
        tokens = batch["tokens"]
        inp = {**batch, "tokens": tokens[:, :-1]}
        hidden, _, aux = forward(params, inp, mode="full")
        targets = tokens[:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        ce = _chunked_ce(params, hidden, targets, mask)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    def init_cache(batch_size: int, max_len: int):
        caches: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
        body_segs = segs[1:] if cfg.enc_dec else segs
        for seg in body_segs:
            one = _block_cache(seg.kind, cfg, batch_size, max_len)
            caches[seg.name] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (seg.n_blocks,) + x.shape)
                .copy(), one)
        if cfg.enc_dec:
            caches["enc_out"] = jnp.zeros(
                (batch_size, cfg.n_audio_frames, cfg.d_model), dt)
        if cfg.cross_attn_every:
            caches["vision"] = jnp.zeros(
                (batch_size, cfg.n_vision_tokens, cfg.d_model), dt)
        return caches

    def prefill(params, batch, max_len: int):
        """Run the full prompt, build a decode cache of size max_len.
        Returns (last_logits (B, V), caches)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        caches = init_cache(B, max_len)
        pos = jnp.zeros((), jnp.int32)
        x, new_caches, _ = forward(params, batch, mode="step",
                                   caches=caches, pos=pos)
        new_caches["len"] = jnp.full((), S, jnp.int32)
        logits = _unembed(params, x[:, -1:, :])[:, 0]
        return logits, new_caches

    def decode(params, caches, tokens):
        """One decode step. tokens: (B,) int32. Returns (logits, caches)."""
        pos = caches["len"]
        batch = {"tokens": tokens[:, None]}
        x, new_caches, _ = forward(params, batch, mode="step",
                                   caches={k: v for k, v in caches.items()
                                           if k != "len"}, pos=pos)
        new_caches["len"] = pos + 1
        logits = _unembed(params, x)[:, 0]
        return logits, new_caches

    return LM(cfg, init, loss, prefill, decode, init_cache)

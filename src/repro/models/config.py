"""Model configuration for all assigned architectures.

One dataclass covers every family; family-specific fields are ignored by the
others. Exact per-arch values live in repro/configs/<arch>.py.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None        # default d_model // n_heads

    ffn_kind: Literal["swiglu", "geglu"] = "swiglu"
    attn_window: int | None = None     # sliding-window attention (mixtral)
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0                 # routed experts (0 = dense FFN)
    n_shared_experts: int = 0          # deepseek shared expert(s)
    top_k: int = 2
    moe_d_ff: int = 0                  # routed-expert hidden dim
    n_dense_layers: int = 0            # leading layers that keep dense FFN
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- hybrid / ssm ---
    ssm_state: int = 0                 # Mamba2 state size N (0 = no ssm)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0                # zamba2: shared attn block every k layers
    rwkv: bool = False                 # RWKV6 time/channel mix blocks

    # --- vlm ---
    cross_attn_every: int = 0          # cross-attn to vision every k layers
    n_vision_tokens: int = 1601        # stub frontend output length

    # --- audio (enc-dec) ---
    enc_dec: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500         # stub conv frontend output length

    # --- numerics / compile ---
    dtype: str = "bfloat16"
    remat: bool = True                 # activation checkpointing per layer
    moe_groups: int = 1                # dispatch groups (= data shards)
    # attention implementation for train/prefill self-attention:
    #   "einsum" — materialized-score SDPA (paper-faithful baseline)
    #   "flash"  — Pallas flash kernel via shard_map (§Perf optimized path;
    #              falls back to einsum when heads don't divide the TP axis)
    attn_impl: str = "einsum"
    # expert-parallel dispatch axes for MoE all-to-all re-sharding
    # (§Perf: deepseek-v3); None disables the constraint.
    ep_axes: tuple | None = None

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        D, V, L = self.d_model, self.vocab, self.n_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.rwkv:
            att = L * (4 * D * D + 6 * D)        # r,k,v,g,o + mixes/decay
            ffn = L * 2 * D * self.d_ff          # rwkv channel mix (r,k,v ~ 2x)
            return emb + att + ffn
        hd = self.hd
        if self.use_mla:
            att_l = (D * self.q_lora_rank
                     + self.q_lora_rank * self.n_heads
                     * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                     + D * (self.kv_lora_rank + self.qk_rope_head_dim)
                     + self.kv_lora_rank * self.n_heads
                     * (self.qk_nope_head_dim + self.v_head_dim)
                     + self.n_heads * self.v_head_dim * D)
        else:
            att_l = (D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
                     + self.n_heads * hd * D)
        n_ff = 3 * D * self.d_ff
        moe_l = 0
        if self.is_moe:
            moe_l = (self.n_experts * 3 * D * self.moe_d_ff
                     + self.n_shared_experts * 3 * D * self.moe_d_ff
                     + D * self.n_experts)
            n_moe_layers = L - self.n_dense_layers
            ffn_total = self.n_dense_layers * n_ff + n_moe_layers * moe_l
        else:
            ffn_total = L * n_ff
        ssm_l = 0
        if self.ssm_state:
            d_in = self.ssm_expand * D
            ssm_l = L * (D * 2 * d_in + d_in * D + D * d_in // 2)
        layers = L * att_l if not self.ssm_state else 0
        if self.attn_every:   # zamba2: ONE shared attn block (attn + its FFN)
            layers = att_l
            ffn_total = n_ff
        if self.enc_dec:
            layers = (self.n_encoder_layers + L) * att_l + L * att_l  # + cross
            ffn_total = (self.n_encoder_layers + L) * n_ff
        if self.cross_attn_every:
            layers += (L // self.cross_attn_every) * att_l
        return emb + layers + ffn_total + ssm_l

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = self.n_layers - self.n_dense_layers
        all_experts = n_moe_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
        active = n_moe_layers * (self.top_k + self.n_shared_experts) \
            * 3 * self.d_model * self.moe_d_ff
        return full - all_experts + active

"""Shared neural layers: norms, rope, attention (GQA / MLA / cross), FFNs.

Pure functions over explicit param dicts. Weights are bf16 (cfg.dtype);
normalization and softmax accumulate in f32. All matmuls request f32
accumulation via preferred_element_type.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.config import ModelConfig

Params = dict[str, Any]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dot(x, w):
    return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32
                               ).astype(x.dtype)


# --- init helpers ------------------------------------------------------------
def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def stacked(key, n, init_fn):
    """Stack n independent inits along axis 0 (scan-friendly params)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


# --- RMSNorm ------------------------------------------------------------------
def rmsnorm(x, gamma, eps):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype) * gamma.astype(x.dtype)


# --- RoPE ---------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- Attention (GQA, optional window / cross / bidirectional) -----------------
def attn_init(key, cfg: ModelConfig, d_kv_in: int | None = None) -> Params:
    """d_kv_in: source dim for k/v (cross-attention); defaults to d_model."""
    D, hd = cfg.d_model, cfg.hd
    d_kv_in = d_kv_in or D
    ks = jax.random.split(key, 4)
    dt = _dt(cfg)
    return {
        "wq": dense_init(ks[0], D, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d_kv_in, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d_kv_in, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, D, dt),
    }


# materializing (S, T) logits beyond this many query rows switches to the
# exact q-chunked path (bounds live memory to (B, H, CHUNK, T)).
_Q_CHUNK = 4096


def _flash_shardable(cfg: ModelConfig) -> bool:
    """Flash path needs an ambient mesh whose model axis divides the query
    heads (each rank runs the kernel on its local heads)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    m = mesh.shape["model"]
    if cfg.n_heads % m:
        return False
    h_loc = cfg.n_heads // m
    if cfg.n_kv_heads % m == 0:
        return True
    # replicated-KV mode: each rank's q heads must map to a contiguous,
    # rank-constant set of kv heads
    g = cfg.n_heads // cfg.n_kv_heads
    return g % h_loc == 0 or h_loc % g == 0


def _flash_sdpa(cfg: ModelConfig, q, k, v, *, causal: bool,
                window: int | None):
    """(B, S, H, D) flash attention through the Pallas kernel, sharded with
    shard_map over (batch -> data axes, heads -> model). KV heads shard when
    divisible, otherwise replicate + local slice (GQA).

    On TPU the kernel compiles to Mosaic; on CPU it runs in interpret mode —
    either way the HLO carries the kernel's BlockSpec streaming as its HBM
    traffic (launch/hlo_analysis.py VMEM-scope rule)."""
    from repro.kernels import ops as kops   # local import: no cycle at load

    mesh = compat.get_abstract_mesh()
    m = mesh.shape["model"]
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b, s, h, d = q.shape
    hkv = k.shape[2]
    ba = ba if ba and b % max(
        1, int(np.prod([mesh.shape[a] for a in ba]))) == 0 else None
    h_loc = h // m
    kv_sharded = hkv % m == 0

    def local(qt, kt, vt):
        if not kv_sharded and hkv != h:
            # slice the kv heads this rank's q heads attend to
            r = jax.lax.axis_index("model")
            g = h // hkv
            n_kv_loc = max(h_loc // g, 1)
            start = (r * h_loc) // g
            kt = jax.lax.dynamic_slice_in_dim(kt, start, n_kv_loc, axis=1)
            vt = jax.lax.dynamic_slice_in_dim(vt, start, n_kv_loc, axis=1)
        return kops.flash_attention(qt, kt, vt, causal=causal,
                                    window=window)

    kv_spec = P(ba, "model" if kv_sharded else None, None, None)
    out = compat.shard_map(local, mesh=mesh,
                           in_specs=(P(ba, "model", None, None),
                                     kv_spec, kv_spec),
                           out_specs=P(ba, "model", None, None))(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3))
    return out.transpose(0, 2, 1, 3)


def _sdpa(q, k, v, *, causal, window, q_pos=None, kv_len=None):
    """q: (B, S, H, D); k/v: (B, T, Hkv, D) -> (B, S, H, D).

    q_pos: (S,) absolute positions of queries (decode: T-1); kv_len: number of
    valid kv entries (decode with preallocated cache).
    """
    b, s, h, d = q.shape
    if s > _Q_CHUNK and s % _Q_CHUNK == 0:
        if q_pos is None:
            q_pos = jnp.arange(s)
        qs = q.reshape(b, s // _Q_CHUNK, _Q_CHUNK, h, d).transpose(1, 0, 2, 3, 4)
        ps = q_pos.reshape(s // _Q_CHUNK, _Q_CHUNK)
        out = jax.lax.map(
            lambda args: _sdpa(args[0], k, v, causal=causal, window=window,
                               q_pos=args[1], kv_len=kv_len), (qs, ps))
        return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, v.shape[-1])
    t, hkv = k.shape[1], k.shape[2]
    # GQA strategy (§Perf iteration 1): the grouped einsum never
    # materializes repeated K/V — on a TP mesh where hkv < |model| the KV
    # cache is sequence-sharded and jnp.repeat would force the partitioner
    # to all-gather the whole cache every layer (6.4e10 B/dev per decode
    # step on llama3-8b decode_32k). The grouped form keeps the
    # T-contraction sequence-sharded; only partial (B,S,H,D) sums cross
    # chips (flash-decoding parallelism, derived by the SPMD partitioner).
    # For TRAIN/PREFILL with hkv not divisible by the model axis, grouped
    # logits (B,hkv,g,S,T) lose their clean head sharding and cost MORE
    # (llama-3.2-vision-90b train: memory +11%) — use repeat there.
    mesh = compat.get_abstract_mesh()
    m = mesh.shape.get("model", 1) if mesh is not None \
        and hasattr(mesh, "shape") else 1
    grouped = (s == 1) or hkv % max(m, 1) == 0 or hkv == h
    if not grouped:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
        hkv = h
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    qi = (q_pos if q_pos is not None else jnp.arange(s))[:, None]
    ki = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    if kv_len is not None:
        mask &= ki < kv_len
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    # v's head dim may differ from q/k's (MLA: dv=128 vs dqk=192)
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


def attention(p: Params, cfg: ModelConfig, x, *, kv_src=None, positions=None,
              causal=True, cache=None, use_rope=True):
    """Self- or cross-attention. x: (B, S, D).

    cache: None (train/prefill, no cache) or dict {k, v, len} with
    preallocated (B, T, Hkv, hd) buffers for decode — returns (out, cache').
    kv_src: (B, T, Dsrc) for cross-attention (no cache, no rope on kv).
    """
    B, S, D = x.shape
    hd = cfg.hd
    src = kv_src if kv_src is not None else x
    q = dot(x, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = dot(src, p["wk"]).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    v = dot(src, p["wv"]).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    if positions is None:
        positions = jnp.arange(S)
    if use_rope and kv_src is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    use_flash = (cfg.attn_impl == "flash" and kv_src is None and S > 1
                 and _flash_shardable(cfg))
    if cache is not None:
        # decode (S==1) or prefill (S>1): write k/v at position cache["len"]
        idx = cache["len"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        if use_flash:
            # prefill starts from an empty cache (idx == 0), so attention
            # over the in-flight k/v equals attention over the cache
            out = _flash_sdpa(cfg, q, k, v, causal=True,
                              window=cfg.attn_window)
        else:
            out = _sdpa(q, ck, cv, causal=True, window=cfg.attn_window,
                        q_pos=positions, kv_len=idx + S)
        new_cache = {"k": ck, "v": cv, "len": idx + S}
        return dot(out.reshape(B, S, cfg.n_heads * hd), p["wo"]), new_cache
    if use_flash:
        out = _flash_sdpa(cfg, q, k, v, causal=causal,
                          window=cfg.attn_window)
    else:
        out = _sdpa(q, k, v, causal=causal and kv_src is None,
                    window=cfg.attn_window)
    return dot(out.reshape(B, S, cfg.n_heads * hd), p["wo"]), None


# --- MLA (deepseek multi-head latent attention) --------------------------------
def mla_init(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    dt = _dt(cfg)
    return {
        "wdq": dense_init(ks[0], D, cfg.q_lora_rank, dt),
        "wuq": dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_hd, dt),
        "wdkv": dense_init(ks[2], D, cfg.kv_lora_rank, dt),
        "wkr": dense_init(ks[3], D, cfg.qk_rope_head_dim, dt),
        "wuk": dense_init(ks[4], cfg.kv_lora_rank,
                          cfg.n_heads * cfg.qk_nope_head_dim, dt),
        "wuv": dense_init(ks[5], cfg.kv_lora_rank,
                          cfg.n_heads * cfg.v_head_dim, dt),
        "wo": dense_init(ks[6], cfg.n_heads * cfg.v_head_dim, D, dt),
    }


def mla_attention(p: Params, cfg: ModelConfig, x, *, positions=None,
                  cache=None):
    """Multi-head latent attention. Cache (decode) holds only the compressed
    kv latent (B, T, kv_lora_rank) + rope key (B, T, rope_hd) — the paper's
    (DeepSeek-V3) KV-cache reduction. Decode uses the absorbed-matmul form.
    """
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)
    q = dot(dot(x, p["wdq"]), p["wuq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv_c = dot(x, p["wdkv"])                                # (B, S, R)
    k_rope = apply_rope(dot(x, p["wkr"])[:, :, None, :], positions,
                        cfg.rope_theta)                     # (B, S, 1, dr)
    scale = (dn + dr) ** -0.5

    if cache is None:
        k_nope = dot(kv_c, p["wuk"]).reshape(B, S, H, dn)
        v = dot(kv_c, p["wuv"]).reshape(B, S, H, dv)
        # concat nope+rope into one head dim: q'.k' = nope.nope + rope.rope,
        # so the (q-chunked) shared SDPA computes MLA logits exactly; its
        # scale (dn+dr)^-0.5 matches `scale`.
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope.astype(k_nope.dtype),
                                      (B, S, H, dr))], axis=-1)
        out = _sdpa(q_full, k_full, v, causal=True, window=None)
        return dot(out.reshape(B, S, H * dv), p["wo"]), None

    # decode (S == 1), absorbed form: score in latent space.
    idx = cache["len"]
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["kv_c"], kv_c, idx, axis=1)
    ckr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope[:, :, 0, :], idx, axis=1)
    wuk = p["wuk"].reshape(cfg.kv_lora_rank, H, dn)
    q_c = jnp.einsum("bshd,rhd->bshr", q_nope, wuk,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    logits = (jnp.einsum("bshr,btr->bhst", q_c, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshd,btd->bhst", q_rope, ckr,
                           preferred_element_type=jnp.float32)) * scale
    t = ckv.shape[1]
    ki = jnp.arange(t)[None, None, None, :]
    qi = positions[None, None, :, None]
    logits = jnp.where((ki < idx + S) & (ki <= qi), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhst,btr->bshr", probs, ckv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    wuv = p["wuv"].reshape(cfg.kv_lora_rank, H, dv)
    out = jnp.einsum("bshr,rhd->bshd", o_c, wuv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = {"kv_c": ckv, "k_rope": ckr, "len": idx + S}
    return dot(out.reshape(B, S, H * dv), p["wo"]), new_cache


# --- FFN (swiglu / geglu) -------------------------------------------------------
def ffn_init(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = _dt(cfg)
    return {"w1": dense_init(ks[0], D, F, dt),
            "w3": dense_init(ks[1], D, F, dt),
            "w2": dense_init(ks[2], F, D, dt)}


def ffn(p: Params, cfg: ModelConfig, x):
    gate = dot(x, p["w1"])
    act = jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype) \
        if cfg.ffn_kind == "geglu" else jax.nn.silu(gate)
    return dot(act * dot(x, p["w3"]), p["w2"])

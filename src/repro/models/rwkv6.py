"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Recurrence per head (state S in R^{N x N}, N = head size):
  S_t = diag(w_t) S_{t-1} + k_t^T v_t
  y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(decay_t)) data-dependent (the Finch novelty vs RWKV-5).

Training/prefill runs the recurrence with lax.scan over time (linear in S —
the arch's entire point for the long_500k shape); decode is one state update.
The low-rank token-shift interpolation (LoRA mix) is simplified to a single
learned per-channel mix, which preserves the compute/memory shape of the
published block (DESIGN.md notes this deviation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, dot, rmsnorm

HEAD_N = 64


def rwkv_dims(cfg: ModelConfig):
    assert cfg.d_model % HEAD_N == 0
    return cfg.d_model // HEAD_N, HEAD_N


def rwkv6_init(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    H, N = rwkv_dims(cfg)
    ks = jax.random.split(key, 10)
    dt = jnp.dtype(cfg.dtype)
    return {
        "mix_r": jnp.full((D,), 0.5, jnp.float32),
        "mix_k": jnp.full((D,), 0.5, jnp.float32),
        "mix_v": jnp.full((D,), 0.5, jnp.float32),
        "mix_w": jnp.full((D,), 0.5, jnp.float32),
        "wr": dense_init(ks[0], D, D, dt),
        "wk": dense_init(ks[1], D, D, dt),
        "wv": dense_init(ks[2], D, D, dt),
        "wg": dense_init(ks[3], D, D, dt),
        "ww": dense_init(ks[4], D, D, dt, scale=1e-3),   # data-dep decay proj
        "w_bias": jnp.full((D,), -6.0, jnp.float32),
        "u_bonus": jnp.zeros((H, N), jnp.float32),
        "wo": dense_init(ks[5], D, D, dt),
        "ln_g": jnp.ones((D,), jnp.float32),
        # channel mix
        "cmix_k": jnp.full((D,), 0.5, jnp.float32),
        "ck": dense_init(ks[6], D, cfg.d_ff, dt),
        "cv": dense_init(ks[7], cfg.d_ff, D, dt),
        "cr": dense_init(ks[8], D, D, dt),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} with carry-in `prev` (B, 1, D)."""
    return jnp.concatenate([prev, x[:, :-1, :]], axis=1)


def time_mix(p: Params, cfg: ModelConfig, x, state):
    """x: (B, S, D); state: {tm_prev (B,1,D), wkv (B,H,N,N) f32}."""
    B, S, D = x.shape
    H, N = rwkv_dims(cfg)
    xp = _shift(x, state["tm_prev"])

    def mix(m):
        return x * m.astype(x.dtype) + xp * (1 - m).astype(x.dtype)

    r = dot(mix(p["mix_r"]), p["wr"]).reshape(B, S, H, N)
    k = dot(mix(p["mix_k"]), p["wk"]).reshape(B, S, H, N)
    v = dot(mix(p["mix_v"]), p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(dot(mix(p["mix_v"]), p["wg"]).astype(jnp.float32))
    wdec = dot(mix(p["mix_w"]), p["ww"]).astype(jnp.float32) + p["w_bias"]
    w = jnp.exp(-jnp.exp(wdec)).reshape(B, S, H, N)          # (0,1) decay

    def step(s_prev, inp):
        rt, kt, vt, wt = inp                                  # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]              # (B,H,N,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         s_prev + p["u_bonus"][..., None] * kv)
        s_new = wt[..., None] * s_prev + kv
        return s_new, out

    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          w.transpose(1, 0, 2, 3))
    s_final, ys = jax.lax.scan(step, state["wkv"], xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, D)             # (B,S,D) f32
    y = rmsnorm(y.astype(x.dtype), p["ln_g"], cfg.norm_eps)
    y = (y.astype(jnp.float32) * g).astype(x.dtype)
    new_state = {"tm_prev": x[:, -1:, :], "wkv": s_final}
    return dot(y, p["wo"]), new_state


def channel_mix(p: Params, cfg: ModelConfig, x, state):
    xp = _shift(x, state["cm_prev"])
    m = p["cmix_k"].astype(x.dtype)
    xm = x * m + xp * (1 - m)
    k = dot(xm, p["ck"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(dot(xm, p["cr"]).astype(jnp.float32)).astype(x.dtype)
    return r * dot(k, p["cv"]), {"cm_prev": x[:, -1:, :]}


def rwkv6_block_state(cfg: ModelConfig, batch: int):
    H, N = rwkv_dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    return {"tm_prev": jnp.zeros((batch, 1, cfg.d_model), dt),
            "wkv": jnp.zeros((batch, H, N, N), jnp.float32),
            "cm_prev": jnp.zeros((batch, 1, cfg.d_model), dt)}

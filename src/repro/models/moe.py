"""Mixture-of-Experts FFN with capacity-based (GShard-style) token dispatch.

Formulation: tokens grouped by data shard (G groups of S tokens). The router
produces top-k expert choices; tokens are packed into per-expert capacity
slots C = ceil(S * top_k * capacity_factor / E) via a one-hot dispatch tensor
(G, S, E, C). All contractions are einsums so pjit shards them:

  G -> data axis, E -> model axis (expert parallelism when E >= |model|;
  otherwise experts are replicated and the expert hidden dim F is
  tensor-parallel over model).

Per-device dispatch memory is (S_loc * E_loc * C) — kept small via
microbatching (train/loop.py). Overflowing tokens are dropped (standard
capacity semantics); the combine weights renormalize over surviving slots.

DeepSeek-V3 extras: n_shared_experts dense experts always applied; router
uses sigmoid affinity + per-expert bias (aux-loss-free balancing is left to
the optimizer-side bias update, implemented in update_router_bias).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, dense_init, ffn, ffn_init


def moe_init(key, cfg: ModelConfig) -> Params:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32, scale=0.02),
        "router_bias": jnp.zeros((E,), jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
               * D ** -0.5).astype(dt),
        "w3": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
               * D ** -0.5).astype(dt),
        "w2": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
               * F ** -0.5).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[4], cfg,
                               d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            // cfg.n_experts) + 1
    return max(c, cfg.top_k)


def route(p: Params, cfg: ModelConfig, x_flat: jax.Array):
    """x_flat: (G, S, D) -> (combine (G,S,E,C) f32, dispatch (G,S,E,C) bool,
    aux_loss scalar)."""
    G, S, D = x_flat.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    logits = jnp.einsum("gsd,de->gse", x_flat.astype(jnp.float32),
                        p["router"])
    # deepseek-style sigmoid affinity with balancing bias for SELECTION,
    # softmax-normalized weights for COMBINATION
    gates = jax.nn.softmax(logits, axis=-1)
    sel_score = gates + p["router_bias"]
    _, topk_idx = jax.lax.top_k(sel_score, K)               # (G, S, K)

    # position of each (token, k) within its expert, in token order
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32)  # (G, S, K, E)
    flat = onehot.reshape(G, S * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, S, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (G, S, K)
    keep = pos < C
    gate_k = jnp.take_along_axis(gates, topk_idx, axis=-1) * keep
    denom = jnp.sum(gate_k, axis=-1, keepdims=True)
    gate_k = gate_k / jnp.maximum(denom, 1e-9)

    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                            dtype=jnp.float32)[..., :C]      # (G,S,K,C)
    # contract over k WITHOUT materializing (G,S,K,E,C)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, pos_oh) > 0
    combine = jnp.einsum("gske,gskc->gsec", onehot * gate_k[..., None],
                         pos_oh)

    # load-balancing aux loss (Switch): E * sum(frac_tokens * frac_prob)
    me = jnp.mean(onehot.sum(2), axis=(0, 1))                # fraction routed
    ce = jnp.mean(gates, axis=(0, 1))
    aux = E * jnp.sum(me * ce) / cfg.top_k
    return combine, dispatch, aux


# dispatch one-hots scale with tokens-per-group^2 / E; beyond this many
# tokens per group the sequence is processed in chunks (exact — routing is
# per-token; only capacity boundaries move, as in any production MoE server).
_MOE_CHUNK_TOKENS = 16384


def moe_ffn(p: Params, cfg: ModelConfig, x: jax.Array, n_groups: int):
    """x: (B, S, D) -> (out, aux_loss). Tokens regrouped to (G, S', D)."""
    B, S, D = x.shape
    if B * S > n_groups * _MOE_CHUNK_TOKENS and S % 2 == 0:
        n_chunks = 2
        while (B * (S // n_chunks) > n_groups * _MOE_CHUNK_TOKENS
               and (S // n_chunks) % 2 == 0):
            n_chunks *= 2
        xc = x.reshape(B, n_chunks, S // n_chunks, D).transpose(1, 0, 2, 3)
        outs, auxs = jax.lax.map(
            lambda xs: moe_ffn(p, cfg, xs, n_groups), xc)
        return (outs.transpose(1, 0, 2, 3).reshape(B, S, D),
                jnp.mean(auxs))
    T = B * S
    G = n_groups
    assert T % G == 0, (T, G)
    xg = x.reshape(G, T // G, D)
    combine, dispatch, aux = route(p, cfg, xg)
    ein = jnp.einsum

    def ep(t):
        """Two-level expert parallelism (§Perf, deepseek-v3): when expert
        weights shard E over (data x model), re-shard the dispatched slot
        tensor from token-sharded (G over data) to expert-sharded so the
        expert matmuls are fully local. The SPMD partitioner lowers this
        constraint to the EP all-to-all; without it, it all-gathers every
        token to every expert owner (40 TB/device on deepseek-v3 train)."""
        if not cfg.ep_axes:
            return t
        from jax.sharding import PartitionSpec
        spec = PartitionSpec(None, tuple(cfg.ep_axes),
                             *([None] * (t.ndim - 2)))
        return jax.lax.with_sharding_constraint(t, spec)

    xin = ep(ein("gsec,gsd->gecd", dispatch.astype(x.dtype), xg))  # (G,E,C,D)
    h = ein("gecd,edf->gecf", xin, p["w1"],
            preferred_element_type=jnp.float32).astype(x.dtype)
    h3 = ein("gecd,edf->gecf", xin, p["w3"],
             preferred_element_type=jnp.float32).astype(x.dtype)
    h = jax.nn.silu(h) * h3
    eo = ep(ein("gecf,efd->gecd", h, p["w2"],
                preferred_element_type=jnp.float32).astype(x.dtype))
    out = ein("gsec,gecd->gsd", combine.astype(x.dtype), eo)
    out = out.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + ffn(p["shared"], cfg, x)
    return out, aux


def update_router_bias(bias: jax.Array, expert_load: jax.Array,
                       step_size: float = 1e-3) -> jax.Array:
    """DeepSeek-V3 aux-loss-free balancing: nudge selection bias against
    overloaded experts (called from the train loop with per-step loads)."""
    target = jnp.mean(expert_load)
    return bias + step_size * jnp.sign(target - expert_load)

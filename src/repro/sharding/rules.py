"""Parameter / activation / cache sharding rules.

Mapping philosophy (megatron-style TP + DP, optional pod axis for DP):
  - batch dims       -> ("pod", "data") (or ("data",) single-pod)
  - attention heads, FFN hidden, expert dim, vocab -> "model"
  - layer-stack leading dims (scan) -> unsharded
  - norms / scalars / routers -> replicated

Rules match on the *leaf name* (last string key in the tree path) with a few
contextual overrides (expert weights under a "moe" subtree). Everything not
matched is replicated — loudly, via `explain` in launch/dryrun.py.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf name -> spec over the LAST TWO dims (leading stack dims unsharded).
# "col" = shard output dim (last), "row" = shard input dim (-2), "rep" = none.
_COL = ("wq", "wk", "wv", "w1", "w3", "wuq", "wuk", "wuv", "wkr",
        "in_zx", "in_dt", "wr", "wg", "ww", "ck", "cr", "lm_head")
_ROW = ("wo", "w2", "out_proj", "cv")
_REP = ("router", "router_bias", "ln1", "ln2", "lnx", "x_ln", "x_ln2",
        "final_ln", "norm_g", "ln_g", "a_log", "dt_bias", "d_skip", "w_bias",
        "mix_r", "mix_k", "mix_v", "mix_w", "cmix_k", "x_gate", "xffn_gate",
        "wdq", "wdkv", "in_bc")


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _maybe(axis: str, dim_size: int, mesh: Mesh):
    """Shard only if divisible (e.g. mixtral's 8 experts on a 16-way axis
    fall back to replication along E; their F dim is sharded instead)."""
    return axis if dim_size % mesh.shape[axis] == 0 else None


def param_spec(path: tuple, leaf: Any, mesh: Mesh, *,
               fsdp: bool = False) -> P:
    """``fsdp=True`` additionally shards each matrix's non-TP dim over the
    data axis (ZeRO-3 / FSDP: XLA inserts a per-scan-step all-gather of the
    layer's weights). Required for archs whose params exceed HBM at
    model-axis-only sharding (llama32-vision-90b, deepseek-coder-33b)."""
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    name = names[-1]
    ndim = np.ndim(leaf)
    in_moe = "moe" in names or "shared" in names

    def tail(*spec):
        return P(*([None] * (ndim - len(spec)) + list(spec)))

    def fs(dim_size):
        """data-axis shard of the non-TP dim under fsdp."""
        if not fsdp or "data" not in mesh.shape:
            return None
        return "data" if dim_size % mesh.shape["data"] == 0 else None

    if name == "embed":
        v, d = np.shape(leaf)
        return P(_maybe("model", v, mesh), fs(d))
    if name in ("u_bonus",):          # (H, N) rwkv per-head bonus
        return tail("model", None)
    if name == "conv_w":              # (k, d_inner + 2N): channels mixed ->
        return tail(None, None)       # replicated (small)
    if in_moe and name in ("w1", "w3", "w2"):
        # Expert parallelism: shard E over as many mesh axes as divide it.
        # deepseek-v3's 256 experts fill data x model = 256 (1 expert/chip);
        # mixtral's 8 experts don't divide either axis -> TP over F instead.
        shape3 = np.shape(leaf)[-3:]
        e = shape3[0]
        f_pos = 2 if name in ("w1", "w3") else 1        # F dim within (E,·,·)
        if e % (mesh.shape.get("data", 1) * mesh.shape["model"]) == 0:
            ax_e = ("data", "model")
        else:
            ax_e = _maybe("model", e, mesh)
        spec3: list = [ax_e, None, None]
        if ax_e is None:
            spec3[f_pos] = "model"
        return tail(*spec3)
    if name in _COL:
        return tail(fs(np.shape(leaf)[-2]),
                    _maybe("model", np.shape(leaf)[-1], mesh))
    if name in _ROW:
        return tail(_maybe("model", np.shape(leaf)[-2], mesh),
                    fs(np.shape(leaf)[-1]))
    if name in _REP or ndim <= 1:
        return P()
    return P()   # default: replicate


def params_shardings(params, mesh: Mesh, *, fsdp: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, mesh, fsdp=fsdp)),
        params)


def _batch_axes_for(mesh: Mesh, b: int):
    """Largest prefix of (pod, data) that divides the batch size (long_500k
    has global_batch=1 -> fully replicated batch)."""
    ba = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    if ba and b % n == 0:
        return ba
    if "data" in mesh.shape and b % mesh.shape["data"] == 0:
        return ("data",)
    return ()


def batch_spec(mesh: Mesh, shape: tuple) -> P:
    """Inputs: shard the leading batch dim over (pod, data) when divisible."""
    if len(shape) == 0:
        return P()
    ba = _batch_axes_for(mesh, shape[0])
    return P(ba if ba else None, *([None] * (len(shape) - 1)))


def batch_shardings(batch, mesh: Mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(mesh, np.shape(leaf))),
        batch)


def cache_spec(path: tuple, leaf: Any, mesh: Mesh) -> P:
    """KV caches / states: leading stack dim unsharded, batch dim over
    (pod,data), heads/features over model where divisible."""
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    name = names[-1]
    ndim = np.ndim(leaf)
    shape = np.shape(leaf)
    if name == "len" or ndim == 0:
        return P()

    def ba_for(b_dim_size):
        ba = _batch_axes_for(mesh, b_dim_size)
        return ba if ba else None

    if name in ("k", "v"):            # (L..., B, T, Hkv, hd)
        hkv = shape[-2]
        if hkv % mesh.shape["model"] == 0:
            # TP over kv heads
            return P(*([None] * (ndim - 4)), ba_for(shape[-4]), None,
                     "model", None)
        # GQA kv heads < model axis: sequence-shard the cache over T
        # (softmax/psum over shards handled by SPMD partitioner)
        return P(*([None] * (ndim - 4)), ba_for(shape[-4]), "model",
                 None, None)
    if name in ("kv_c", "k_rope"):    # (L, B, T, R) — MLA latent: shard T
        return P(*([None] * (ndim - 3)), ba_for(shape[-3]), "model", None)
    if name in ("enc_out", "vision"):  # (B, T, D)
        return P(ba_for(shape[0]), None, None)
    if name == "ssm":                 # (L..., B, H, N, P)
        return P(*([None] * (ndim - 4)), ba_for(shape[-4]),
                 _maybe("model", shape[-3], mesh), None, None)
    if name == "conv":                # (L..., B, k, chans)
        return P(*([None] * (ndim - 3)), ba_for(shape[-3]), None,
                 _maybe("model", shape[-1], mesh))
    if name == "wkv":                 # (L, B, H, N, N)
        return P(*([None] * (ndim - 4)), ba_for(shape[-4]),
                 _maybe("model", shape[-3], mesh), None, None)
    if name in ("tm_prev", "cm_prev"):  # (L, B, 1, D)
        return P(*([None] * (ndim - 3)), ba_for(shape[-3]), None, None)
    # default: shard the batch-like dim if we can find it
    return P(*([None] * (ndim - 3)), ba_for(shape[-3]), None, None) \
        if ndim >= 3 else P()


def cache_shardings(cache, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(path, leaf, mesh)),
        cache)


def zero1_state_spec(pspec: P, shape: tuple, mesh: Mesh) -> P:
    """ZeRO-1: additionally shard optimizer moments over the data axis on the
    largest dim the param spec leaves unsharded (falls back to the param spec
    when nothing divides)."""
    if "data" not in mesh.shape:
        return pspec
    n = mesh.shape["data"]
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    # axis already used (fsdp params / (data,model)-sharded experts)
    used = set()
    for s in spec:
        for a in (s if isinstance(s, tuple) else (s,)):
            used.add(a)
    if "data" in used:
        return pspec
    # choose the largest unsharded dim divisible by the data axis
    cands = [(shape[i], i) for i in range(len(shape))
             if spec[i] is None and shape[i] % n == 0]
    if not cands:
        return pspec
    _, i = max(cands)
    spec[i] = "data"
    return P(*spec)

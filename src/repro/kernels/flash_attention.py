"""Pallas TPU flash attention (forward), GQA + causal + sliding window.

Online-softmax blocked attention: grid (B, H, Sq/bq, Skv/bk); the kv axis is
the innermost (sequential on TPU) grid dimension, with running max / sum /
accumulator carried in VMEM scratch across kv steps. Q/K/V blocks are tiled
into VMEM via BlockSpec; K/V index maps fold the GQA group so kv heads are
fetched once per group.

MXU alignment: bq, bk default 128/256; head_dim must be a multiple of 128 on
real hardware for best MXU utilization (gemma's 256 is ideal; 64 works via
lane padding in the ops wrapper).

Out-of-window / acausal blocks are masked (p := 0) rather than skipped; a
production variant skips them with a q-dependent kv grid (noted in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_BIG = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                  l_ref, *, sm_scale: float, causal: bool,
                  window: int | None, bq: int, bk: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                     # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_idx < kv_len          # exclude padded keys
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, NEG_BIG)

    m_prev = m_ref[...][:, :1]                               # (bq, 1)
    l_prev = l_ref[...][:, :1]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)             # (bq, bk)
    l_cur = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if lse_ref is not None:
            m = m_ref[...][:, :1]
            lse_ref[0, 0] = (m + jnp.log(jnp.maximum(l, 1e-30)))[:, 0]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "sm_scale", "bq", "bk",
                              "kv_len", "interpret"))
def flash_attention_blocks(q, k, v, *, sm_scale: float, causal: bool = True,
                           window: int | None = None, bq: int = 128,
                           bk: int = 128, kv_len: int | None = None,
                           interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D); Sq % bq == Skv % bk == 0,
    D lane-aligned. ``kv_len``: true (unpadded) number of keys.
    Returns (out (B, H, Sq, D), lse (B, H, Sq) f32) — lse feeds the
    backward kernels."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0 and sq % bq == 0 and skv % bk == 0
    group = h // hkv
    grid = (b, h, sq // bq, skv // bk)
    kernel = functools.partial(_flash_kernel, sm_scale=sm_scale,
                               causal=causal, window=window, bq=bq, bk=bk,
                               kv_len=kv_len if kv_len is not None else skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, iq, ik: (b_, h_, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),     # acc
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((bq, 128), jnp.float32),   # running sum
        ],
        interpret=interpret,
    )(q, k, v)


# --- backward -----------------------------------------------------------------
def _mask(s, iq, ik, bq, bk, causal, window, kv_len):
    q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = k_idx < kv_len
    if causal:
        m &= k_idx <= q_idx
    if window is not None:
        m &= k_idx > q_idx - window
    return m


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref,
                         dq_ref, dq_acc, *, sm_scale, causal, window,
                         bq, bk, kv_len):
    """grid (B, H, Sq/bq, Skv/bk), kv innermost; accumulates dq."""
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, None]                               # (bq, 1)
    dd = dd_ref[0, 0][:, None]                                 # (bq, 1)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    mask = _mask(s, iq, ik, bq, bk, causal, window, kv_len)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)                 # (bq, bk)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dd) * sm_scale
    dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, sm_scale,
                          causal, window, bq, bk, kv_len, nq):
    """grid (B, Hkv, Skv/bk, group*Sq/bq): innermost flattens (group, iq);
    accumulates this kv block's dk/dv over all query heads in the GQA group
    and all query blocks."""
    ik, jj = pl.program_id(2), pl.program_id(3)
    nj = pl.num_programs(3)
    iq = jj % nq

    @pl.when(jj == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, None]
    dd = dd_ref[0, 0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    mask = _mask(s, iq, ik, bq, bk, causal, window, kv_len)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)                 # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dd) * sm_scale                              # (bq, bk)
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(jj == nj - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "sm_scale", "bq", "bk",
                              "kv_len", "interpret"))
def flash_attention_bwd_blocks(q, k, v, out, lse, do, *, sm_scale,
                               causal=True, window=None, bq=128, bk=128,
                               kv_len=None, interpret=False):
    """Backward pass: returns (dq, dk, dv). Two kernels — dq iterates kv
    blocks per q block; dk/dv iterates (group x q blocks) per kv block.
    dd = rowsum(do * out) is the standard flash-backward precomputation."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    kv_len = kv_len if kv_len is not None else skv
    dd = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                 axis=-1)                                       # (B, H, Sq)
    nq, nk = sq // bq, skv // bk

    q_spec = pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, bk, d), lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0))
    r_spec = pl.BlockSpec((1, 1, bq), lambda b_, h_, iq, ik: (b_, h_, iq))
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, sm_scale=sm_scale,
                          causal=causal, window=window, bq=bq, bk=bk,
                          kv_len=kv_len),
        grid=(b, h, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, r_spec, r_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dd)

    # dk/dv: grid (B, Hkv, nk, group*nq); q-side blocks indexed by the
    # flattened (g, iq) innermost axis
    qh_spec = pl.BlockSpec(
        (1, 1, bq, d),
        lambda b_, kh, ik, jj, g=group, n=nq: (b_, kh * g + jj // n, jj % n, 0))
    rh_spec = pl.BlockSpec(
        (1, 1, bq),
        lambda b_, kh, ik, jj, g=group, n=nq: (b_, kh * g + jj // n, jj % n))
    kvo_spec = pl.BlockSpec((1, 1, bk, d), lambda b_, kh, ik, jj: (b_, kh, ik, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, sm_scale=sm_scale,
                          causal=causal, window=window, bq=bq, bk=bk,
                          kv_len=kv_len, nq=nq),
        grid=(b, hkv, nk, group * nq),
        in_specs=[qh_spec, kvo_spec, kvo_spec, qh_spec, rh_spec, rh_spec],
        out_specs=[kvo_spec, kvo_spec],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dd)
    return dq, dk, dv

"""Pallas TPU kernel: fused RMSNorm (normalize + scale) over the last axis.

Rows tile along the sublane axis, features along lanes; the mean-square
reduction stays in VMEM registers, one HBM read + one write per element
(vs. 3 passes unfused). Feature dim must be lane-aligned (%128); the ops
wrapper pads rows and features as needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float, d_true: int):
    x = x_ref[...].astype(jnp.float32)
    # Padded feature columns are zero -> contribute 0 to the sum; divide by
    # the true feature count, not the padded one.
    ms = jnp.sum(x * x, axis=-1, keepdims=True) / d_true
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "d_true", "block_rows", "interpret"))
def rmsnorm_blocks(x2d: jax.Array, gamma: jax.Array, *, eps: float,
                   d_true: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False) -> jax.Array:
    """x2d: (R_pad, D_pad) with R_pad % block_rows == 0, D_pad % 128 == 0.
    gamma: (1, D_pad)."""
    r_pad, d_pad = x2d.shape
    assert r_pad % block_rows == 0 and d_pad % 128 == 0
    grid = (r_pad // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d_true=d_true),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, d_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, d_pad), x2d.dtype),
        interpret=interpret,
    )(x2d, gamma)

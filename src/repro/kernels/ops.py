"""Public jit'd wrappers around the Pallas kernels: padding, layout, backend
dispatch (interpret mode off-TPU), and shape restoration.

These are the entry points the rest of the framework uses; each has a
pure-jnp oracle in repro.kernels.ref and a sweep test in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topsis as _topsis
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm_pallas as _rn
from repro.kernels import topsis_pallas as _tp

_EPS = 1e-12


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --- TOPSIS -----------------------------------------------------------------
def _auto_block_n(n: int) -> int:
    return min(_tp.DEFAULT_BLOCK_N,
               max(_tp.LANE, 2 ** int(np.ceil(np.log2(max(n, 1))))))


def topsis_closeness(matrix: jax.Array, weights: jax.Array,
                     benefit: jax.Array, *, valid: jax.Array | None = None,
                     block_n: int | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Closeness coefficients for (N, C) decision matrix; C <= 8 (both the
    paper's 5-criteria matrix and the carbon-extended 6-criteria one fit
    the kernel's C_PAD=8 sublane padding — padded criteria rows carry zero
    weight and contribute nothing).

    Global reductions (column norms, ideal points) run in XLA; the O(N*C)
    distance/closeness hot loop runs in the Pallas kernel. ``valid`` is an
    optional (N,) feasibility mask: invalid rows are excluded from the ideal
    points and returned as -inf (never rank first) — identical semantics to
    ``repro.core.topsis.closeness``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    n, c = matrix.shape
    assert c <= _tp.C_PAD, f"at most {_tp.C_PAD} criteria, got {c}"
    benefit = jnp.asarray(benefit, bool)
    if valid is not None:
        valid = jnp.asarray(valid, bool)
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(jnp.sum(w), _EPS)
    mat = jnp.asarray(matrix).astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(mat * mat, axis=0))
    inv_norm = 1.0 / jnp.maximum(norms, _EPS)
    v = mat * inv_norm * w
    a_pos, a_neg = _topsis.masked_ideal_points(v, benefit, valid)

    if block_n is None:
        block_n = _auto_block_n(n)
    xt = _pad_to(_pad_to(mat.T, 0, _tp.C_PAD), 1, block_n)

    def col(x):  # (C,) -> (C_PAD, 1)
        return _pad_to(x.astype(jnp.float32)[:, None], 0, _tp.C_PAD)

    cc = _tp.topsis_closeness_blocks(xt, col(inv_norm), col(w), col(a_pos),
                                     col(a_neg), block_n=block_n,
                                     interpret=interpret)
    cc = cc[0, :n]
    if valid is not None:
        cc = jnp.where(valid, cc, -jnp.inf)
    return cc


def topsis_closeness_batched(mats: jax.Array, weights: jax.Array,
                             benefit: jax.Array, *,
                             valid: jax.Array | None = None,
                             block_n: int | None = None,
                             interpret: bool | None = None) -> jax.Array:
    """(P, N) closeness for a (P, N, C) queue tensor; C <= 8 (5 paper
    criteria or 6 with the carbon-rate column, both under C_PAD).

    The fleet-scale batch path: per-pod column norms and ideal points are
    global reductions in XLA; the Pallas kernel streams the (pods x node
    blocks) grid. ``weights`` is (C,) shared or (P, C) per pod; ``valid`` an
    optional (P, N) feasibility mask (excluded from ideals, -inf in the
    result, as in the single-matrix form).
    """
    if interpret is None:
        interpret = not _on_tpu()
    mats = jnp.asarray(mats).astype(jnp.float32)
    p, n, c = mats.shape
    assert c <= _tp.C_PAD, f"at most {_tp.C_PAD} criteria, got {c}"
    benefit = jnp.asarray(benefit, bool)
    if valid is not None:
        valid = jnp.asarray(valid, bool)
    w = jnp.asarray(weights, jnp.float32)
    if w.ndim == 1:
        w = jnp.broadcast_to(w, (p, c))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), _EPS)
    norms = jnp.sqrt(jnp.sum(mats * mats, axis=1))            # (P, C)
    inv_norm = 1.0 / jnp.maximum(norms, _EPS)
    v = mats * inv_norm[:, None, :] * w[:, None, :]
    a_pos, a_neg = _topsis.masked_ideal_points(v, benefit, valid)  # (P, C)

    if block_n is None:
        block_n = _auto_block_n(n)
    xt = _pad_to(_pad_to(mats.transpose(0, 2, 1), 1, _tp.C_PAD), 2, block_n)

    def col(x):  # (P, C) -> (P, C_PAD, 1)
        return _pad_to(x.astype(jnp.float32), 1, _tp.C_PAD)[:, :, None]

    cc = _tp.topsis_closeness_batched_blocks(
        xt, col(inv_norm), col(w), col(a_pos), col(a_neg),
        block_n=block_n, interpret=interpret)
    cc = cc[:, 0, :n]
    if valid is not None:
        cc = jnp.where(valid, cc, -jnp.inf)
    return cc


def topsis_closeness_grid(mats: jax.Array, weights: jax.Array,
                          benefit: jax.Array, *,
                          valid: jax.Array | None = None,
                          block_n: int | None = None,
                          interpret: bool | None = None) -> jax.Array:
    """(S, P, N) closeness for a (P, N, C) queue tensor under an (S, C)
    weight-scheme grid; C <= 8. The Pareto-sweep batch path: column norms
    are scheme-independent and computed once per pod, the per-(scheme, pod)
    ideal points are global reductions in XLA, and the Pallas kernel walks
    the (pods x node blocks x schemes) grid with schemes innermost so each
    criteria node-block is fetched from HBM once and reused across all S
    schemes (see ``topsis_pallas.topsis_closeness_grid_blocks``). ``valid``
    is the usual (P, N) feasibility mask, shared by every scheme; row
    semantics match ``repro.core.topsis.closeness_grid``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    mats = jnp.asarray(mats).astype(jnp.float32)
    p, n, c = mats.shape
    assert c <= _tp.C_PAD, f"at most {_tp.C_PAD} criteria, got {c}"
    benefit = jnp.asarray(benefit, bool)
    if valid is not None:
        valid = jnp.asarray(valid, bool)
    ws = jnp.asarray(weights, jnp.float32)
    assert ws.ndim == 2 and ws.shape[-1] == c, (ws.shape, mats.shape)
    s = ws.shape[0]
    ws = ws / jnp.maximum(jnp.sum(ws, axis=-1, keepdims=True), _EPS)
    norms = jnp.sqrt(jnp.sum(mats * mats, axis=1))              # (P, C)
    inv_norm = 1.0 / jnp.maximum(norms, _EPS)
    # (S, P, N, C) weighted normalized tensor — only for the ideal-point
    # reductions; the kernel recomputes v blockwise from the (P, N, C) data
    v = mats[None] * inv_norm[None, :, None, :] * ws[:, None, None, :]
    a_pos, a_neg = _topsis.masked_ideal_points(
        v, benefit, None if valid is None else valid[None])     # (S, P, C)

    if block_n is None:
        block_n = _auto_block_n(n)
    xt = _pad_to(_pad_to(mats.transpose(0, 2, 1), 1, _tp.C_PAD), 2, block_n)

    def col_p(x):   # (P, C) -> (P, C_PAD, 1)
        return _pad_to(x.astype(jnp.float32), 1, _tp.C_PAD)[:, :, None]

    def col_sp(x):  # (S, P, C) -> (S, P, C_PAD, 1)
        return _pad_to(x.astype(jnp.float32), 2, _tp.C_PAD)[:, :, :, None]

    wsp = jnp.broadcast_to(ws[:, None, :], (s, p, c))
    cc = _tp.topsis_closeness_grid_blocks(
        xt, col_p(inv_norm), col_sp(wsp), col_sp(a_pos), col_sp(a_neg),
        block_n=block_n, interpret=interpret)
    cc = cc[:, :, 0, :n]
    if valid is not None:
        cc = jnp.where(valid[None], cc, -jnp.inf)
    return cc


def topsis_closeness_kinds(mats_kinds: jax.Array, kind_idx: jax.Array,
                           weights: jax.Array, benefit: jax.Array, *,
                           valid: jax.Array | None = None,
                           block_n: int | None = None,
                           interpret: bool | None = None) -> jax.Array:
    """(P, N) closeness from a deduplicated (K, N, C) kind tensor plus a
    (P,) pod->kind index; C <= 8. The incremental batch path: the fleet
    criteria cache keeps one matrix per workload *kind* (K is small — the
    paper's workload mix has three), so the kernel streams K criteria
    tensors instead of P near-duplicate pod copies.

    Per-pod column norms are gathered from per-kind norms — bitwise equal
    to the per-pod reduction because each pod's rows ARE its kind's rows.
    Ideal points stay per pod (``valid`` differs pod to pod) and run in
    XLA; ``weights`` is (C,) shared or (P, C) per pod; result semantics
    (invalid -> -inf) match :func:`topsis_closeness_batched`.
    """
    if interpret is None:
        interpret = not _on_tpu()
    mats_kinds = jnp.asarray(mats_kinds).astype(jnp.float32)
    k, n, c = mats_kinds.shape
    kind_idx = jnp.asarray(kind_idx, jnp.int32)
    p = kind_idx.shape[0]
    assert c <= _tp.C_PAD, f"at most {_tp.C_PAD} criteria, got {c}"
    benefit = jnp.asarray(benefit, bool)
    if valid is not None:
        valid = jnp.asarray(valid, bool)
    w = jnp.asarray(weights, jnp.float32)
    if w.ndim == 1:
        w = jnp.broadcast_to(w, (p, c))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), _EPS)
    knorms = jnp.sqrt(jnp.sum(mats_kinds * mats_kinds, axis=1))   # (K, C)
    inv_norm = (1.0 / jnp.maximum(knorms, _EPS))[kind_idx]        # (P, C)
    v = mats_kinds[kind_idx] * inv_norm[:, None, :] * w[:, None, :]
    a_pos, a_neg = _topsis.masked_ideal_points(v, benefit, valid)  # (P, C)

    if block_n is None:
        block_n = _auto_block_n(n)
    xt = _pad_to(_pad_to(mats_kinds.transpose(0, 2, 1), 1, _tp.C_PAD),
                 2, block_n)

    def col(x):  # (P, C) -> (P, C_PAD, 1)
        return _pad_to(x.astype(jnp.float32), 1, _tp.C_PAD)[:, :, None]

    cc = _tp.topsis_closeness_kinds_blocks(
        kind_idx, xt, col(inv_norm), col(w), col(a_pos), col(a_neg),
        block_n=block_n, interpret=interpret)
    cc = cc[:, 0, :n]
    if valid is not None:
        cc = jnp.where(valid, cc, -jnp.inf)
    return cc


# --- RMSNorm ----------------------------------------------------------------
def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6, *,
            block_rows: int = 256, interpret: bool | None = None) -> jax.Array:
    """Fused RMSNorm over the last axis of x (any leading shape)."""
    if interpret is None:
        interpret = not _on_tpu()
    d = x.shape[-1]
    lead = x.shape[:-1]
    rows = int(np.prod(lead)) if lead else 1
    x2d = _pad_to(_pad_to(x.reshape(rows, d), 1, 128), 0, block_rows)
    g2d = _pad_to(gamma.reshape(1, d), 1, 128)
    out = _rn.rmsnorm_blocks(x2d, g2d, eps=eps, d_true=d,
                             block_rows=min(block_rows, x2d.shape[0]),
                             interpret=interpret)
    return out[:rows, :d].reshape(*lead, d)


# --- Flash attention ----------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_core(q, k, v, causal, window, sm_scale, bq, bk, kv_len,
                interpret):
    out, _ = _fa.flash_attention_blocks(
        q, k, v, sm_scale=sm_scale, causal=causal, window=window,
        bq=bq, bk=bk, kv_len=kv_len, interpret=interpret)
    return out


def _flash_core_fwd(q, k, v, causal, window, sm_scale, bq, bk, kv_len,
                    interpret):
    out, lse = _fa.flash_attention_blocks(
        q, k, v, sm_scale=sm_scale, causal=causal, window=window,
        bq=bq, bk=bk, kv_len=kv_len, interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, window, sm_scale, bq, bk, kv_len, interpret,
                    res, do):
    q, k, v, out, lse = res
    dq, dk, dv = _fa.flash_attention_bwd_blocks(
        q, k, v, out, lse, do, sm_scale=sm_scale, causal=causal,
        window=window, bq=bq, bk=bk, kv_len=kv_len, interpret=interpret)
    return dq, dk, dv


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    sm_scale: float | None = None, bq: int = 128,
                    bk: int = 128, interpret: bool | None = None) -> jax.Array:
    """(B, H, S, D) GQA flash attention; pads S to block multiples and D to
    the 128-lane boundary. Differentiable: backward runs the flash backward
    Pallas kernels (dq + fused dk/dv), not a rematerialized-score fallback."""
    if interpret is None:
        interpret = not _on_tpu()
    b, h, sq, d = q.shape
    skv = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    bq = min(bq, max(8, 1 << (sq - 1).bit_length()))
    bk = min(bk, max(8, 1 << (skv - 1).bit_length()))
    qp = _pad_to(_pad_to(q, 2, bq), 3, 128)
    kp = _pad_to(_pad_to(k, 2, bk), 3, 128)
    vp = _pad_to(_pad_to(v, 2, bk), 3, 128)
    out = _flash_core(qp, kp, vp, causal, window, sm_scale, bq, bk, skv,
                      interpret)
    return out[:, :, :sq, :d]

"""Pallas TPU kernel for fleet-scale TOPSIS batch scoring.

At 1000+ node scale the scheduler scores N candidate slices x C criteria for
every arriving job; the hot loop is the weighted-normalize + two Euclidean
distances + closeness. This kernel tiles alternatives along the TPU lane axis
(layout (C_pad, N): criteria on sublanes, alternatives on lanes) so the
distance reduction is a cheap sublane reduction, and streams N in VMEM-sized
blocks.

Column norms and the ideal/anti-ideal rows are global O(N*C) reductions,
computed once in the wrapper (repro.kernels.ops.topsis_closeness) — the
kernel consumes them as small VMEM-resident operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
C_PAD = 8          # criteria padded to one sublane group
DEFAULT_BLOCK_N = 2048

_EPS = 1e-12


def _topsis_kernel(xt_ref, inv_norm_ref, w_ref, a_pos_ref, a_neg_ref, cc_ref):
    """One block: xt (C_PAD, BLOCK_N) raw criteria (transposed);
    inv_norm/w/a_pos/a_neg (C_PAD, 1); out cc (1, BLOCK_N).

    Padded criteria rows carry zeros in w and a_pos/a_neg, so they
    contribute nothing to the distances.
    """
    xt = xt_ref[...].astype(jnp.float32)
    v = xt * inv_norm_ref[...] * w_ref[...]            # weighted normalized
    dp = v - a_pos_ref[...]
    dn = v - a_neg_ref[...]
    d_pos = jnp.sqrt(jnp.sum(dp * dp, axis=0, keepdims=True))
    d_neg = jnp.sqrt(jnp.sum(dn * dn, axis=0, keepdims=True))
    denom = d_pos + d_neg
    cc = d_neg / jnp.maximum(denom, _EPS)
    cc_ref[...] = jnp.where(denom <= _EPS, 0.5, cc)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def topsis_closeness_blocks(xt: jax.Array, inv_norm: jax.Array, w: jax.Array,
                            a_pos: jax.Array, a_neg: jax.Array,
                            block_n: int = DEFAULT_BLOCK_N,
                            interpret: bool = False) -> jax.Array:
    """xt: (C_PAD, N_pad) with N_pad % block_n == 0; small operands (C_PAD, 1).
    Returns (1, N_pad) closeness coefficients."""
    c_pad, n_pad = xt.shape
    assert c_pad == C_PAD and n_pad % block_n == 0, (xt.shape, block_n)
    grid = (n_pad // block_n,)
    small = pl.BlockSpec((C_PAD, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _topsis_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C_PAD, block_n), lambda i: (0, i)),
            small, small, small, small,
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        interpret=interpret,
    )(xt, inv_norm, w, a_pos, a_neg)


def _topsis_batched_kernel(xt_ref, inv_norm_ref, w_ref, a_pos_ref, a_neg_ref,
                           cc_ref):
    """One (pod, node-block) grid cell: xt (1, C_PAD, BLOCK_N) raw criteria
    for pod p; per-pod small operands (1, C_PAD, 1); out cc (1, 1, BLOCK_N).
    Same math as :func:`_topsis_kernel` with the pod axis leading — the
    criteria reduction stays a sublane reduction (axis=1)."""
    xt = xt_ref[...].astype(jnp.float32)
    v = xt * inv_norm_ref[...] * w_ref[...]
    dp = v - a_pos_ref[...]
    dn = v - a_neg_ref[...]
    d_pos = jnp.sqrt(jnp.sum(dp * dp, axis=1, keepdims=True))
    d_neg = jnp.sqrt(jnp.sum(dn * dn, axis=1, keepdims=True))
    denom = d_pos + d_neg
    cc = d_neg / jnp.maximum(denom, _EPS)
    cc_ref[...] = jnp.where(denom <= _EPS, 0.5, cc)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def topsis_closeness_batched_blocks(xt: jax.Array, inv_norm: jax.Array,
                                    w: jax.Array, a_pos: jax.Array,
                                    a_neg: jax.Array,
                                    block_n: int = DEFAULT_BLOCK_N,
                                    interpret: bool = False) -> jax.Array:
    """Whole-queue scoring: xt (P, C_PAD, N_pad) with N_pad % block_n == 0;
    per-pod small operands (P, C_PAD, 1). Grid is (pods, node blocks);
    returns (P, 1, N_pad) closeness coefficients."""
    p, c_pad, n_pad = xt.shape
    assert c_pad == C_PAD and n_pad % block_n == 0, (xt.shape, block_n)
    grid = (p, n_pad // block_n)
    small = pl.BlockSpec((1, C_PAD, 1), lambda b, i: (b, 0, 0))
    return pl.pallas_call(
        _topsis_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C_PAD, block_n), lambda b, i: (b, 0, i)),
            small, small, small, small,
        ],
        out_specs=pl.BlockSpec((1, 1, block_n), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((p, 1, n_pad), jnp.float32),
        interpret=interpret,
    )(xt, inv_norm, w, a_pos, a_neg)


def _topsis_grid_kernel(xt_ref, inv_norm_ref, w_ref, a_pos_ref, a_neg_ref,
                        cc_ref):
    """One (pod, node-block, scheme) grid cell of the weight-grid form:
    xt (1, C_PAD, BLOCK_N) raw criteria for pod p — scheme-independent, so
    its BlockSpec index map ignores the scheme coordinate and the pipeline
    keeps the block resident across all S schemes; per-(scheme, pod) small
    operands (1, 1, C_PAD, 1); out cc (1, 1, 1, BLOCK_N). Math is
    :func:`_topsis_batched_kernel` with the scheme block-dim stripped."""
    xt = xt_ref[...].astype(jnp.float32)
    v = xt * inv_norm_ref[...] * w_ref[0]
    dp = v - a_pos_ref[0]
    dn = v - a_neg_ref[0]
    d_pos = jnp.sqrt(jnp.sum(dp * dp, axis=1, keepdims=True))
    d_neg = jnp.sqrt(jnp.sum(dn * dn, axis=1, keepdims=True))
    denom = d_pos + d_neg
    cc = d_neg / jnp.maximum(denom, _EPS)
    cc_ref[...] = jnp.where(denom <= _EPS, 0.5, cc)[None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def topsis_closeness_grid_blocks(xt: jax.Array, inv_norm: jax.Array,
                                 w: jax.Array, a_pos: jax.Array,
                                 a_neg: jax.Array,
                                 block_n: int = DEFAULT_BLOCK_N,
                                 interpret: bool = False) -> jax.Array:
    """Weight-scheme-grid scoring: xt (P, C_PAD, N_pad) raw criteria shared
    by every scheme; per-pod inv_norm (P, C_PAD, 1); per-(scheme, pod)
    w / a_pos / a_neg (S, P, C_PAD, 1). The grid is (pods, node blocks,
    schemes) with the scheme axis INNERMOST (fastest-varying): Pallas only
    re-fetches an operand block when its index-map output changes between
    consecutive grid steps, and xt's map ignores the scheme coordinate — so
    each (pod, node-block) criteria tile is pulled from HBM once and reused
    across all S schemes, keeping criteria traffic at O(P*N) rather than
    O(S*P*N). Schemes lead the OUTPUT layout instead: returns
    (S, P, 1, N_pad) closeness, one contiguous (P, N) plane per scheme."""
    p, c_pad, n_pad = xt.shape
    s = w.shape[0]
    assert c_pad == C_PAD and n_pad % block_n == 0, (xt.shape, block_n)
    assert w.shape == a_pos.shape == a_neg.shape == (s, p, C_PAD, 1), (
        w.shape, a_pos.shape, a_neg.shape)
    grid = (p, n_pad // block_n, s)
    small = pl.BlockSpec((1, 1, C_PAD, 1), lambda b, i, k: (k, b, 0, 0))
    return pl.pallas_call(
        _topsis_grid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C_PAD, block_n), lambda b, i, k: (b, 0, i)),
            pl.BlockSpec((1, C_PAD, 1), lambda b, i, k: (b, 0, 0)),
            small, small, small,
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block_n),
                               lambda b, i, k: (k, b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((s, p, 1, n_pad), jnp.float32),
        interpret=interpret,
    )(xt, inv_norm, w, a_pos, a_neg)


def _topsis_kinds_kernel(kind_ref, xt_ref, inv_norm_ref, w_ref, a_pos_ref,
                         a_neg_ref, cc_ref):
    """One (pod, node-block) grid cell of the kind-indexed form: the
    scalar-prefetch ``kind_ref`` steered this pod's criteria block — the
    BlockSpec index map reads ``kind_ref[b]`` — so ``xt_ref`` holds the
    (1, C_PAD, BLOCK_N) block of the pod's *workload kind*, not a per-pod
    copy. Math is identical to :func:`_topsis_batched_kernel`; the small
    operands stay per pod (each pod's feasibility mask shapes its ideal
    points even when the raw criteria rows are shared)."""
    del kind_ref       # consumed by the index maps, not the kernel body
    xt = xt_ref[...].astype(jnp.float32)
    v = xt * inv_norm_ref[...] * w_ref[...]
    dp = v - a_pos_ref[...]
    dn = v - a_neg_ref[...]
    d_pos = jnp.sqrt(jnp.sum(dp * dp, axis=1, keepdims=True))
    d_neg = jnp.sqrt(jnp.sum(dn * dn, axis=1, keepdims=True))
    denom = d_pos + d_neg
    cc = d_neg / jnp.maximum(denom, _EPS)
    cc_ref[...] = jnp.where(denom <= _EPS, 0.5, cc)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def topsis_closeness_kinds_blocks(kind_idx: jax.Array, xt: jax.Array,
                                  inv_norm: jax.Array, w: jax.Array,
                                  a_pos: jax.Array, a_neg: jax.Array,
                                  block_n: int = DEFAULT_BLOCK_N,
                                  interpret: bool = False) -> jax.Array:
    """Kind-indexed whole-queue scoring: xt (K, C_PAD, N_pad) holds one
    criteria tensor per *workload kind* (K << P), ``kind_idx`` (P,) int32
    maps each pod to its kind row, and per-pod small operands stay
    (P, C_PAD, 1). The grid is still (pods, node blocks), but the kernel
    streams each kind's blocks from HBM instead of P near-duplicate pod
    copies — the bandwidth saving that lets the batch path scale past the
    (P, N, C) materialization ceiling. Returns (P, 1, N_pad)."""
    k, c_pad, n_pad = xt.shape
    p = kind_idx.shape[0]
    assert c_pad == C_PAD and n_pad % block_n == 0, (xt.shape, block_n)
    grid = (p, n_pad // block_n)
    small = pl.BlockSpec((1, C_PAD, 1), lambda b, i, kind_ref: (b, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C_PAD, block_n),
                         lambda b, i, kind_ref: (kind_ref[b], 0, i)),
            small, small, small, small,
        ],
        out_specs=pl.BlockSpec((1, 1, block_n),
                               lambda b, i, kind_ref: (b, 0, i)),
    )
    return pl.pallas_call(
        _topsis_kinds_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, 1, n_pad), jnp.float32),
        interpret=interpret,
    )(kind_idx, xt, inv_norm, w, a_pos, a_neg)

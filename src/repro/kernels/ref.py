"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: tests sweep shapes/dtypes and
``assert_allclose`` the kernels (run in interpret mode on CPU) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


# --- TOPSIS batch scoring ---------------------------------------------------
def topsis_closeness_ref(matrix: jax.Array, weights: jax.Array,
                         benefit: jax.Array) -> jax.Array:
    """Closeness coefficients for an (N, C) decision matrix (float32).

    Mirrors repro.core.topsis.closeness without the valid-mask path (the
    fleet batch-scorer filters infeasible slices before scoring).
    """
    weights = weights / jnp.maximum(jnp.sum(weights), _EPS)
    norms = jnp.sqrt(jnp.sum(matrix * matrix, axis=0, keepdims=True))
    v = matrix / jnp.maximum(norms, _EPS) * weights
    a_pos = jnp.where(benefit, jnp.max(v, axis=0), jnp.min(v, axis=0))
    a_neg = jnp.where(benefit, jnp.min(v, axis=0), jnp.max(v, axis=0))
    d_pos = jnp.sqrt(jnp.sum((v - a_pos) ** 2, axis=1))
    d_neg = jnp.sqrt(jnp.sum((v - a_neg) ** 2, axis=1))
    cc = d_neg / jnp.maximum(d_pos + d_neg, _EPS)
    return jnp.where(d_pos + d_neg <= _EPS, 0.5, cc)


# --- RMSNorm ----------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * gamma.astype(jnp.float32)).astype(dtype)


# --- Flash attention (causal / full) ----------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, sm_scale: float | None = None,
                  window: int | None = None) -> jax.Array:
    """(B, H, S, D) x (B, Hkv, S, D) -> (B, H, S, D); GQA broadcast when
    H > Hkv; optional sliding window (mixtral)."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)

"""Cluster node model — paper Table I node categories — plus the
struct-of-arrays ``NodeTable`` the fleet-scale batched scheduler scores
against (one numpy array per column instead of one Python object per node).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.carbon import DEFAULT_REGIONS
from repro.core.elastic import ASLEEP
from repro.core.energy import NODE_ENERGY_PROFILES


@dataclasses.dataclass
class Node:
    name: str
    node_class: str          # "A" | "B" | "C" | "default"
    vcpus: float
    mem_gb: float
    # system components (kube-system etc.) reserve resources on the default node
    reserved_cpu: float = 0.0
    reserved_mem: float = 0.0
    used_cpu: float = 0.0
    used_mem: float = 0.0
    # grid region the node draws power from (carbon-aware stack,
    # repro.core.carbon); the paper's cluster keeps the single "default"
    region: str = "default"
    # power-state lifecycle (elastic fleet subsystem, repro.core.elastic):
    # "active" | "idle" | "asleep" | "waking", maintained by ElasticFleet
    # when an AutoscalePolicy drives the run. None (the default) means "no
    # lifecycle" — the awake criterion falls back to the static used_cpu
    # derivation and everything reproduces the policy-free engine bitwise.
    power_state: str | None = None

    @property
    def speed(self) -> float:
        return NODE_ENERGY_PROFILES[self.node_class]["speed"]

    @property
    def free_cpu(self) -> float:
        return self.vcpus - self.reserved_cpu - self.used_cpu

    @property
    def free_mem(self) -> float:
        return self.mem_gb - self.reserved_mem - self.used_mem

    @property
    def cpu_util(self) -> float:
        return (self.reserved_cpu + self.used_cpu) / self.vcpus

    @property
    def mem_util(self) -> float:
        return (self.reserved_mem + self.used_mem) / self.mem_gb

    def fits(self, cpu: float, mem: float) -> bool:
        return self.free_cpu >= cpu - 1e-9 and self.free_mem >= mem - 1e-9

    def bind(self, cpu: float, mem: float) -> None:
        assert self.fits(cpu, mem), f"overcommit on {self.name}"
        self.used_cpu += cpu
        self.used_mem += mem

    def release(self, cpu: float, mem: float) -> None:
        self.used_cpu -= cpu
        self.used_mem -= mem


@dataclasses.dataclass
class NodeTable:
    """Struct-of-arrays fleet view: column arrays over N nodes.

    The scheduler hot path builds its (N, 5) decision matrix by
    broadcasting over these columns — no Python-level per-node loop — which
    is what lets the same code scale from the paper's 4-node cluster to the
    1000+-node fleets the Pallas kernel targets. All columns are copied out
    of the source ``Node`` list at construction (a snapshot, not a view):
    rebuild via :meth:`from_nodes` after cluster state changes, or mutate
    the ``used_*`` arrays directly when the table is the source of truth
    (synthetic fleets from :func:`make_fleet`).
    """

    names: list[str]
    node_class: list[str]
    vcpus: np.ndarray          # (N,) float64
    mem_gb: np.ndarray
    reserved_cpu: np.ndarray
    reserved_mem: np.ndarray
    used_cpu: np.ndarray
    used_mem: np.ndarray
    speed: np.ndarray
    dyn_power_per_vcpu: np.ndarray
    idle_power: np.ndarray
    # grid region per node (carbon column lookups); defaults to "default"
    # everywhere for tables built before the carbon stack existed
    region: list[str] = dataclasses.field(default_factory=list)
    # power-state column (elastic fleet subsystem): None entries mean "no
    # lifecycle" and keep the legacy awake derivation for that node
    power_state: "list[str | None]" = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.region:
            self.region = ["default"] * len(self.names)
        if not self.power_state:
            self.power_state = [None] * len(self.names)
        # precompute the lifecycle masks once per snapshot so the hot
        # `awake` property stays a vectorized select (None = no lifecycle)
        if any(s is not None for s in self.power_state):
            self._state_known = np.asarray(
                [s is not None for s in self.power_state])
            self._state_awake = np.asarray(
                [s is not None and s != ASLEEP for s in self.power_state])
        else:
            self._state_known = None
            self._state_awake = None

    @classmethod
    def from_nodes(cls, nodes: Sequence[Node]) -> "NodeTable":
        prof = [NODE_ENERGY_PROFILES[n.node_class] for n in nodes]
        f64 = lambda xs: np.asarray(xs, dtype=np.float64)
        return cls(
            names=[n.name for n in nodes],
            node_class=[n.node_class for n in nodes],
            vcpus=f64([n.vcpus for n in nodes]),
            mem_gb=f64([n.mem_gb for n in nodes]),
            reserved_cpu=f64([n.reserved_cpu for n in nodes]),
            reserved_mem=f64([n.reserved_mem for n in nodes]),
            used_cpu=f64([n.used_cpu for n in nodes]),
            used_mem=f64([n.used_mem for n in nodes]),
            speed=f64([p["speed"] for p in prof]),
            dyn_power_per_vcpu=f64([p["dyn_power_per_vcpu"] for p in prof]),
            idle_power=f64([p["idle_power"] for p in prof]),
            region=[n.region for n in nodes],
            power_state=[n.power_state for n in nodes],
        )

    def __len__(self) -> int:
        return len(self.names)

    @property
    def free_cpu(self) -> np.ndarray:
        return self.vcpus - self.reserved_cpu - self.used_cpu

    @property
    def free_mem(self) -> np.ndarray:
        return self.mem_gb - self.reserved_mem - self.used_mem

    @property
    def cpu_util(self) -> np.ndarray:
        return (self.reserved_cpu + self.used_cpu) / self.vcpus

    @property
    def awake(self) -> np.ndarray:
        """Awake mask feeding the marginal-idle rule of the energy and
        carbon-rate criteria: an awake node's idle power is already paid,
        so a placement there costs only dynamic power. With a real
        power-state column (elastic fleet subsystem) a node is awake in
        every state but ASLEEP — in particular an empty-but-IDLE node is
        awake, unlike the static derivation that treats every empty node as
        a wake-up cost. Nodes without a lifecycle keep the legacy
        ``used_cpu > 0`` derivation, bitwise."""
        derived = self.used_cpu > 1e-9
        if self._state_known is None:
            return derived
        return np.where(self._state_known, self._state_awake, derived)

    def fits(self, cpu, mem) -> np.ndarray:
        """Bool feasibility mask (PodFitsResources filter): (N,) for scalar
        requests, (P, N) when cpu/mem are (P, 1) request columns."""
        return ((self.free_cpu >= cpu - 1e-9)
                & (self.free_mem >= mem - 1e-9))


@dataclasses.dataclass
class FleetState(NodeTable):
    """Delta-maintained :class:`NodeTable`: the event engine's single source
    of truth for fleet state.

    Where a plain ``NodeTable`` is a throwaway snapshot (rebuilt from the
    ``Node`` list every scoring call), a ``FleetState`` is *long-lived*: the
    engine routes every mutation — task commit, completion release, eviction,
    power-state transition — through :meth:`bind` / :meth:`release` /
    :meth:`set_power_states`, which update only the touched node's column
    entries (O(touched columns), no per-round O(N) re-flatten) and keep the
    backing ``Node`` objects in sync, so policies that read per-node views
    (``sim.state.nodes[i]``) keep working unchanged.

    Dirty-column contract: every mutation stamps the touched node with a
    monotonically increasing modification version. A consumer (the
    schedulers' incremental decision-matrix caches, the jax device mirror)
    remembers the :attr:`version` it last synced at and asks
    :meth:`modified_since` for the node indices whose criteria columns must
    be recomputed — anything else is guaranteed bitwise-identical to a fresh
    ``NodeTable.from_nodes(nodes)`` rebuild (tests/test_fleet_state.py pins
    this with a randomized-interleaving property test). Multiple consumers
    with independent cursors can share one ``FleetState``. Mutating the
    ``Node`` objects or the column arrays directly (instead of going through
    the mutators) breaks the contract — consumers would silently serve stale
    columns.
    """

    def __post_init__(self):
        super().__post_init__()
        # authoritative per-node views (set by from_nodes); kept in sync by
        # the mutators below so policy code can keep reading Node objects
        self.nodes: list[Node] = []
        self._mod = np.zeros(len(self.names), dtype=np.int64)
        self.version = 0

    @classmethod
    def from_nodes(cls, nodes: Sequence[Node]) -> "FleetState":
        fs = super().from_nodes(nodes)
        fs.nodes = list(nodes)
        return fs

    def _touch(self, i: int) -> None:
        self.version += 1
        self._mod[i] = self.version

    def modified_since(self, version: int) -> np.ndarray:
        """Indices of nodes mutated after a consumer's last-synced
        ``version`` (ascending). The consumer should store
        ``self.version`` as its new cursor *before* recomputing."""
        return np.flatnonzero(self._mod > version)

    def bind(self, i: int, cpu: float, mem: float) -> None:
        """Commit ``cpu``/``mem`` on node ``i``: Node object and ``used_*``
        columns move together, and the node is marked dirty."""
        node = self.nodes[i]
        node.bind(cpu, mem)
        self.used_cpu[i] = node.used_cpu
        self.used_mem[i] = node.used_mem
        self._touch(i)

    def release(self, i: int, cpu: float, mem: float) -> None:
        """Release ``cpu``/``mem`` on node ``i`` (completion or eviction)."""
        node = self.nodes[i]
        node.release(cpu, mem)
        self.used_cpu[i] = node.used_cpu
        self.used_mem[i] = node.used_mem
        self._touch(i)

    def set_power_states(self, states: "Sequence[str | None]") -> None:
        """Sync the power-state column to ``states`` (one entry per node),
        touching only nodes whose state actually changed — the elastic
        fleet rewrites all N states every round, but a round typically
        transitions a handful of nodes. State changes dirty the node
        because the ``awake`` mask feeds the energy and carbon-rate
        criteria columns."""
        changed = [i for i, (old, new)
                   in enumerate(zip(self.power_state, states)) if old != new]
        if not changed:
            return
        if self._state_known is None:
            self._state_known = np.asarray(
                [s is not None for s in self.power_state])
            self._state_awake = np.asarray(
                [s is not None and s != ASLEEP for s in self.power_state])
        for i in changed:
            s = states[i]
            self.power_state[i] = s
            self.nodes[i].power_state = s
            self._state_known[i] = s is not None
            self._state_awake[i] = s is not None and s != ASLEEP
            self._touch(i)


# Paper Table-I capacities (vcpus, mem_gb) per node class, and the capacity
# jitter applied to synthetic fleets — shared by make_fleet and
# make_scenario_cluster so the two fleet generators never desynchronize.
NODE_CAPS: dict[str, tuple[float, float]] = {
    "A": (2, 4), "B": (2, 8), "C": (4, 16), "default": (2, 8)}
CAP_SCALES = (1, 2, 4)


def make_fleet_nodes(n: int, seed: int = 0, utilization: float = 0.0,
                     regions: Sequence[str] = DEFAULT_REGIONS) -> list[Node]:
    """The ``Node`` objects behind :func:`make_fleet` — same rng stream,
    same values, but as mutable per-node views. Feed to
    :meth:`FleetState.from_nodes` when the fleet must be *maintained*
    (incremental engine rounds) rather than snapshotted once."""
    rng = np.random.default_rng(seed)
    classes = list(NODE_CAPS)
    nodes = []
    for i in range(n):
        cls_i = classes[int(rng.integers(len(classes)))]
        vcpus, mem = NODE_CAPS[cls_i]
        scale = float(rng.choice(CAP_SCALES))
        nodes.append(Node(f"node-{i:05d}", cls_i, vcpus * scale, mem * scale,
                          region=regions[i % len(regions)]))
    if utilization > 0.0:
        u = rng.uniform(0.0, min(2.0 * utilization, 0.95), n)
        for node, ui in zip(nodes, u):
            node.used_cpu = float(ui * (node.vcpus - node.reserved_cpu))
            node.used_mem = float(ui * (node.mem_gb - node.reserved_mem))
    return nodes


def make_fleet(n: int, seed: int = 0, utilization: float = 0.0,
               regions: Sequence[str] = DEFAULT_REGIONS) -> NodeTable:
    """Synthetic heterogeneous fleet of ``n`` nodes for benchmarks/examples:
    the paper's Table-I node classes replicated with jittered capacities and
    (optionally) random pre-existing load. Nodes are spread round-robin
    across ``regions`` (inert unless a carbon signal is attached)."""
    return NodeTable.from_nodes(make_fleet_nodes(n, seed=seed,
                                                 utilization=utilization,
                                                 regions=regions))


# Scenario fleet class mixes: probability of each Table-I node class.
# edge_heavy skews to frugal e2-medium-like boxes (far-edge deployments),
# cloud_heavy to the fast, power-hungry n2-standard-4 tier, mixed is uniform.
SCENARIO_PROFILES: dict[str, dict[str, float]] = {
    "edge_heavy": {"A": 0.60, "B": 0.25, "C": 0.05, "default": 0.10},
    "cloud_heavy": {"A": 0.05, "B": 0.25, "C": 0.60, "default": 0.10},
    "mixed": {"A": 0.25, "B": 0.25, "C": 0.25, "default": 0.25},
}


def make_scenario_cluster(profile: str, n: int, seed: int = 0,
                          regions: Sequence[str] = DEFAULT_REGIONS
                          ) -> list[Node]:
    """Scenario fleet for the event-driven engine: ``n`` mutable ``Node``
    objects (4 ≤ n ≤ 131072) whose class mix follows ``SCENARIO_PROFILES``.

    The first four nodes are one of each Table-I class at paper capacities
    (every fleet keeps the paper's heterogeneity axis; unlike
    :func:`make_paper_cluster`, no system reservations on the default
    node); the rest are drawn from the profile's mix with the capacity
    jitter of :func:`make_fleet`. Nodes are spread round-robin across
    ``regions`` (drives the carbon column when a signal is attached;
    inert otherwise). Deterministic in ``seed`` — scenario runs replay
    exactly. The engine wraps these in a delta-maintained
    :class:`FleetState` (burst scoring recomputes only dirty node columns,
    which is what lets scenario fleets scale past the old 8192 ceiling).
    """
    if profile not in SCENARIO_PROFILES:
        raise ValueError(f"unknown profile {profile!r}; "
                         f"choose from {sorted(SCENARIO_PROFILES)}")
    if not 4 <= n <= 131072:
        raise ValueError(f"fleet size {n} outside [4, 131072]")
    rng = np.random.default_rng(seed)
    mix = SCENARIO_PROFILES[profile]
    classes = list(mix)
    probs = np.asarray([mix[c] for c in classes], dtype=np.float64)
    nodes = []
    for i in range(n):
        cls_i = (classes[i] if i < 4
                 else classes[int(rng.choice(len(classes), p=probs))])
        vcpus, mem = NODE_CAPS[cls_i]
        scale = 1.0 if i < 4 else float(rng.choice(CAP_SCALES))
        nodes.append(Node(f"{profile}-{i:05d}", cls_i,
                          vcpus * scale, mem * scale,
                          region=regions[i % len(regions)]))
    return nodes


def make_paper_cluster() -> list[Node]:
    """Heterogeneous GKE cluster of paper Table I (one node per category)."""
    return [
        Node("node-a", "A", vcpus=2, mem_gb=4),                     # e2-medium
        Node("node-b", "B", vcpus=2, mem_gb=8),                     # n2-standard-2
        Node("node-c", "C", vcpus=4, mem_gb=16),                    # n2-standard-4
        Node("node-default", "default", vcpus=2, mem_gb=8,          # e2-standard-2
             reserved_cpu=0.5, reserved_mem=1.5),                   # system components
    ]

"""Cluster node model — paper Table I node categories."""
from __future__ import annotations

import dataclasses

from repro.core.energy import NODE_ENERGY_PROFILES


@dataclasses.dataclass
class Node:
    name: str
    node_class: str          # "A" | "B" | "C" | "default"
    vcpus: float
    mem_gb: float
    # system components (kube-system etc.) reserve resources on the default node
    reserved_cpu: float = 0.0
    reserved_mem: float = 0.0
    used_cpu: float = 0.0
    used_mem: float = 0.0

    @property
    def speed(self) -> float:
        return NODE_ENERGY_PROFILES[self.node_class]["speed"]

    @property
    def free_cpu(self) -> float:
        return self.vcpus - self.reserved_cpu - self.used_cpu

    @property
    def free_mem(self) -> float:
        return self.mem_gb - self.reserved_mem - self.used_mem

    @property
    def cpu_util(self) -> float:
        return (self.reserved_cpu + self.used_cpu) / self.vcpus

    @property
    def mem_util(self) -> float:
        return (self.reserved_mem + self.used_mem) / self.mem_gb

    def fits(self, cpu: float, mem: float) -> bool:
        return self.free_cpu >= cpu - 1e-9 and self.free_mem >= mem - 1e-9

    def bind(self, cpu: float, mem: float) -> None:
        assert self.fits(cpu, mem), f"overcommit on {self.name}"
        self.used_cpu += cpu
        self.used_mem += mem

    def release(self, cpu: float, mem: float) -> None:
        self.used_cpu -= cpu
        self.used_mem -= mem


def make_paper_cluster() -> list[Node]:
    """Heterogeneous GKE cluster of paper Table I (one node per category)."""
    return [
        Node("node-a", "A", vcpus=2, mem_gb=4),                     # e2-medium
        Node("node-b", "B", vcpus=2, mem_gb=8),                     # n2-standard-2
        Node("node-c", "C", vcpus=4, mem_gb=16),                    # n2-standard-4
        Node("node-default", "default", vcpus=2, mem_gb=8,          # e2-standard-2
             reserved_cpu=0.5, reserved_mem=1.5),                   # system components
    ]

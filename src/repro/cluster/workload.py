"""Containerized AIoT workloads (paper Table II), competition levels
(paper Table V), and the arrival processes that feed the event-driven
simulator (paper-mode t=0 burst, Poisson bursts, replayable JSON traces)."""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
import os


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    kind: str            # light | medium | complex
    cpu_request: float   # vCPU (K8s resource request)
    mem_request: float   # GB
    base_time_s: float   # runtime on a class-B node (speed 1.0), calibrated
    description: str


# Table II. base_time_s calibrated so the default-K8s column of Table VI is
# matched (DESIGN.md §7); TOPSIS columns are then predictions.
WORKLOADS: dict[str, WorkloadSpec] = {
    "light": WorkloadSpec("light", 0.2, 0.5, 12.6489,
                          "basic linear regression, 1k samples"),
    "medium": WorkloadSpec("medium", 0.5, 1.0, 55.4095,
                           "scalable linear regression, 1M samples"),
    "complex": WorkloadSpec("complex", 1.0, 2.0, 39.3375,
                            "distributed linear regression, 10M samples"),
}


@dataclasses.dataclass(frozen=True)
class Pod:
    uid: int
    workload: WorkloadSpec
    scheduler: str        # "topsis" | "default"
    # Carbon-aware temporal shifting (repro.core.carbon): a deferrable pod
    # may wait for a grid-carbon dip before scheduling — but never more than
    # deadline_s past its arrival — and may be preempted+requeued (once)
    # when its node's regional intensity spikes. Inert without a
    # CarbonPolicy on the run.
    deferrable: bool = False
    deadline_s: float = 600.0     # relative to arrival; must stay finite

    @property
    def cpu(self) -> float:
        return self.workload.cpu_request

    @property
    def mem(self) -> float:
        return self.workload.mem_request


# Table V: per scheduler pod counts (light, medium, complex).
COMPETITION_LEVELS: dict[str, dict[str, int]] = {
    "low": {"light": 2, "medium": 1, "complex": 1},
    "medium": {"light": 4, "medium": 2, "complex": 1},
    "high": {"light": 6, "medium": 3, "complex": 2},
}


def make_pods(level: str) -> list[Pod]:
    """Interleaved TOPSIS/default pod arrival stream for a competition level.

    The paper deploys both schedulers' pods concurrently on the shared
    cluster (Table V: 'N (k TOPSIS, k Default)'): arrivals are interleaved
    (default, topsis, default, ...), heavy pods first within each
    scheduler's batch. This reproduces the structure of paper Table VI —
    the default column is near-constant per level at low/medium (little
    cross-scheduler interaction) but varies slightly at high competition
    (0.4471 vs 0.4257), exactly the shared-cluster contention signature.
    """
    counts = COMPETITION_LEVELS[level]
    uid = itertools.count()
    pods: list[Pod] = []
    order = ["complex", "medium", "light"]
    per_sched = {
        s: [Pod(next(uid), WORKLOADS[k], s)
            for k in order for _ in range(counts[k])]
        for s in ("default", "topsis")
    }
    for d, t in zip(per_sched["default"], per_sched["topsis"]):
        pods.extend((d, t))
    return pods


# --- Arrival processes (event-driven simulator input) ------------------------
class ArrivalProcess:
    """A time-ordered stream of pod-arrival bursts.

    Implementations yield ``(t_arrival_s, [Pod, ...])`` events from
    :meth:`events`, non-decreasing in time. The event-driven simulator
    (``repro.cluster.simulator.run_scenario``) ingests each burst when the
    clock reaches it; TOPSIS pods of a burst can be scored in one batched
    pass (``BatchScheduler.select_many``). Processes must be deterministic
    for a fixed construction (seeded RNGs), so scenario runs replay exactly.
    """

    def events(self) -> "list[tuple[float, list[Pod]]]":
        raise NotImplementedError

    def total_pods(self) -> int:
        return sum(len(pods) for _, pods in self.events())


class PaperArrivals(ArrivalProcess):
    """Paper mode (§IV): every pod of a competition level arrives at t=0 in
    the interleaved Table-V stream — one burst, post-hoc energy over the
    busy union. ``table6()`` routes through this process, which is what
    pins the event-driven engine to the paper's factorial numbers."""

    def __init__(self, level: str):
        self.level = level

    def events(self):
        return [(0.0, make_pods(self.level))]


class PoissonArrivals(ArrivalProcess):
    """Poisson burst arrivals: burst epochs are a Poisson process of rate
    ``rate_per_s`` (exponential inter-arrival gaps), each burst holds
    ``burst_size`` pods whose kinds are drawn from ``mix`` (a
    kind -> probability dict over ``WORKLOADS``) and whose scheduler is
    "topsis" with probability ``topsis_share`` else "default". Fixed
    ``seed`` makes the stream replayable; ``n_bursts`` bounds the horizon.
    """

    def __init__(self, rate_per_s: float = 0.2, n_bursts: int = 10,
                 burst_size: int = 4, mix: dict[str, float] | None = None,
                 topsis_share: float = 0.5, seed: int = 0,
                 deferrable_share: float = 0.0, deadline_s: float = 600.0):
        if rate_per_s <= 0:
            raise ValueError("rate_per_s must be positive")
        self.rate_per_s = rate_per_s
        self.n_bursts = n_bursts
        self.burst_size = burst_size
        self.mix = dict(mix or {"light": 0.5, "medium": 0.3, "complex": 0.2})
        if any(k not in WORKLOADS for k in self.mix):
            raise ValueError(f"unknown workload kind in mix: {self.mix}")
        self.topsis_share = topsis_share
        # carbon-aware temporal shifting: each pod is deferrable with this
        # probability; at 0.0 (default) the RNG stream is untouched, so
        # pre-carbon scenarios replay bitwise
        if not 0.0 <= deferrable_share <= 1.0:
            raise ValueError(f"deferrable_share must be in [0, 1], "
                             f"got {deferrable_share}")
        if not (math.isfinite(deadline_s) and deadline_s > 0.0):
            raise ValueError(f"deadline_s must be finite and positive, "
                             f"got {deadline_s}")
        self.deferrable_share = deferrable_share
        self.deadline_s = deadline_s
        self.seed = seed

    def events(self):
        import numpy as np
        rng = np.random.default_rng(self.seed)
        kinds = list(self.mix)
        probs = np.asarray([self.mix[k] for k in kinds], dtype=np.float64)
        probs = probs / probs.sum()
        uid = itertools.count()
        t = 0.0
        out: list[tuple[float, list[Pod]]] = []
        for _ in range(self.n_bursts):
            t += float(rng.exponential(1.0 / self.rate_per_s))
            burst = [
                Pod(next(uid),
                    WORKLOADS[kinds[int(rng.choice(len(kinds), p=probs))]],
                    "topsis" if rng.uniform() < self.topsis_share
                    else "default",
                    deferrable=(self.deferrable_share > 0.0
                                and rng.uniform() < self.deferrable_share),
                    deadline_s=self.deadline_s)
                for _ in range(self.burst_size)
            ]
            out.append((t, burst))
        return out


class TraceArrivals(ArrivalProcess):
    """Replayable arrival trace: a list of ``{"t": float, "kind": str,
    "scheduler": "topsis"|"default", "count": int, "deferrable": bool,
    "deadline_s": float}`` entries (count defaults to 1, deferrable to
    False, deadline_s to the Pod default), e.g. loaded from a JSON file via
    :meth:`from_file`. Entries sharing one ``t`` form one burst; bursts are
    emitted in time-sorted order, entry order preserved within a burst — so
    a trace replays to the identical pod stream every run.

    Every entry is validated up front with a message naming the offending
    entry (and, when loaded via :meth:`from_file`, the source file) — a
    malformed trace fails at construction, not deep inside the event
    engine.
    """

    def __init__(self, entries: "list[dict]", source: str | None = None):
        self.entries = list(entries)
        prefix = f"{source}: " if source else ""
        for i, e in enumerate(self.entries):
            where = f"{prefix}trace entry {i} ({e!r})"
            if not isinstance(e, dict):
                raise ValueError(f"{where}: expected an object with at "
                                 f"least 't' and 'kind' fields")
            try:
                t_ok = math.isfinite(float(e["t"])) and float(e["t"]) >= 0.0
            except (KeyError, TypeError, ValueError):
                t_ok = False
            if not t_ok:
                raise ValueError(f"{where}: needs a finite non-negative "
                                 f"arrival time 't'")
            if e.get("kind") not in WORKLOADS:
                raise ValueError(
                    f"{where}: unknown workload kind {e.get('kind')!r}; "
                    f"choose from {sorted(WORKLOADS)}")
            if e.get("scheduler", "topsis") not in ("topsis", "default"):
                raise ValueError(
                    f"{where}: unknown scheduler {e['scheduler']!r}; "
                    f"choose 'topsis' or 'default'")
            count = e.get("count", 1)
            try:
                count_ok = int(count) == count and int(count) > 0
            except (TypeError, ValueError):
                count_ok = False
            if not count_ok:
                raise ValueError(f"{where}: 'count' must be a positive "
                                 f"integer, got {count!r}")
            ddl = e.get("deadline_s", 1.0)
            try:
                ddl_ok = math.isfinite(float(ddl)) and float(ddl) > 0.0
            except (TypeError, ValueError):
                ddl_ok = False
            if not ddl_ok:
                raise ValueError(f"{where}: 'deadline_s' must be finite "
                                 f"and positive, got {ddl!r}")

    @classmethod
    def from_file(cls, path) -> "TraceArrivals":
        """Load a JSON trace; ``path`` may be a ``str`` or any
        ``os.PathLike`` (``pathlib.Path``). Validation errors are prefixed
        with the file path and the offending entry's index."""
        with open(path) as f:
            return cls(json.load(f), source=os.fspath(path))

    def events(self):
        uid = itertools.count()
        by_t: dict[float, list[Pod]] = {}
        for e in sorted(self.entries, key=lambda e: float(e["t"])):
            pods = by_t.setdefault(float(e["t"]), [])
            kw = {}
            if "deferrable" in e:
                kw["deferrable"] = bool(e["deferrable"])
            if "deadline_s" in e:
                kw["deadline_s"] = float(e["deadline_s"])
            for _ in range(int(e.get("count", 1))):
                pods.append(Pod(next(uid), WORKLOADS[e["kind"]],
                                e.get("scheduler", "topsis"), **kw))
        return sorted(by_t.items())

"""Containerized AIoT workloads (paper Table II) and competition levels
(paper Table V)."""
from __future__ import annotations

import dataclasses
import itertools


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    kind: str            # light | medium | complex
    cpu_request: float   # vCPU (K8s resource request)
    mem_request: float   # GB
    base_time_s: float   # runtime on a class-B node (speed 1.0), calibrated
    description: str


# Table II. base_time_s calibrated so the default-K8s column of Table VI is
# matched (DESIGN.md §7); TOPSIS columns are then predictions.
WORKLOADS: dict[str, WorkloadSpec] = {
    "light": WorkloadSpec("light", 0.2, 0.5, 12.6489,
                          "basic linear regression, 1k samples"),
    "medium": WorkloadSpec("medium", 0.5, 1.0, 55.4095,
                           "scalable linear regression, 1M samples"),
    "complex": WorkloadSpec("complex", 1.0, 2.0, 39.3375,
                            "distributed linear regression, 10M samples"),
}


@dataclasses.dataclass(frozen=True)
class Pod:
    uid: int
    workload: WorkloadSpec
    scheduler: str        # "topsis" | "default"

    @property
    def cpu(self) -> float:
        return self.workload.cpu_request

    @property
    def mem(self) -> float:
        return self.workload.mem_request


# Table V: per scheduler pod counts (light, medium, complex).
COMPETITION_LEVELS: dict[str, dict[str, int]] = {
    "low": {"light": 2, "medium": 1, "complex": 1},
    "medium": {"light": 4, "medium": 2, "complex": 1},
    "high": {"light": 6, "medium": 3, "complex": 2},
}


def make_pods(level: str) -> list[Pod]:
    """Interleaved TOPSIS/default pod arrival stream for a competition level.

    The paper deploys both schedulers' pods concurrently on the shared
    cluster (Table V: 'N (k TOPSIS, k Default)'): arrivals are interleaved
    (default, topsis, default, ...), heavy pods first within each
    scheduler's batch. This reproduces the structure of paper Table VI —
    the default column is near-constant per level at low/medium (little
    cross-scheduler interaction) but varies slightly at high competition
    (0.4471 vs 0.4257), exactly the shared-cluster contention signature.
    """
    counts = COMPETITION_LEVELS[level]
    uid = itertools.count()
    pods: list[Pod] = []
    order = ["complex", "medium", "light"]
    per_sched = {
        s: [Pod(next(uid), WORKLOADS[k], s)
            for k in order for _ in range(counts[k])]
        for s in ("default", "topsis")
    }
    for d, t in zip(per_sched["default"], per_sched["topsis"]):
        pods.extend((d, t))
    return pods

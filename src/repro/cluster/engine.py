"""Discrete-event simulation kernel for the cluster engine.

This module owns the mechanics every scenario shares — the typed event
clock, the pending/running queues, the scheduling round, the per-node power
timeline — and nothing policy-specific. Carbon temporal shifting, the
elastic power-state lifecycle, and any future policy plug in through the
:class:`repro.core.policy.SchedulingPolicy` hook protocol; the kernel calls
their hooks at fixed points in each round and otherwise treats them as
opaque. ``repro.cluster.simulator.run_scenario`` is the thin driver that
composes the ordered policy list and calls :func:`simulate`.

Kernel semantics (kube-scheduler backoff-and-retry, idealized): a
scheduling round places every pending pod it can against current cluster
state; pods that do not fit wait in a FIFO queue and are retried whenever a
running task completes, a new burst arrives, or a policy wake fires. The
clock advances to the earliest candidate :class:`~repro.core.policy.Event`
— COMPLETION before ARRIVAL before wake-like on ties — releasing exactly
one completion per step (the backoff step). Pods still pending when no
event can ever free capacity are counted unschedulable. Every processed
event lands in ``SimState.event_log``, so a fixed scenario replays to an
identical log (tests/test_engine.py pins this determinism, plus bitwise
reproduction of the pre-kernel engine's outputs for every policy
combination).

State is explicit: :class:`SimState` holds the queues (running tasks are
:class:`RunningTask` dataclasses on a heap, not bare tuples), the records,
the timeline, per-pod bookkeeping (arrival instants,
:class:`EvictBlock` same-node restart blocks), and the event counters
policies publish into. Cluster capacity lives in a delta-maintained
:class:`~repro.cluster.node.FleetState` (``SimState.fleet``): commit,
completion, and eviction mutate its columns in place (O(touched columns)
per event, with dirty tracking the schedulers' incremental caches consume)
instead of re-flattening ``Node`` objects into a fresh snapshot per round;
``SimState.nodes`` is a per-node view over the same objects for policy
code. The eviction/requeue machinery
(:meth:`EventEngine.evict`) truncates a victim's record and power segment
at the eviction instant and hands the pod back for requeueing — carbon
preemption and consolidation drains are two callers of the same service.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Sequence

import numpy as np

from repro.core import telemetry
from repro.core.elastic import ASLEEP, NODE_WAKE_PROFILES
from repro.core.energy import (NODE_ENERGY_PROFILES, PowerTimeline,
                               task_energy_joules)
from repro.core.policy import ARRIVAL, COMPLETION, Event, SchedulingPolicy
from repro.core.scheduler import (BatchScheduler, DefaultK8sScheduler,
                                  GreenPodScheduler, predict_exec_time)
from repro.cluster.node import FleetState, Node, make_paper_cluster
from repro.cluster.workload import ArrivalProcess, Pod


@dataclasses.dataclass
class PodRecord:
    pod: Pod
    node: str
    node_class: str
    start_s: float
    runtime_s: float
    energy_j: float
    scheduling_time_s: float
    arrival_s: float = 0.0      # burst arrival time (deferral latency basis)


@dataclasses.dataclass(order=True)
class RunningTask:
    """One committed task on the running heap, ordered by ``(end_s, uid)``
    (uids are unique, so the tail fields never compare). ``record_index``
    and ``segment_index`` point at the task's :class:`PodRecord` and power
    segment so an eviction can truncate both at the eviction instant."""

    end_s: float
    uid: int
    pod: Pod = dataclasses.field(compare=False)
    node_index: int = dataclasses.field(compare=False)
    record_index: int = dataclasses.field(compare=False)
    segment_index: int = dataclasses.field(compare=False)


@dataclasses.dataclass(frozen=True)
class EvictBlock:
    """A same-node restart block: the node a pod was just evicted off, and
    the eviction instant. The block holds only while the clock stays at
    ``t`` (rounds can repeat at one instant via the backoff step); an
    instant same-node restart would discard the partial run for nothing."""

    node_index: int
    t: float


@dataclasses.dataclass
class SimResult:
    records: list[PodRecord]
    unschedulable: int
    timeline: PowerTimeline | None = None
    preemptions: int = 0
    # elastic fleet counters (autoscale runs; zero otherwise)
    migrations: int = 0        # tasks drained off consolidated nodes
    wakes: int = 0             # ASLEEP -> WAKING transitions
    sleeps: int = 0            # falls asleep (idle timeout or drain)
    # processed-event log: (t, kind, payload) per kernel event, in clock
    # order (None for results constructed outside the kernel)
    events: list | None = None
    # per-decision TOPSIS attributions (explain=True runs; None otherwise)
    explanations: list | None = None

    def _timeline(self) -> PowerTimeline:
        """The run's power timeline (rebuilt from records for results
        constructed without one)."""
        if self.timeline is None:
            self.timeline = PowerTimeline()
            for r in self.records:
                self.timeline.add(r.node, r.node_class, r.pod.scheduler,
                                  r.start_s, r.runtime_s,
                                  r.energy_j / r.runtime_s if r.runtime_s
                                  else 0.0)
        return self.timeline

    def energy_kj(self, scheduler: str) -> float:
        """Node-level energy attributed to a scheduler: per-pod dynamic energy
        plus each node's idle power for the union time that scheduler's pods
        keep the node awake (Table IV: 'efficiency of scheduling decisions
        from an energy optimization perspective') — read off the
        power-state timeline."""
        return self._timeline().energy_kj(scheduler)

    def energy_series(self, scheduler: str | None = None):
        """Time-resolved cumulative energy ``(edges_s, joules)`` for one
        scheduler (or the whole cluster when None)."""
        return self._timeline().energy_series(scheduler)

    def power_series(self, scheduler: str | None = None):
        """Piecewise-constant total power ``(edges_s, watts)``."""
        return self._timeline().power_series(scheduler)

    def total_carbon_g(self, scheduler: str | None = None) -> float:
        """Operational carbon (gCO2) off the power timeline — requires the
        run to have had a CarbonPolicy (signal attached to the timeline)."""
        return self._timeline().total_carbon_g(scheduler)

    def carbon_series(self, scheduler: str | None = None):
        """Time-resolved cumulative carbon ``(edges_s, grams)``."""
        return self._timeline().carbon_series(scheduler)

    def fleet_idle_energy_kj(self) -> float:
        """Every joule the fleet drew that is not task dynamic power:
        busy-union idle + power-state ledger (IDLE/ASLEEP/WAKING draw) +
        wake surges. On a run without an AutoscalePolicy the state ledger
        is empty and this reduces to the busy-union idle total — which
        *excludes* empty nodes' draw; when comparing a policy run against
        a no-policy baseline, use
        ``repro.core.elastic.always_on_fleet_idle_kj`` for the baseline
        side."""
        return self._timeline().fleet_idle_energy_kj()

    def fleet_energy_kj(self) -> float:
        """Whole-fleet energy: dynamic + :meth:`fleet_idle_energy_kj`."""
        return self._timeline().fleet_energy_kj()

    def state_energy_kj(self, state: str | None = None) -> float:
        """Energy drawn in one power state (or all, state=None) off the
        elastic state ledger, in kJ."""
        return self._timeline().state_energy_j(state) / 1000.0

    def fleet_carbon_g(self) -> float:
        """Whole-fleet carbon including the state ledger (needs a carbon
        signal on the run, like :meth:`total_carbon_g`)."""
        return self._timeline().fleet_carbon_g()

    def mean_deferral_latency_s(self, scheduler: str | None = None) -> float:
        """Mean wait between arrival and *first* start over deferrable pods
        (a preempted pod's requeued record does not reset its latency)."""
        first: dict[int, PodRecord] = {}
        for r in self.records:
            if not r.pod.deferrable:
                continue
            if scheduler is not None and r.pod.scheduler != scheduler:
                continue
            cur = first.get(r.pod.uid)
            if cur is None or r.start_s < cur.start_s:
                first[r.pod.uid] = r
        if not first:
            return 0.0
        return float(np.mean([r.start_s - r.arrival_s
                              for r in first.values()]))

    def mean_energy_kj(self, scheduler: str) -> float:
        """Per-pod average energy — the unit of paper Table VI (its kJ values
        decrease from low→high competition while pod counts grow ~3x, which is
        only consistent with a per-pod average). A preempted pod has one
        record per run attempt but counts once."""
        n = len({r.pod.uid for r in self.records
                 if r.pod.scheduler == scheduler})
        return self.energy_kj(scheduler) / n if n else 0.0

    def mean_sched_time_ms(self, scheduler: str) -> float:
        """Mean scheduling time per *attempt* (a preempted pod's requeued
        placement is a real second scheduling decision)."""
        ts = [r.scheduling_time_s for r in self.records
              if r.pod.scheduler == scheduler]
        return 1000.0 * float(np.mean(ts)) if ts else 0.0

    def mean_exec_time_s(self, scheduler: str) -> float:
        """Mean total time-on-cluster per pod (a preempted pod's truncated
        partial run and its rerun sum into one pod's total)."""
        totals: dict[int, float] = {}
        for r in self.records:
            if r.pod.scheduler == scheduler:
                totals[r.pod.uid] = totals.get(r.pod.uid, 0.0) + r.runtime_s
        return float(np.mean(list(totals.values()))) if totals else 0.0

    def unschedulable_rate(self) -> float:
        total = len({r.pod.uid for r in self.records}) + self.unschedulable
        return self.unschedulable / total if total else 0.0

    def allocation(self, scheduler: str) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            if r.pod.scheduler == scheduler:
                out[r.node_class] = out.get(r.node_class, 0) + 1
        return out

    def summary(self) -> dict:
        """Run metrics in the shape the benchmark sweeps record: run-level
        counters plus one entry per scheduler that placed pods."""
        out: dict = {
            "pods": len({r.pod.uid for r in self.records})
            + self.unschedulable,
            "unschedulable_rate": self.unschedulable_rate(),
            "preemptions": self.preemptions,
            "migrations": self.migrations,
            "wakes": self.wakes,
            "sleeps": self.sleeps,
            "schedulers": {},
        }
        for s in sorted({r.pod.scheduler for r in self.records}):
            out["schedulers"][s] = {
                "pods": len({r.pod.uid for r in self.records
                             if r.pod.scheduler == s}),
                "energy_kj": self.energy_kj(s),
                "mean_energy_kj": self.mean_energy_kj(s),
                "mean_sched_time_ms": self.mean_sched_time_ms(s),
                "mean_exec_time_s": self.mean_exec_time_s(s),
                "allocation": self.allocation(s),
            }
        if self.explanations:
            out["explanations"] = self.explanations
        return out


@dataclasses.dataclass
class SimState:
    """Everything one simulation run mutates, in one explicit structure.

    Policies read and mutate this through the engine's hook calls:
    ``pending`` is the FIFO retry queue, ``running`` a heap of
    :class:`RunningTask`, ``blocked`` the same-node restart blocks keyed by
    pod uid, ``arrival_s`` each pod's burst arrival instant (the deferral
    deadline basis), and the counter fields are what
    :class:`SimResult` reports.

    ``fleet`` — a delta-maintained :class:`FleetState` — is the single
    source of truth for cluster capacity and power states. The kernel
    mutates it through its column mutators (never the ``Node`` objects
    directly: that would bypass the dirty tracking the schedulers'
    incremental caches rely on); ``nodes`` is a read view over the same
    per-node objects for policy code."""

    fleet: FleetState
    schedulers: dict
    timeline: PowerTimeline
    pending: list[Pod] = dataclasses.field(default_factory=list)
    running: list[RunningTask] = dataclasses.field(default_factory=list)
    records: list[PodRecord] = dataclasses.field(default_factory=list)
    arrival_s: dict[int, float] = dataclasses.field(default_factory=dict)
    blocked: dict[int, EvictBlock] = dataclasses.field(default_factory=dict)
    event_log: list[tuple] = dataclasses.field(default_factory=list)
    t: float = 0.0
    unschedulable: int = 0
    preemptions: int = 0
    migrations: int = 0
    wakes: int = 0
    sleeps: int = 0

    @property
    def nodes(self) -> list[Node]:
        """Per-node views over the fleet (same objects ``fleet`` maintains);
        mutate capacity/power state through ``fleet``, not through these."""
        return self.fleet.nodes


class EventEngine:
    """The discrete-event kernel: one instance drives one scenario run.

    Policies receive this object in every hook; ``state`` exposes the
    queues and ledgers, and the kernel services below expose the shared
    machinery (:meth:`evict`, :meth:`block_restart`, :meth:`deadline`).
    """

    def __init__(self, state: SimState,
                 policies: Sequence[SchedulingPolicy],
                 arrivals: ArrivalProcess, batch: bool = False):
        self.state = state
        self.policies = tuple(policies)
        self.batch = batch
        self._events = sorted(arrivals.events(), key=lambda ev: ev[0])
        # sim-time series accumulators (observer-only: live on the engine,
        # never in SimState, and are touched only when telemetry is on)
        self._series_prev: tuple[float, float, float] | None = None
        self._series_energy_j = 0.0
        self._series_carbon_g = 0.0

    # --- kernel services (used by policies) ----------------------------------
    def deadline(self, pod: Pod) -> float:
        """The absolute instant a pod's deferral window closes: its burst
        arrival plus its relative ``deadline_s``."""
        return self.state.arrival_s.get(pod.uid, 0.0) + pod.deadline_s

    def block_restart(self, uid: int, node_index: int, t: float) -> None:
        """Forbid an instant same-node restart for a just-evicted pod (the
        block lapses once the clock leaves ``t``)."""
        self.state.blocked[uid] = EvictBlock(node_index, t)

    def evict(self, victims: Sequence[RunningTask], t: float) -> list[Pod]:
        """Evict running tasks at instant ``t`` (carbon preemption or a
        consolidation drain): release resources, truncate each victim's
        record and power segment at ``t``, notify every policy, and return
        the pods for the caller to requeue. A victim committed to a
        still-WAKING node has ``start_s > t`` — it never ran, so its
        partial attempt clamps to zero runtime/energy."""
        st = self.state
        telemetry.active().inc("engine_evictions", value=float(len(victims)))
        gone = {v.uid for v in victims}
        st.running[:] = [rt for rt in st.running if rt.uid not in gone]
        heapq.heapify(st.running)
        pods: list[Pod] = []
        for v in victims:
            st.fleet.release(v.node_index, v.pod.cpu, v.pod.mem)
            for pol in self.policies:
                pol.on_evict(self, v.node_index, t)
            rec = st.records[v.record_index]
            elapsed = max(t - rec.start_s, 0.0)
            rec.runtime_s = elapsed
            rec.energy_j = (st.timeline.segments[v.segment_index].dyn_power_w
                            * elapsed)
            st.timeline.truncate(v.segment_index, t)
            pods.append(v.pod)
        return pods

    # --- internals -----------------------------------------------------------
    def _commit(self, pod: Pod, idx: int, t: float,
                sched_time_s: float) -> None:
        """Bind pod to nodes[idx], append its record + running-heap entry,
        and post the task segment to the power timeline. A policy may move
        the task's effective start (a WAKING node's ready instant)."""
        st = self.state
        node = st.nodes[idx]
        st.fleet.bind(idx, pod.cpu, pod.mem)
        start = t
        for pol in self.policies:
            adjusted = pol.on_commit(self, idx, t)
            if adjusted is not None:
                start = adjusted
        rt = predict_exec_time(pod, node)
        ej = task_energy_joules(node.node_class, rt, pod.cpu)
        st.records.append(PodRecord(pod, node.name, node.node_class, start,
                                    rt, ej, sched_time_s,
                                    st.arrival_s.get(pod.uid, 0.0)))
        st.timeline.add(node.name, node.node_class, pod.scheduler, start, rt,
                        NODE_ENERGY_PROFILES[node.node_class]
                        ["dyn_power_per_vcpu"] * pod.cpu)
        heapq.heappush(st.running,
                       RunningTask(start + rt, pod.uid, pod, idx,
                                   len(st.records) - 1,
                                   len(st.timeline.segments) - 1))
        telemetry.active().inc("engine_commits", scheduler=pod.scheduler)

    def _pop_release(self) -> float:
        """Pop the earliest completion, release its resources, notify the
        policies, log the event, return its end time (the backoff step)."""
        st = self.state
        done = heapq.heappop(st.running)
        st.fleet.release(done.node_index, done.pod.cpu, done.pod.mem)
        for pol in self.policies:
            pol.on_completion(self, done.node_index, done.end_s)
        st.event_log.append((done.end_s, COMPLETION, done.uid))
        telemetry.active().inc("engine_events", kind=COMPLETION)
        return done.end_s

    def _run_burst(self, pods: list[Pod], t: float,
                   blocked_now: dict[int, int], exclude,
                   scheduler: str = "topsis") -> list[Pod]:
        """Schedule an arrival burst through one batched scoring pass
        (``select_many`` of the named scheduler — bursts are grouped by
        ``pod.scheduler``, so a mixed queue never scores through the wrong
        engine) and commit the assignments. Returns the pods that did not
        fit. ``blocked_now`` maps pod uid -> a node index the pod must not
        be committed to this round; the exclusion happens inside
        ``select_many``'s greedy ledger, so a blocked top choice falls
        through to the pod's next-ranked node without charging phantom
        capacity. ``exclude`` ((N,) or (P, N) bool) hard-masks
        policy-forbidden nodes out of the scoring validity."""
        st = self.state
        blocked = ([blocked_now.get(p.uid) for p in pods]
                   if blocked_now else None)
        assignments, diag = st.schedulers[scheduler].select_many(
            pods, st.fleet, now=t, blocked=blocked, exclude=exclude)
        still: list[Pod] = []
        for pod, idx in zip(pods, assignments):
            if idx is None:
                still.append(pod)
                continue
            self._commit(pod, idx, t, diag["per_pod_time_s"])
        return still

    def _record_series(self, tel) -> None:
        """Sample the sim-time metric timelines at the current clock
        instant (called after each scheduling round when recording is on).

        Strictly observer-side: reads sim state, writes telemetry. Every
        recorded value is a simulation quantity — queue depths, the fleet's
        instantaneous draw from the committed ledger, cumulative energy and
        carbon integrated piecewise-constant between clock advances — so
        the same scenario records bit-identical series on every backend.
        The cumulative series are the sampled operator view; the exact
        end-of-run totals stay on the :class:`PowerTimeline` ledger."""
        st = self.state
        t = st.t
        # per-node instantaneous draw: dynamic power of started tasks plus
        # the per-state baseline (busy-union idle rule for legacy nodes:
        # an empty always-on node draws nothing in the ledger either)
        power = [0.0] * len(st.nodes)
        for rt in st.running:
            seg = st.timeline.segments[rt.segment_index]
            if seg.start_s <= t:
                power[rt.node_index] += seg.dyn_power_w
        awake = 0
        for i, node in enumerate(st.nodes):
            s = node.power_state
            if s != ASLEEP:
                awake += 1
            if s is None:
                if node.used_cpu > 0.0:
                    power[i] += (NODE_ENERGY_PROFILES[node.node_class]
                                 ["idle_power"])
            elif s == ASLEEP:
                power[i] += (NODE_WAKE_PROFILES[node.node_class]
                             ["sleep_power_w"])
            else:       # active / idle / waking all draw the idle baseline
                power[i] += (NODE_ENERGY_PROFILES[node.node_class]
                             ["idle_power"])
        fleet_power = sum(power)
        sig = st.timeline.carbon_signal
        if sig is not None:
            from repro.core.carbon import J_PER_KWH
            carbon_rate = sum(
                p * sig.intensity(st.timeline.region_of(node.name), t)
                for p, node in zip(power, st.nodes) if p) / J_PER_KWH
        else:
            carbon_rate = 0.0
        if self._series_prev is not None:
            prev_t, prev_p, prev_r = self._series_prev
            if t > prev_t:
                self._series_energy_j += prev_p * (t - prev_t)
                self._series_carbon_g += prev_r * (t - prev_t)
        self._series_prev = (t, fleet_power, carbon_rate)
        tel.record("engine_pending_depth", t, float(len(st.pending)))
        tel.record("engine_running_tasks", t, float(len(st.running)))
        tel.record("fleet_awake_nodes", t, float(awake))
        tel.record("fleet_power_w", t, fleet_power)
        tel.record("fleet_energy_cum_kj", t, self._series_energy_j / 1000.0)
        if sig is not None:
            tel.record("fleet_carbon_cum_g", t, self._series_carbon_g)

    # --- the event loop ------------------------------------------------------
    def run(self) -> SimResult:
        st = self.state
        policies = self.policies
        events = self._events
        tel = telemetry.active()
        if tel.enabled:
            # the sim clock restarts at zero: timelines describe this run
            tel.clear_series()
        ei = 0
        while True:
            # ingest every burst due by the current clock
            while ei < len(events) and events[ei][0] <= st.t:
                burst_t, burst_pods = events[ei]
                for p in burst_pods:
                    for pol in policies:
                        pol.on_arrival(self, p, burst_t)
                    st.arrival_s.setdefault(p.uid, burst_t)
                st.pending.extend(burst_pods)
                st.event_log.append((burst_t, ARRIVAL, len(burst_pods)))
                tel.inc("engine_events", kind=ARRIVAL)
                ei += 1
            # safety net: release anything that finished before now (the
            # advance step never moves the clock past an unreleased
            # completion)
            while st.running and st.running[0].end_s < st.t:
                self._pop_release()
            if not st.pending and not st.running and ei >= len(events):
                break
            t = st.t
            # queue-depth gauges, sampled once per clock instant's round
            tel.set_gauge("engine_pending_depth", float(len(st.pending)))
            tel.set_gauge("engine_running_tasks", float(len(st.running)))
            for pol in policies:
                pol.on_clock(self, t)
            with tel.span("engine_round"):
                # round-start mutations: carbon preemption evictions, the
                # consolidation drain pass — requeued pods re-enter this
                # round's pending queue
                for pol in policies:
                    pol.on_round_start(self, t)
                blocked_now = {uid: b.node_index
                               for uid, b in st.blocked.items() if b.t == t}
                # exclusion masks for this round: the OR of every policy's
                # fleet-wide mask, plus per-pod extras (a policy may forbid
                # specific nodes for specific pods — deadline-late WAKING
                # nodes for deferrable pods)
                base_ex = None
                for pol in policies:
                    m = pol.exclude_mask(self, t)
                    if m is not None:
                        base_ex = m if base_ex is None else (base_ex | m)

                def _exclude_for(pod: Pod):
                    # per-pod extras run even when no policy set a
                    # fleet-wide mask (base may be None — a policy can be
                    # purely per-pod)
                    mask = base_ex
                    for pol in policies:
                        extra = pol.exclude_for(self, pod, mask, t)
                        if extra is not None:
                            mask = extra
                    return mask
                # deferral filter: policies hold pods out of this round
                # (they keep their queue position and retry at the
                # policy's wake)
                held: list[Pod] = []
                held_uids: set[int] = set()
                for pol in policies:
                    n_held = 0
                    for p in pol.filter_pending(self, st.pending, t):
                        if p.uid not in held_uids:
                            held.append(p)
                            held_uids.add(p.uid)
                            n_held += 1
                    if n_held:
                        tel.inc("policy_deferred_pods", value=float(n_held),
                                policy=type(pol).__name__)
                # scheduling round: place what fits, FIFO retry for the
                # rest. Batch-capable schedulers take the burst path,
                # grouped by pod.scheduler (in first-appearance order) so
                # a mixed queue routes each group through its own scoring
                # engine
                placed: set[int] = set()
                bursts: dict[str, list[Pod]] = {}
                for pod in st.pending:
                    if pod.uid in held_uids:
                        continue
                    sched = st.schedulers[pod.scheduler]
                    if self.batch and hasattr(sched, "select_many"):
                        bursts.setdefault(pod.scheduler, []).append(pod)
                        continue
                    idx, diag = sched.select(
                        pod, st.fleet, now=t, exclude=_exclude_for(pod))
                    if idx is None:
                        continue
                    if blocked_now.get(pod.uid) == idx:
                        # blocked instant same-node restart: wait like a
                        # deferred pod (guarantees a wake event to retry
                        # on)
                        held.append(pod)
                        held_uids.add(pod.uid)
                        continue
                    self._commit(pod, idx, t, diag["scheduling_time_s"])
                    placed.add(pod.uid)
                for group, burst in bursts.items():
                    per_pod = [_exclude_for(p) for p in burst]
                    if any(pp is not base_ex for pp in per_pod):
                        # a policy set per-pod extras: stack to (P, N),
                        # padding unmasked pods with the base (or an empty
                        # mask)
                        fill = (base_ex if base_ex is not None
                                else np.zeros(len(st.nodes), dtype=bool))
                        ex_b = np.stack([pp if pp is not None else fill
                                         for pp in per_pod])
                    else:
                        ex_b = base_ex
                    b_still = self._run_burst(burst, t, blocked_now, ex_b,
                                              scheduler=group)
                    placed.update({p.uid for p in burst}
                                  - {p.uid for p in b_still})
                st.pending = [p for p in st.pending if p.uid not in placed]
                # evicted-but-unplaced victims wait like held pods (the
                # block lapses once t advances)
                for p in st.pending:
                    if p.uid in blocked_now and p.uid not in held_uids:
                        held.append(p)
                        held_uids.add(p.uid)
                for pol in policies:
                    pol.on_round_end(self, st.pending, held, t)
            if tel.enabled:
                self._record_series(tel)
            # advance the clock to the earliest candidate event:
            # completion, arrival burst, or a policy wake
            next_arrival = events[ei][0] if ei < len(events) else None
            next_completion = (st.running[0].end_s if st.running else None)
            wake_ev: Event | None = None
            wake_pol: SchedulingPolicy | None = None
            for pol in policies:
                ev = pol.next_wake_time(self, t, held)
                if ev is not None and (wake_ev is None or ev < wake_ev):
                    wake_ev, wake_pol = ev, pol
            next_wake = wake_ev.t if wake_ev is not None else None
            if st.pending and next_completion is not None \
                    and (next_arrival is None
                         or next_completion <= next_arrival) \
                    and (next_wake is None or next_completion <= next_wake):
                # backoff step: free exactly one completed pod, then retry
                st.t = self._pop_release()
                continue
            if next_arrival is not None and (next_wake is None
                                             or next_arrival <= next_wake):
                if next_completion is not None \
                        and next_completion <= next_arrival:
                    # release completions due at-or-before the arrival (one
                    # per iteration) so the burst schedules against freed
                    # capacity — including the exact completion==arrival tie
                    st.t = self._pop_release()
                    continue
                st.t = next_arrival
                continue
            if next_wake is not None:
                if next_completion is not None \
                        and next_completion <= next_wake:
                    st.t = self._pop_release()
                    continue
                st.t = next_wake
                st.event_log.append((wake_ev.t, wake_ev.kind,
                                     wake_ev.payload))
                tel.inc("engine_events", kind=wake_ev.kind)
                wake_pol.on_tick(self, wake_ev)
                continue
            if st.pending:
                # no completions left, no future arrivals, no wakes:
                # nothing can ever fit
                st.unschedulable += len(st.pending)
                break
            break   # only running tasks remain; their records are complete
        # close the run at its horizon (latest task end or the final clock,
        # whichever is later): drain the still-running completions through
        # the policy hooks so post-last-task state lands in the ledgers,
        # then let every policy flush
        horizon = st.t
        for r in st.records:
            horizon = max(horizon, r.start_s + r.runtime_s)
        while st.running:
            self._pop_release()
        for pol in policies:
            pol.finalize(self, horizon)
        if tel.enabled:
            # end-of-run rollups (observer-only; guarded so disabled runs
            # skip the ledger walk entirely)
            st.timeline.publish_telemetry(tel)
            st.timeline.publish_series(tel)
            tel.set_gauge("engine_unschedulable", float(st.unschedulable))
        explanations: list | None = None
        for sched in st.schedulers.values():
            ex = getattr(sched, "explanations", None)
            if ex:
                explanations = (explanations or []) + ex
        return SimResult(st.records, st.unschedulable, st.timeline,
                         preemptions=st.preemptions,
                         migrations=st.migrations,
                         wakes=st.wakes, sleeps=st.sleeps,
                         events=st.event_log,
                         explanations=explanations)


def simulate(arrivals: ArrivalProcess, scheme: str,
             cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
             adaptive: bool = False, batch: bool = False,
             batch_backend: str = "jax",
             policies: Sequence[SchedulingPolicy] = (),
             explain: bool = False) -> SimResult:
    """Build a run (fleet, schedulers, timeline) and drive it through the
    kernel with the given ordered policy list.

    If any policy carries a ``carbon_signal``, the signal is attached to
    the TOPSIS schedulers (the sixth carbon-rate criterion) and to the
    run's power timeline (carbon accounting). With no policies the kernel
    reduces to the policy-free event loop — arrival and completion events
    only — and reproduces the pre-kernel engine bitwise.

    ``explain=True`` turns on per-decision TOPSIS attribution: every
    placement records the winner-vs-runner-up per-criterion closeness
    contributions (``SimResult.explanations``; surfaced in
    ``summary()``). Numpy scoring only — a batch run on jax/pallas
    raises at its first scoring round.
    """
    policies = tuple(policies)
    nodes = cluster_factory()
    signals = [p.carbon_signal for p in policies
               if p.carbon_signal is not None]
    if len({id(s) for s in signals}) > 1:
        raise ValueError(
            f"{len(signals)} policies supplied distinct carbon signals; "
            f"the schedulers and the power timeline take exactly one — "
            f"share a single signal object between the policies")
    csig = signals[0] if signals else None
    schedulers = {
        "topsis": (BatchScheduler(scheme, adaptive=adaptive,
                                  backend=batch_backend,
                                  carbon_signal=csig,
                                  explain=explain) if batch
                   else GreenPodScheduler(scheme, adaptive=adaptive,
                                          carbon_signal=csig,
                                          explain=explain)),
        "default": DefaultK8sScheduler(),
    }
    timeline = PowerTimeline(
        carbon_signal=csig,
        node_region=({n.name: n.region for n in nodes}
                     if csig is not None else None))
    fleet = FleetState.from_nodes(nodes)
    state = SimState(fleet=fleet, schedulers=schedulers, timeline=timeline)
    # schedulers adopt the fleet as a live snapshot: scoring rounds sync
    # only dirty node columns instead of re-flattening the Node list
    for sched in schedulers.values():
        if hasattr(sched, "attach"):
            sched.attach(fleet)
    engine = EventEngine(state, policies, arrivals, batch=batch)
    for pol in policies:
        pol.bind(engine)
    return engine.run()

"""Event-driven cluster simulation engine.

The paper's factorial experiment (§IV) is one point in this engine's input
space: every pod arriving at t=0 (``PaperArrivals``) on the 4-node Table-I
cluster. The engine itself consumes any ``ArrivalProcess`` — Poisson bursts,
replayed JSON traces — over any fleet (``make_scenario_cluster`` builds
edge-heavy / cloud-heavy / mixed fleets up to 8192 nodes), and accounts
energy on a per-node power-state timeline (``repro.core.energy.PowerTimeline``)
instead of a post-hoc interval union, so every run yields energy-vs-time
series per scheduler in addition to the paper's scalar totals (Table IV
metric definitions).

Event loop semantics (kube-scheduler backoff-and-retry, idealized): a
scheduling round places every pending pod it can against current cluster
state; pods that do not fit wait in a FIFO queue and are retried whenever a
running pod completes or a new burst arrives. With ``PaperArrivals`` this
reduces exactly to the legacy all-at-t0 loop — ``table6()`` reproduces the
pre-refactor paper-mode output bitwise (tests/test_scenarios.py pins it
against the recorded golden).

Carbon-aware temporal shifting (``carbon=CarbonPolicy(...)``) adds two
event kinds on top: *deferral* — a deferrable pod waits, bounded by its
deadline, for the fleet-minimum grid intensity to dip below the policy
threshold, with carbon-check wake events at the policy cadence (and always
exactly at a waiting pod's deadline) — and *preemption* — a running
deferrable task is evicted and requeued (at most once, never past its
deadline) when its node's regional intensity spikes above the preemption
threshold; its power-timeline segment is truncated at the eviction instant
so the energy/carbon interval splits between the partial and requeued runs.
Without a policy the loop is byte-for-byte the legacy one.

Elastic fleet events (``autoscale=AutoscalePolicy(...)``,
``repro.core.elastic``) give nodes a power-state lifecycle on top: *sleep*
— a node empty past the idle timeout falls ASLEEP lazily (no event needed;
rounds simply see it excluded and the state ledger records the transition
exactly); *wake* — pods that end a round unplaced wake the TOPSIS-best
sleeping node (a real event: the round re-runs when the wake completes,
and pods committed to a still-WAKING node start exactly at its ready
instant, never past a deferrable pod's deadline); *drain* — the periodic
consolidation pass evicts and requeues every task of a low-utilization
node through the same truncate-and-requeue machinery preemption uses, then
puts the node straight to sleep. State-dependent idle power, sleep
residuals, and wake surges land on the run's ``PowerTimeline`` state
ledger (``fleet_idle_energy_kj`` / ``fleet_carbon_g``). With
``autoscale=None`` none of this machinery runs and the engine reproduces
the policy-free output bitwise.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable

import numpy as np

from repro.core.carbon import CarbonPolicy
from repro.core.elastic import AutoscalePolicy, ElasticFleet
from repro.core.energy import (NODE_ENERGY_PROFILES, PowerTimeline,
                               task_energy_joules)
from repro.core.scheduler import (BatchScheduler, DefaultK8sScheduler,
                                  GreenPodScheduler, predict_exec_time)
from repro.cluster.node import Node, make_paper_cluster
from repro.cluster.workload import ArrivalProcess, PaperArrivals, Pod


@dataclasses.dataclass
class PodRecord:
    pod: Pod
    node: str
    node_class: str
    start_s: float
    runtime_s: float
    energy_j: float
    scheduling_time_s: float
    arrival_s: float = 0.0      # burst arrival time (deferral latency basis)


@dataclasses.dataclass
class SimResult:
    records: list[PodRecord]
    unschedulable: int
    timeline: PowerTimeline | None = None
    preemptions: int = 0
    # elastic fleet counters (autoscale runs; zero otherwise)
    migrations: int = 0        # tasks drained off consolidated nodes
    wakes: int = 0             # ASLEEP -> WAKING transitions
    sleeps: int = 0            # falls asleep (idle timeout or drain)

    def _timeline(self) -> PowerTimeline:
        """The run's power timeline (rebuilt from records for results
        constructed without one)."""
        if self.timeline is None:
            self.timeline = PowerTimeline()
            for r in self.records:
                self.timeline.add(r.node, r.node_class, r.pod.scheduler,
                                  r.start_s, r.runtime_s,
                                  r.energy_j / r.runtime_s if r.runtime_s
                                  else 0.0)
        return self.timeline

    def energy_kj(self, scheduler: str) -> float:
        """Node-level energy attributed to a scheduler: per-pod dynamic energy
        plus each node's idle power for the union time that scheduler's pods
        keep the node awake (Table IV: 'efficiency of scheduling decisions
        from an energy optimization perspective') — now read off the
        power-state timeline."""
        return self._timeline().energy_kj(scheduler)

    def energy_series(self, scheduler: str | None = None):
        """Time-resolved cumulative energy ``(edges_s, joules)`` for one
        scheduler (or the whole cluster when None)."""
        return self._timeline().energy_series(scheduler)

    def power_series(self, scheduler: str | None = None):
        """Piecewise-constant total power ``(edges_s, watts)``."""
        return self._timeline().power_series(scheduler)

    def total_carbon_g(self, scheduler: str | None = None) -> float:
        """Operational carbon (gCO2) off the power timeline — requires the
        run to have had a CarbonPolicy (signal attached to the timeline)."""
        return self._timeline().total_carbon_g(scheduler)

    def carbon_series(self, scheduler: str | None = None):
        """Time-resolved cumulative carbon ``(edges_s, grams)``."""
        return self._timeline().carbon_series(scheduler)

    def fleet_idle_energy_kj(self) -> float:
        """Every joule the fleet drew that is not task dynamic power:
        busy-union idle + power-state ledger (IDLE/ASLEEP/WAKING draw) +
        wake surges. On a run without an AutoscalePolicy the state ledger
        is empty and this reduces to the busy-union idle total — which
        *excludes* empty nodes' draw; when comparing a policy run against
        a no-policy baseline, use
        ``repro.core.elastic.always_on_fleet_idle_kj`` for the baseline
        side."""
        return self._timeline().fleet_idle_energy_kj()

    def fleet_energy_kj(self) -> float:
        """Whole-fleet energy: dynamic + :meth:`fleet_idle_energy_kj`."""
        return self._timeline().fleet_energy_kj()

    def state_energy_kj(self, state: str | None = None) -> float:
        """Energy drawn in one power state (or all, state=None) off the
        elastic state ledger, in kJ."""
        return self._timeline().state_energy_j(state) / 1000.0

    def fleet_carbon_g(self) -> float:
        """Whole-fleet carbon including the state ledger (needs a carbon
        signal on the run, like :meth:`total_carbon_g`)."""
        return self._timeline().fleet_carbon_g()

    def mean_deferral_latency_s(self, scheduler: str | None = None) -> float:
        """Mean wait between arrival and *first* start over deferrable pods
        (a preempted pod's requeued record does not reset its latency)."""
        first: dict[int, PodRecord] = {}
        for r in self.records:
            if not r.pod.deferrable:
                continue
            if scheduler is not None and r.pod.scheduler != scheduler:
                continue
            cur = first.get(r.pod.uid)
            if cur is None or r.start_s < cur.start_s:
                first[r.pod.uid] = r
        if not first:
            return 0.0
        return float(np.mean([r.start_s - r.arrival_s
                              for r in first.values()]))

    def mean_energy_kj(self, scheduler: str) -> float:
        """Per-pod average energy — the unit of paper Table VI (its kJ values
        decrease from low→high competition while pod counts grow ~3x, which is
        only consistent with a per-pod average). A preempted pod has one
        record per run attempt but counts once."""
        n = len({r.pod.uid for r in self.records
                 if r.pod.scheduler == scheduler})
        return self.energy_kj(scheduler) / n if n else 0.0

    def mean_sched_time_ms(self, scheduler: str) -> float:
        """Mean scheduling time per *attempt* (a preempted pod's requeued
        placement is a real second scheduling decision)."""
        ts = [r.scheduling_time_s for r in self.records
              if r.pod.scheduler == scheduler]
        return 1000.0 * float(np.mean(ts)) if ts else 0.0

    def mean_exec_time_s(self, scheduler: str) -> float:
        """Mean total time-on-cluster per pod (a preempted pod's truncated
        partial run and its rerun sum into one pod's total)."""
        totals: dict[int, float] = {}
        for r in self.records:
            if r.pod.scheduler == scheduler:
                totals[r.pod.uid] = totals.get(r.pod.uid, 0.0) + r.runtime_s
        return float(np.mean(list(totals.values()))) if totals else 0.0

    def unschedulable_rate(self) -> float:
        total = len({r.pod.uid for r in self.records}) + self.unschedulable
        return self.unschedulable / total if total else 0.0

    def allocation(self, scheduler: str) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            if r.pod.scheduler == scheduler:
                out[r.node_class] = out.get(r.node_class, 0) + 1
        return out


def _commit(pod: Pod, idx: int, nodes: list[Node], t: float,
            sched_time_s: float, records: list[PodRecord],
            running: list, timeline: PowerTimeline,
            arrival_s: float = 0.0, efleet: ElasticFleet | None = None) -> None:
    """Bind pod to nodes[idx], append its record + completion event, and
    post the task segment to the power timeline. The running-heap entry
    carries the record and segment indices so a preemption can truncate
    both at the eviction instant. With an elastic fleet the task's start is
    its *effective* start — delayed to the wake-completion instant when the
    chosen node is still WAKING."""
    node = nodes[idx]
    node.bind(pod.cpu, pod.mem)
    start = efleet.on_commit(idx, t) if efleet is not None else t
    rt = predict_exec_time(pod, node)
    ej = task_energy_joules(node.node_class, rt, pod.cpu)
    records.append(PodRecord(pod, node.name, node.node_class, start, rt,
                             ej, sched_time_s, arrival_s))
    timeline.add(node.name, node.node_class, pod.scheduler, start, rt,
                 NODE_ENERGY_PROFILES[node.node_class]["dyn_power_per_vcpu"]
                 * pod.cpu)
    heapq.heappush(running, (start + rt, pod.uid, pod, idx,
                             len(records) - 1, len(timeline.segments) - 1))


def _pop_release(running: list, nodes: list[Node],
                 efleet: ElasticFleet | None = None) -> float:
    """Pop the earliest completion, release its resources, return its end
    time (the backoff/retry step)."""
    end_t, _, done, idx, _, _ = heapq.heappop(running)
    nodes[idx].release(done.cpu, done.mem)
    if efleet is not None:
        efleet.on_complete(idx, end_t)
    return end_t


def _evict(victims: list[tuple], t: float, running: list, nodes: list[Node],
           records: list[PodRecord], timeline: PowerTimeline,
           efleet: ElasticFleet | None = None) -> list[Pod]:
    """Evict running-heap entries at instant ``t`` (carbon preemption or a
    consolidation drain): release resources, truncate each victim's record
    and power segment at ``t``, and return the pods to requeue. A victim
    committed to a still-WAKING node has ``start_s > t`` — it never ran, so
    its partial attempt clamps to zero runtime/energy."""
    gone = {e[1] for e in victims}
    running[:] = [e for e in running if e[1] not in gone]
    heapq.heapify(running)
    pods: list[Pod] = []
    for _, uid, pod, idx, rec_i, seg_i in victims:
        nodes[idx].release(pod.cpu, pod.mem)
        if efleet is not None:
            efleet.on_evict(idx, t)
        rec = records[rec_i]
        elapsed = max(t - rec.start_s, 0.0)
        rec.runtime_s = elapsed
        rec.energy_j = timeline.segments[seg_i].dyn_power_w * elapsed
        timeline.truncate(seg_i, t)
        pods.append(pod)
    return pods


def run_burst(pods: list[Pod], nodes: list[Node], sched: BatchScheduler,
              t: float, records: list[PodRecord], running: list,
              timeline: PowerTimeline,
              arrive: dict[int, float] | None = None,
              block: dict[int, int] | None = None,
              exclude=None, efleet: ElasticFleet | None = None) -> list[Pod]:
    """Schedule an arrival burst through one batched scoring pass
    (``BatchScheduler.select_many``) and commit the assignments. Returns
    the pods that did not fit. ``block`` maps pod uid -> a node index the
    pod must not be committed to this round (the node it was just
    preempted off — an instant same-node restart would discard the partial
    run for nothing); the exclusion happens inside ``select_many``'s
    greedy ledger, so a blocked top choice falls through to the pod's
    next-ranked node without charging phantom capacity. ``exclude`` ((N,)
    or (P, N) bool) hard-masks engine-forbidden nodes (ASLEEP capacity;
    per-pod deadline-late WAKING nodes) out of the scoring validity."""
    blocked = [block.get(p.uid) for p in pods] if block else None
    assignments, diag = sched.select_many(pods, nodes, now=t,
                                          blocked=blocked, exclude=exclude)
    still: list[Pod] = []
    for pod, idx in zip(pods, assignments):
        if idx is None:
            still.append(pod)
            continue
        _commit(pod, idx, nodes, t, diag["per_pod_time_s"], records, running,
                timeline, arrival_s=(arrive or {}).get(pod.uid, 0.0),
                efleet=efleet)
    return still


def run_scenario(arrivals: ArrivalProcess, scheme: str,
                 cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
                 adaptive: bool = False, batch: bool = False,
                 batch_backend: str = "jax",
                 carbon: CarbonPolicy | None = None,
                 autoscale: AutoscalePolicy | None = None) -> SimResult:
    """Drive one scenario through the event-driven engine.

    Events are pod-arrival bursts (from ``arrivals``) and task completions
    (from prior placements). Each scheduling round walks the FIFO pending
    queue against current cluster state: default-scheduler pods and
    per-pod TOPSIS go through ``select``; with ``batch=True`` the round's
    TOPSIS pods are scored in one ``BatchScheduler.select_many`` pass on
    ``batch_backend`` (the fleet-scale path — bursts route through the
    batched engine). After a round, the clock advances to the earliest of
    the next completion (releasing exactly one pod's resources before
    retrying, the legacy backoff step) or the next arrival burst. Pods
    still pending when no completion or arrival can ever free capacity are
    counted unschedulable.

    With a ``carbon`` policy the engine additionally (1) attaches the
    policy's signal to the TOPSIS schedulers (sixth carbon-rate criterion)
    and to the run's power timeline (carbon accounting); (2) *defers*
    deferrable pods while the fleet-minimum intensity exceeds
    ``carbon.defer_threshold`` — bounded by each pod's deadline — waking at
    ``carbon.check_interval_s`` cadence and exactly at deadlines; and (3)
    *preempts* a running deferrable task (at most once per pod, never past
    its deadline) when its node's regional intensity exceeds
    ``carbon.preempt_threshold``, truncating its timeline segment and
    requeueing it as pending. Deferred pods are never counted
    unschedulable while a wake event is still due.

    With an ``autoscale`` policy (``repro.core.elastic``) nodes get a
    power-state lifecycle: (1) every round excludes ASLEEP nodes and feeds
    real power states into the awake/marginal-idle criterion (an IDLE node
    is awake — zero marginal idle cost); (2) pods still pending after a
    round wake the TOPSIS-best sleeping nodes (a pod committed to a node
    that is still WAKING starts exactly at the wake-completion instant,
    and a deferrable pod is never committed to a WAKING node whose ready
    time lies past its deadline); (3) at ``consolidate_interval_s``
    cadence, low-utilization nodes are drained — every running task
    evicted, truncated, and requeued through the preemption machinery,
    only when it provably fits on the remaining awake fleet and never when
    a deferrable victim is at/past its deadline — and put straight to
    sleep. The fleet's IDLE/ASLEEP/WAKING draw and wake surges land on the
    timeline's state ledger (``SimResult.fleet_idle_energy_kj`` /
    ``fleet_carbon_g``). ``autoscale=None`` reproduces the policy-free
    engine bitwise.
    """
    nodes = cluster_factory()
    csig = carbon.signal if carbon is not None else None
    sched = {"topsis": (BatchScheduler(scheme, adaptive=adaptive,
                                       backend=batch_backend,
                                       carbon_signal=csig) if batch
                        else GreenPodScheduler(scheme, adaptive=adaptive,
                                               carbon_signal=csig)),
             "default": DefaultK8sScheduler()}
    events = sorted(arrivals.events(), key=lambda ev: ev[0])
    ei = 0
    pending: list[Pod] = []
    # running heap entries: (end_t, uid, pod, node_i, record_i, segment_i)
    running: list[tuple] = []
    records: list[PodRecord] = []
    timeline = PowerTimeline(
        carbon_signal=csig,
        node_region={n.name: n.region for n in nodes} if carbon else None)
    fleet_regions = sorted({n.region for n in nodes})
    arrive: dict[int, float] = {}      # uid -> burst arrival time
    preempted: set[int] = set()        # uids evicted once already
    evict_block: dict[int, tuple[int, float]] = {}   # uid -> (node_i, t_evict)
    n_preempt = 0
    n_migrations = 0
    efleet = (ElasticFleet(nodes, autoscale, timeline)
              if autoscale is not None else None)
    next_consolidate = (autoscale.consolidate_interval_s
                        if autoscale is not None
                        and autoscale.consolidate_interval_s is not None
                        else None)
    t = 0.0
    unschedulable = 0

    def _deadline(pod: Pod) -> float:
        return arrive.get(pod.uid, 0.0) + pod.deadline_s

    while True:
        # ingest every burst due by the current clock
        while ei < len(events) and events[ei][0] <= t:
            for p in events[ei][1]:
                if carbon is not None and p.deferrable and not (
                        math.isfinite(p.deadline_s) and p.deadline_s > 0.0):
                    # an unbounded deadline would let the wake loop spin
                    # forever under a never-dipping signal
                    raise ValueError(
                        f"deferrable pod {p.uid} needs a finite positive "
                        f"deadline_s, got {p.deadline_s}")
                arrive.setdefault(p.uid, events[ei][0])
            pending.extend(events[ei][1])
            ei += 1
        # safety net: release anything that finished before now (the advance
        # step below never moves the clock past an unreleased completion)
        while running and running[0][0] < t:
            _pop_release(running, nodes, efleet)
        if not pending and not running and ei >= len(events):
            break
        # elastic bookkeeping: finalize wake transitions completed by now
        # (their WAKING intervals land in the state ledger; the nodes turn
        # ACTIVE or IDLE before this round queries states)
        if efleet is not None:
            efleet.advance_to(t)
        # preemption event: evict running deferrable tasks whose node's
        # regional intensity spiked above the threshold (once per pod,
        # never past its deadline); truncate their ledger entries at t and
        # requeue them — they re-enter this round's pending queue and
        # either migrate to a cleaner region or defer for a dip. A victim
        # is blocked from the node it was evicted off for as long as the
        # clock stays at the eviction instant — an instant same-node
        # restart would discard the partial run for nothing, and rounds
        # can repeat at one t via the backoff step — and may return there
        # once time advances.
        if carbon is not None and carbon.preempt_threshold is not None:
            victims = [e for e in running
                       if e[0] > t and e[2].deferrable
                       and e[2].uid not in preempted and t < _deadline(e[2])
                       and carbon.signal.intensity(nodes[e[3]].region, t)
                       > carbon.preempt_threshold]
            if victims:
                pending.extend(_evict(victims, t, running, nodes, records,
                                      timeline, efleet))
                for _, uid, _, idx, _, _ in victims:
                    preempted.add(uid)
                    evict_block[uid] = (idx, t)
                n_preempt += len(victims)
        # consolidation drain event (elastic fleet): at the policy cadence,
        # evict + requeue every task of the low-utilization nodes the
        # policy picked (each provably fits on the remaining awake fleet;
        # deferrable victims are never drained at/past their deadline) and
        # put the emptied nodes straight to sleep. Requeued pods re-enter
        # this round's pending queue and re-place through the normal TOPSIS
        # round; the drained node is ASLEEP, so the exclusion mask keeps
        # them from bouncing straight back.
        if (efleet is not None and next_consolidate is not None
                and t >= next_consolidate):
            if running:
                drain_idxs, victims = efleet.consolidation_victims(
                    t, running, _deadline)
                if victims:
                    # drained pods go to the FRONT of the queue: they are
                    # older than any pod arriving this round, and restart
                    # priority is what keeps the drain-time fit guarantee
                    # (and deferrable victims' deadlines) honest against
                    # same-round arrival contention
                    pending[:0] = _evict(victims, t, running, nodes,
                                         records, timeline, efleet)
                    n_migrations += len(victims)
                    for i in drain_idxs:
                        efleet.force_sleep(i, t)
            next_consolidate = t + autoscale.consolidate_interval_s
        blocked_now = {uid: idx for uid, (idx, tt) in evict_block.items()
                       if tt == t}
        # exclusion masks for this round: ASLEEP nodes for everyone, plus —
        # per deferrable pod — WAKING nodes whose ready time lies past the
        # pod's deadline (it would start there, violating the deferral
        # contract). Also refresh the power-state column the awake
        # criterion reads.
        base_ex = None
        if efleet is not None:
            efleet.write_states(t)
            base_ex = efleet.exclude_mask(t)

        def _exclude_for(pod: Pod):
            if base_ex is None:
                return None
            if pod.deferrable and math.isfinite(pod.deadline_s):
                return efleet.exclude_for_deadline(base_ex, _deadline(pod))
            return base_ex
        # scheduling round: place what fits, FIFO retry for the rest;
        # deferrable pods sit out while the fleet-wide carbon dip test
        # fails and their deadline is still ahead
        defer_now = False
        if carbon is not None and any(p.deferrable for p in pending):
            defer_now = (carbon.signal.fleet_min(fleet_regions, t)
                         > carbon.defer_threshold)
        deferred: list[Pod] = []
        placed: set[int] = set()
        burst: list[Pod] = []
        for pod in pending:
            if defer_now and pod.deferrable and t < _deadline(pod) - 1e-12:
                deferred.append(pod)
                continue
            if batch and pod.scheduler == "topsis":
                burst.append(pod)
                continue
            idx, diag = sched[pod.scheduler].select(pod, nodes, now=t,
                                                    exclude=_exclude_for(pod))
            if idx is None:
                continue
            if blocked_now.get(pod.uid) == idx:
                deferred.append(pod)      # blocked instant same-node restart
                continue
            _commit(pod, idx, nodes, t, diag["scheduling_time_s"], records,
                    running, timeline, arrival_s=arrive.get(pod.uid, 0.0),
                    efleet=efleet)
            placed.add(pod.uid)
        if burst:
            ex_b = None
            if base_ex is not None:
                per_pod = [_exclude_for(p) for p in burst]
                ex_b = (np.stack(per_pod)
                        if any(pp is not base_ex for pp in per_pod)
                        else base_ex)
            b_still = run_burst(burst, nodes, sched["topsis"], t,
                                records, running, timeline, arrive,
                                block=blocked_now, exclude=ex_b,
                                efleet=efleet)
            placed.update({p.uid for p in burst} - {p.uid for p in b_still})
        pending = [p for p in pending if p.uid not in placed]
        # evicted-but-unplaced victims wait like deferred pods (guarantees
        # a wake event so they retry; the block lapses once t advances)
        in_deferred = {p.uid for p in deferred}
        deferred.extend(p for p in pending
                        if p.uid in blocked_now and p.uid not in in_deferred)
        # queue-pressure wake (elastic fleet): pods that ended this round
        # unplaced — and are not voluntarily deferring — wake the
        # TOPSIS-best sleeping nodes; the wake-completion event re-runs the
        # round, where the pods can commit onto the WAKING capacity
        if efleet is not None and pending:
            in_deferred_now = {p.uid for p in deferred}
            pressure = [p for p in pending if p.uid not in in_deferred_now]
            if pressure:
                efleet.wake_for_pressure(sched["topsis"], pressure, t)
        # advance the clock to the next event: completion, arrival burst,
        # or carbon-check wake (while pods defer or preemptable tasks run)
        next_arrival = events[ei][0] if ei < len(events) else None
        next_completion = running[0][0] if running else None
        next_wake = None
        if carbon is not None:
            cands = [_deadline(p) for p in deferred]
            if deferred:
                cands.append(t + carbon.check_interval_s)
            if carbon.preempt_threshold is not None and any(
                    e[0] > t and e[2].deferrable and e[1] not in preempted
                    and t < _deadline(e[2]) for e in running):
                cands.append(t + carbon.check_interval_s)
            cands = [c for c in cands if c > t]
            if cands:
                next_wake = min(cands)
        # elastic wake-like events: in-flight node wake completions (the
        # pending pods retry onto the now-awake capacity) and the next
        # consolidation tick (only while tasks run — a drained fleet has
        # nothing to consolidate, and an unconditional tick would keep the
        # loop alive forever)
        if efleet is not None:
            ecands = []
            nt = efleet.next_transition(t)
            if nt is not None:
                ecands.append(nt)
            if next_consolidate is not None and running \
                    and next_consolidate > t:
                ecands.append(next_consolidate)
            if ecands:
                ne = min(ecands)
                next_wake = ne if next_wake is None else min(next_wake, ne)
        if pending and next_completion is not None \
                and (next_arrival is None or next_completion <= next_arrival) \
                and (next_wake is None or next_completion <= next_wake):
            # backoff step: free exactly one completed pod, then retry
            t = _pop_release(running, nodes, efleet)
            continue
        if next_arrival is not None and (next_wake is None
                                         or next_arrival <= next_wake):
            if next_completion is not None and next_completion <= next_arrival:
                # release completions due at-or-before the arrival (one per
                # iteration) so the burst schedules against freed capacity —
                # including the exact completion==arrival tie
                t = _pop_release(running, nodes, efleet)
                continue
            t = next_arrival
            continue
        if next_wake is not None:
            if next_completion is not None and next_completion <= next_wake:
                t = _pop_release(running, nodes, efleet)
                continue
            t = next_wake
            continue
        if pending:
            # no completions left, no future arrivals: nothing can ever fit
            unschedulable += len(pending)
            break
        break   # only running tasks remain; their records are complete
    if efleet is not None:
        # close the power-state ledger at the run horizon (latest task end
        # or the final clock, whichever is later): drain the still-running
        # completions through the elastic hooks so every node's
        # post-last-task idle tail (and the ASLEEP stretch it lazily decays
        # into) lands in the timeline, then flush the open intervals —
        # state energy/carbon totals are exact
        horizon = t
        for r in records:
            horizon = max(horizon, r.start_s + r.runtime_s)
        while running:
            _pop_release(running, nodes, efleet)
        efleet.close(horizon)
    return SimResult(records, unschedulable, timeline, preemptions=n_preempt,
                     migrations=n_migrations,
                     wakes=efleet.wakes if efleet is not None else 0,
                     sleeps=efleet.sleeps if efleet is not None else 0)


def run_experiment(level: str, scheme: str,
                   cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
                   adaptive: bool = False, batch: bool = False,
                   batch_backend: str = "jax") -> SimResult:
    """One cell of the paper's factorial design (competition level x scheme):
    the paper-mode arrival process (all pods at t=0, interleaved Table-V
    stream) through the event-driven engine."""
    return run_scenario(PaperArrivals(level), scheme,
                        cluster_factory=cluster_factory, adaptive=adaptive,
                        batch=batch, batch_backend=batch_backend)


def table6(levels=("low", "medium", "high"),
           schemes=("general", "energy_centric", "performance_centric",
                    "resource_efficient"), adaptive: bool = False):
    """Reproduce paper Table VI: energy (kJ) per (level, scheme) for both
    schedulers + optimization %. Returns nested dict."""
    out: dict[str, dict[str, dict[str, float]]] = {}
    for level in levels:
        out[level] = {}
        for scheme in schemes:
            res = run_experiment(level, scheme, adaptive=adaptive)
            dk = res.mean_energy_kj("default")
            tk = res.mean_energy_kj("topsis")
            out[level][scheme] = {
                "default_kj": dk,
                "topsis_kj": tk,
                "savings_kj": dk - tk,
                "optimization_pct": 100.0 * (dk - tk) / dk if dk else 0.0,
            }
    return out

"""Scenario driver over the discrete-event simulation kernel.

The actual event loop lives in ``repro.cluster.engine``: a kernel that owns
the typed event clock (ARRIVAL / COMPLETION / CARBON_CHECK / WAKE_DONE /
CONSOLIDATE_TICK), the explicit :class:`~repro.cluster.engine.SimState`
(pending queue, running-task heap, records, power timeline), and the
scheduling round. Everything scenario-specific plugs in through the
``SchedulingPolicy`` hook protocol (``repro.core.policy``):

* ``CarbonScheduling`` (``repro.core.carbon``) — temporal shifting:
  deferrable pods wait, bounded by their deadline, for the fleet-minimum
  grid intensity to dip; running deferrable tasks are preempted off
  spiking regions (once per pod), their timeline segments truncated at the
  eviction instant.
* ``AutoscaleScheduling`` (``repro.core.elastic``) — the node power-state
  lifecycle: idle-timeout sleep, queue-pressure wakes of the TOPSIS-best
  sleeping node, and periodic consolidation drains through the same
  truncate-and-requeue machinery.

This module is the thin driver: :func:`run_scenario` keeps its original
signature, maps the ``carbon=`` / ``autoscale=`` knob dataclasses onto an
ordered policy list, and hands the run to :func:`repro.cluster.engine.
simulate`. Composing future policies (cost-benefit drain, predictive wake)
means appending to that list — not threading more state through an engine
function.

The paper's factorial experiment (§IV) is one point in the input space:
every pod arriving at t=0 (``PaperArrivals``) on the 4-node Table-I
cluster, no policies. ``table6()`` routes through this driver and
reproduces the pre-refactor paper-mode output bitwise
(tests/test_scenarios.py pins it against the recorded golden; the full
policy matrix is pinned by tests/test_engine.py against
tests/golden_engine_scenarios.json).
"""
from __future__ import annotations

from typing import Callable

from repro.core.carbon import CarbonPolicy, CarbonScheduling
from repro.core.elastic import AutoscalePolicy, AutoscaleScheduling
from repro.cluster.engine import (PodRecord, SimResult,  # noqa: F401
                                  simulate)              # (re-exported)
from repro.cluster.node import Node, make_paper_cluster
from repro.cluster.workload import ArrivalProcess, PaperArrivals


def run_scenario(arrivals: ArrivalProcess, scheme: str,
                 cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
                 adaptive: bool = False, batch: bool = False,
                 batch_backend: str = "jax",
                 carbon: CarbonPolicy | None = None,
                 autoscale: AutoscalePolicy | None = None,
                 explain: bool = False) -> SimResult:
    """Drive one scenario through the event-driven kernel.

    Events are pod-arrival bursts (from ``arrivals``) and task completions
    (from prior placements); each scheduling round walks the FIFO pending
    queue against current cluster state. Default-scheduler pods and
    per-pod TOPSIS go through ``select``; with ``batch=True`` the round's
    TOPSIS pods are scored in one ``BatchScheduler.select_many`` pass on
    ``batch_backend`` (the fleet-scale path).

    ``carbon`` (a :class:`~repro.core.carbon.CarbonPolicy`) attaches the
    signal to the TOPSIS schedulers (sixth carbon-rate criterion) and the
    power timeline, and enables deferral/preemption temporal shifting;
    ``autoscale`` (an :class:`~repro.core.elastic.AutoscalePolicy`) gives
    nodes the sleep/wake/drain lifecycle. Both are plain knob dataclasses;
    each maps onto one ``SchedulingPolicy`` implementation, composed in
    the fixed order ``[carbon, autoscale]``. With both at ``None`` the
    kernel runs policy-free and reproduces the legacy engine bitwise.

    ``explain=True`` records per-decision TOPSIS attributions
    (``SimResult.explanations``; numpy scoring only — see
    :func:`repro.cluster.engine.simulate`).
    """
    policies = []
    if carbon is not None:
        policies.append(CarbonScheduling(carbon))
    if autoscale is not None:
        policies.append(AutoscaleScheduling(autoscale))
    return simulate(arrivals, scheme, cluster_factory=cluster_factory,
                    adaptive=adaptive, batch=batch,
                    batch_backend=batch_backend, policies=policies,
                    explain=explain)


def run_experiment(level: str, scheme: str,
                   cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
                   adaptive: bool = False, batch: bool = False,
                   batch_backend: str = "jax") -> SimResult:
    """One cell of the paper's factorial design (competition level x scheme):
    the paper-mode arrival process (all pods at t=0, interleaved Table-V
    stream) through the event-driven kernel."""
    return run_scenario(PaperArrivals(level), scheme,
                        cluster_factory=cluster_factory, adaptive=adaptive,
                        batch=batch, batch_backend=batch_backend)


def table6(levels=("low", "medium", "high"),
           schemes=("general", "energy_centric", "performance_centric",
                    "resource_efficient"), adaptive: bool = False):
    """Reproduce paper Table VI: energy (kJ) per (level, scheme) for both
    schedulers + optimization %. Returns nested dict."""
    out: dict[str, dict[str, dict[str, float]]] = {}
    for level in levels:
        out[level] = {}
        for scheme in schemes:
            res = run_experiment(level, scheme, adaptive=adaptive)
            dk = res.mean_energy_kj("default")
            tk = res.mean_energy_kj("topsis")
            out[level][scheme] = {
                "default_kj": dk,
                "topsis_kj": tk,
                "savings_kj": dk - tk,
                "optimization_pct": 100.0 * (dk - tk) / dk if dk else 0.0,
            }
    return out

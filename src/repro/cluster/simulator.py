"""Event-driven cluster simulation engine.

The paper's factorial experiment (§IV) is one point in this engine's input
space: every pod arriving at t=0 (``PaperArrivals``) on the 4-node Table-I
cluster. The engine itself consumes any ``ArrivalProcess`` — Poisson bursts,
replayed JSON traces — over any fleet (``make_scenario_cluster`` builds
edge-heavy / cloud-heavy / mixed fleets up to 8192 nodes), and accounts
energy on a per-node power-state timeline (``repro.core.energy.PowerTimeline``)
instead of a post-hoc interval union, so every run yields energy-vs-time
series per scheduler in addition to the paper's scalar totals (Table IV
metric definitions).

Event loop semantics (kube-scheduler backoff-and-retry, idealized): a
scheduling round places every pending pod it can against current cluster
state; pods that do not fit wait in a FIFO queue and are retried whenever a
running pod completes or a new burst arrives. With ``PaperArrivals`` this
reduces exactly to the legacy all-at-t0 loop — ``table6()`` reproduces the
pre-refactor paper-mode output bitwise (tests/test_scenarios.py pins it
against the recorded golden).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable

import numpy as np

from repro.core.energy import (NODE_ENERGY_PROFILES, PowerTimeline,
                               task_energy_joules)
from repro.core.scheduler import (BatchScheduler, DefaultK8sScheduler,
                                  GreenPodScheduler, predict_exec_time)
from repro.cluster.node import Node, make_paper_cluster
from repro.cluster.workload import ArrivalProcess, PaperArrivals, Pod


@dataclasses.dataclass
class PodRecord:
    pod: Pod
    node: str
    node_class: str
    start_s: float
    runtime_s: float
    energy_j: float
    scheduling_time_s: float


@dataclasses.dataclass
class SimResult:
    records: list[PodRecord]
    unschedulable: int
    timeline: PowerTimeline | None = None

    def _timeline(self) -> PowerTimeline:
        """The run's power timeline (rebuilt from records for results
        constructed without one)."""
        if self.timeline is None:
            self.timeline = PowerTimeline()
            for r in self.records:
                self.timeline.add(r.node, r.node_class, r.pod.scheduler,
                                  r.start_s, r.runtime_s,
                                  r.energy_j / r.runtime_s if r.runtime_s
                                  else 0.0)
        return self.timeline

    def energy_kj(self, scheduler: str) -> float:
        """Node-level energy attributed to a scheduler: per-pod dynamic energy
        plus each node's idle power for the union time that scheduler's pods
        keep the node awake (Table IV: 'efficiency of scheduling decisions
        from an energy optimization perspective') — now read off the
        power-state timeline."""
        return self._timeline().energy_kj(scheduler)

    def energy_series(self, scheduler: str | None = None):
        """Time-resolved cumulative energy ``(edges_s, joules)`` for one
        scheduler (or the whole cluster when None)."""
        return self._timeline().energy_series(scheduler)

    def power_series(self, scheduler: str | None = None):
        """Piecewise-constant total power ``(edges_s, watts)``."""
        return self._timeline().power_series(scheduler)

    def mean_energy_kj(self, scheduler: str) -> float:
        """Per-pod average energy — the unit of paper Table VI (its kJ values
        decrease from low→high competition while pod counts grow ~3x, which is
        only consistent with a per-pod average)."""
        n = sum(1 for r in self.records if r.pod.scheduler == scheduler)
        return self.energy_kj(scheduler) / n if n else 0.0

    def mean_sched_time_ms(self, scheduler: str) -> float:
        ts = [r.scheduling_time_s for r in self.records
              if r.pod.scheduler == scheduler]
        return 1000.0 * float(np.mean(ts)) if ts else 0.0

    def mean_exec_time_s(self, scheduler: str) -> float:
        ts = [r.runtime_s for r in self.records if r.pod.scheduler == scheduler]
        return float(np.mean(ts)) if ts else 0.0

    def unschedulable_rate(self) -> float:
        total = len(self.records) + self.unschedulable
        return self.unschedulable / total if total else 0.0

    def allocation(self, scheduler: str) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            if r.pod.scheduler == scheduler:
                out[r.node_class] = out.get(r.node_class, 0) + 1
        return out


def _commit(pod: Pod, idx: int, nodes: list[Node], t: float,
            sched_time_s: float, records: list[PodRecord],
            running: list, timeline: PowerTimeline) -> None:
    """Bind pod to nodes[idx], append its record + completion event, and
    post the task segment to the power timeline."""
    node = nodes[idx]
    node.bind(pod.cpu, pod.mem)
    rt = predict_exec_time(pod, node)
    ej = task_energy_joules(node.node_class, rt, pod.cpu)
    records.append(PodRecord(pod, node.name, node.node_class, t, rt,
                             ej, sched_time_s))
    timeline.add(node.name, node.node_class, pod.scheduler, t, rt,
                 NODE_ENERGY_PROFILES[node.node_class]["dyn_power_per_vcpu"]
                 * pod.cpu)
    heapq.heappush(running, (t + rt, pod.uid, pod, idx))


def run_burst(pods: list[Pod], nodes: list[Node], sched: BatchScheduler,
              t: float, records: list[PodRecord], running: list,
              timeline: PowerTimeline) -> list[Pod]:
    """Schedule an arrival burst through one batched scoring pass
    (``BatchScheduler.select_many``) and commit the assignments. Returns
    the pods that did not fit."""
    assignments, diag = sched.select_many(pods, nodes)
    still: list[Pod] = []
    for pod, idx in zip(pods, assignments):
        if idx is None:
            still.append(pod)
            continue
        _commit(pod, idx, nodes, t, diag["per_pod_time_s"], records, running,
                timeline)
    return still


def run_scenario(arrivals: ArrivalProcess, scheme: str,
                 cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
                 adaptive: bool = False, batch: bool = False,
                 batch_backend: str = "jax") -> SimResult:
    """Drive one scenario through the event-driven engine.

    Events are pod-arrival bursts (from ``arrivals``) and task completions
    (from prior placements). Each scheduling round walks the FIFO pending
    queue against current cluster state: default-scheduler pods and
    per-pod TOPSIS go through ``select``; with ``batch=True`` the round's
    TOPSIS pods are scored in one ``BatchScheduler.select_many`` pass on
    ``batch_backend`` (the fleet-scale path — bursts route through the
    batched engine). After a round, the clock advances to the earliest of
    the next completion (releasing exactly one pod's resources before
    retrying, the legacy backoff step) or the next arrival burst. Pods
    still pending when no completion or arrival can ever free capacity are
    counted unschedulable.
    """
    nodes = cluster_factory()
    sched = {"topsis": (BatchScheduler(scheme, adaptive=adaptive,
                                       backend=batch_backend) if batch
                        else GreenPodScheduler(scheme, adaptive=adaptive)),
             "default": DefaultK8sScheduler()}
    events = sorted(arrivals.events(), key=lambda ev: ev[0])
    ei = 0
    pending: list[Pod] = []
    running: list[tuple[float, int, Pod, int]] = []   # (end_t, uid, pod, node_i)
    records: list[PodRecord] = []
    timeline = PowerTimeline()
    t = 0.0
    unschedulable = 0
    while True:
        # ingest every burst due by the current clock
        while ei < len(events) and events[ei][0] <= t:
            pending.extend(events[ei][1])
            ei += 1
        # safety net: release anything that finished before now (the advance
        # step below never moves the clock past an unreleased completion)
        while running and running[0][0] < t:
            _, _, done, idx = heapq.heappop(running)
            nodes[idx].release(done.cpu, done.mem)
        if not pending and not running and ei >= len(events):
            break
        # scheduling round: place what fits, FIFO retry for the rest
        placed: set[int] = set()
        burst: list[Pod] = []
        for pod in pending:
            if batch and pod.scheduler == "topsis":
                burst.append(pod)
                continue
            idx, diag = sched[pod.scheduler].select(pod, nodes)
            if idx is None:
                continue
            _commit(pod, idx, nodes, t, diag["scheduling_time_s"], records,
                    running, timeline)
            placed.add(pod.uid)
        if burst:
            b_still = run_burst(burst, nodes, sched["topsis"], t,
                                records, running, timeline)
            placed.update({p.uid for p in burst} - {p.uid for p in b_still})
        pending = [p for p in pending if p.uid not in placed]
        # advance the clock to the next event
        next_arrival = events[ei][0] if ei < len(events) else None
        next_completion = running[0][0] if running else None
        if pending and next_completion is not None and (
                next_arrival is None or next_completion <= next_arrival):
            # backoff step: free exactly one completed pod, then retry
            end_t, _, done, idx = heapq.heappop(running)
            nodes[idx].release(done.cpu, done.mem)
            t = end_t
            continue
        if next_arrival is not None:
            if next_completion is not None and next_completion <= next_arrival:
                # release completions due at-or-before the arrival (one per
                # iteration) so the burst schedules against freed capacity —
                # including the exact completion==arrival tie
                end_t, _, done, idx = heapq.heappop(running)
                nodes[idx].release(done.cpu, done.mem)
                t = end_t
                continue
            t = next_arrival
            continue
        if pending:
            # no completions left, no future arrivals: nothing can ever fit
            unschedulable += len(pending)
            break
        break   # only running tasks remain; their records are complete
    return SimResult(records, unschedulable, timeline)


def run_experiment(level: str, scheme: str,
                   cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
                   adaptive: bool = False, batch: bool = False,
                   batch_backend: str = "jax") -> SimResult:
    """One cell of the paper's factorial design (competition level x scheme):
    the paper-mode arrival process (all pods at t=0, interleaved Table-V
    stream) through the event-driven engine."""
    return run_scenario(PaperArrivals(level), scheme,
                        cluster_factory=cluster_factory, adaptive=adaptive,
                        batch=batch, batch_backend=batch_backend)


def table6(levels=("low", "medium", "high"),
           schemes=("general", "energy_centric", "performance_centric",
                    "resource_efficient"), adaptive: bool = False):
    """Reproduce paper Table VI: energy (kJ) per (level, scheme) for both
    schedulers + optimization %. Returns nested dict."""
    out: dict[str, dict[str, dict[str, float]]] = {}
    for level in levels:
        out[level] = {}
        for scheme in schemes:
            res = run_experiment(level, scheme, adaptive=adaptive)
            dk = res.mean_energy_kj("default")
            tk = res.mean_energy_kj("topsis")
            out[level][scheme] = {
                "default_kj": dk,
                "topsis_kj": tk,
                "savings_kj": dk - tk,
                "optimization_pct": 100.0 * (dk - tk) / dk if dk else 0.0,
            }
    return out

"""Discrete-event cluster simulator reproducing the paper's factorial
experiment (§IV): both schedulers' pods share one heterogeneous cluster;
energy is accounted per scheduling decision (Table IV metric definitions).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable

import numpy as np

from repro.core.energy import NODE_ENERGY_PROFILES, task_energy_joules
from repro.core.scheduler import (BatchScheduler, DefaultK8sScheduler,
                                  GreenPodScheduler, predict_exec_time)
from repro.cluster.node import Node, make_paper_cluster
from repro.cluster.workload import Pod, make_pods


@dataclasses.dataclass
class PodRecord:
    pod: Pod
    node: str
    node_class: str
    start_s: float
    runtime_s: float
    energy_j: float
    scheduling_time_s: float


def _union_length(intervals: list[tuple[float, float]]) -> float:
    """Total length of the union of [start, end) intervals."""
    if not intervals:
        return 0.0
    total, cur_s, cur_e = 0.0, *sorted(intervals)[0]
    for s, e in sorted(intervals)[1:]:
        if s > cur_e:
            total += cur_e - cur_s
            cur_s, cur_e = s, e
        else:
            cur_e = max(cur_e, e)
    return total + (cur_e - cur_s)


@dataclasses.dataclass
class SimResult:
    records: list[PodRecord]
    unschedulable: int

    def energy_kj(self, scheduler: str) -> float:
        """Node-level energy attributed to a scheduler: per-pod dynamic energy
        plus each node's idle power for the union time that scheduler's pods
        keep the node awake (Table IV: 'efficiency of scheduling decisions
        from an energy optimization perspective')."""
        dyn = sum(r.energy_j for r in self.records
                  if r.pod.scheduler == scheduler)
        idle = 0.0
        by_node: dict[str, list[tuple[float, float]]] = {}
        classes: dict[str, str] = {}
        for r in self.records:
            if r.pod.scheduler == scheduler:
                by_node.setdefault(r.node, []).append(
                    (r.start_s, r.start_s + r.runtime_s))
                classes[r.node] = r.node_class
        for node, ivs in by_node.items():
            idle += (NODE_ENERGY_PROFILES[classes[node]]["idle_power"]
                     * _union_length(ivs))
        return (dyn + idle) / 1000.0

    def mean_energy_kj(self, scheduler: str) -> float:
        """Per-pod average energy — the unit of paper Table VI (its kJ values
        decrease from low→high competition while pod counts grow ~3x, which is
        only consistent with a per-pod average)."""
        n = sum(1 for r in self.records if r.pod.scheduler == scheduler)
        return self.energy_kj(scheduler) / n if n else 0.0

    def mean_sched_time_ms(self, scheduler: str) -> float:
        ts = [r.scheduling_time_s for r in self.records
              if r.pod.scheduler == scheduler]
        return 1000.0 * float(np.mean(ts)) if ts else 0.0

    def mean_exec_time_s(self, scheduler: str) -> float:
        ts = [r.runtime_s for r in self.records if r.pod.scheduler == scheduler]
        return float(np.mean(ts)) if ts else 0.0

    def allocation(self, scheduler: str) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            if r.pod.scheduler == scheduler:
                out[r.node_class] = out.get(r.node_class, 0) + 1
        return out


def _commit(pod: Pod, idx: int, nodes: list[Node], t: float,
            sched_time_s: float, records: list[PodRecord],
            running: list) -> None:
    """Bind pod to nodes[idx] and append its record + completion event."""
    node = nodes[idx]
    node.bind(pod.cpu, pod.mem)
    rt = predict_exec_time(pod, node)
    ej = task_energy_joules(node.node_class, rt, pod.cpu)
    records.append(PodRecord(pod, node.name, node.node_class, t, rt,
                             ej, sched_time_s))
    heapq.heappush(running, (t + rt, pod.uid, pod, idx))


def run_burst(pods: list[Pod], nodes: list[Node], sched: BatchScheduler,
              t: float, records: list[PodRecord],
              running: list) -> tuple[list[Pod], bool]:
    """Schedule an arrival burst through one batched scoring pass
    (``BatchScheduler.select_many``) and commit the assignments. Returns
    (pods that did not fit, whether any placement was made)."""
    assignments, diag = sched.select_many(pods, nodes)
    still: list[Pod] = []
    progress = False
    for pod, idx in zip(pods, assignments):
        if idx is None:
            still.append(pod)
            continue
        _commit(pod, idx, nodes, t, diag["per_pod_time_s"], records, running)
        progress = True
    return still, progress


def run_experiment(level: str, scheme: str,
                   cluster_factory: Callable[[], list[Node]] = make_paper_cluster,
                   adaptive: bool = False, batch: bool = False,
                   batch_backend: str = "jax") -> SimResult:
    """One cell of the paper's factorial design (competition level x scheme).

    Event loop: all pods arrive at t=0 in the interleaved Table-V stream;
    each is scheduled against current cluster state; pods that do not fit wait
    in a FIFO pending queue and are retried whenever a running pod completes
    (kube-scheduler backoff-and-retry, idealized).

    ``batch=True`` routes each round's TOPSIS arrivals through
    ``BatchScheduler.select_many`` (one scoring pass per burst on
    ``batch_backend``) instead of the per-pod rescore loop — the fleet-scale
    path. Default-scheduler pods always go through the per-pod baseline.
    Within a round, default pods bind during the per-pod pass and the burst
    is scored against the resulting snapshot, so placements are not
    bitwise-identical to ``batch=False`` (the documented snapshot trade-off
    of ``BatchScheduler``); the pending retry queue stays FIFO either way.
    """
    nodes = cluster_factory()
    sched = {"topsis": (BatchScheduler(scheme, adaptive=adaptive,
                                       backend=batch_backend) if batch
                        else GreenPodScheduler(scheme, adaptive=adaptive)),
             "default": DefaultK8sScheduler()}
    pending: list[Pod] = list(make_pods(level))
    running: list[tuple[float, int, Pod, int]] = []   # (end_t, uid, pod, node_i)
    records: list[PodRecord] = []
    t = 0.0
    unschedulable = 0
    progress = True
    while pending or running:
        if not progress and not running:
            unschedulable += len(pending)   # nothing can ever fit
            break
        progress = False
        placed: set[int] = set()
        burst: list[Pod] = []
        for pod in pending:
            if batch and pod.scheduler == "topsis":
                burst.append(pod)
                continue
            idx, diag = sched[pod.scheduler].select(pod, nodes)
            if idx is None:
                continue
            _commit(pod, idx, nodes, t, diag["scheduling_time_s"], records,
                    running)
            placed.add(pod.uid)
            progress = True
        if burst:
            b_still, b_progress = run_burst(burst, nodes, sched["topsis"], t,
                                            records, running)
            placed.update({p.uid for p in burst} - {p.uid for p in b_still})
            progress = progress or b_progress
        # unplaced pods retry in their original arrival (FIFO) order
        pending = [p for p in pending if p.uid not in placed]
        if pending and running:
            # advance time to the next completion, free its resources, retry
            end_t, _, pod, idx = heapq.heappop(running)
            nodes[idx].release(pod.cpu, pod.mem)
            t = end_t
            progress = True
        elif not pending:
            break
    return SimResult(records, unschedulable)


def table6(levels=("low", "medium", "high"),
           schemes=("general", "energy_centric", "performance_centric",
                    "resource_efficient"), adaptive: bool = False):
    """Reproduce paper Table VI: energy (kJ) per (level, scheme) for both
    schedulers + optimization %. Returns nested dict."""
    out: dict[str, dict[str, dict[str, float]]] = {}
    for level in levels:
        out[level] = {}
        for scheme in schemes:
            res = run_experiment(level, scheme, adaptive=adaptive)
            dk = res.mean_energy_kj("default")
            tk = res.mean_energy_kj("topsis")
            out[level][scheme] = {
                "default_kj": dk,
                "topsis_kj": tk,
                "savings_kj": dk - tk,
                "optimization_pct": 100.0 * (dk - tk) / dk if dk else 0.0,
            }
    return out

"""zamba2-7b [arXiv:2411.15242]: hybrid — 81 Mamba2 layers with a SHARED
full-attention block applied every 6 layers; d3584 32H ff14336 vocab 32000,
ssm_state 64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
)

SMOKE = ModelConfig(
    arch_id="zamba2-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, attn_every=2,
    dtype="float32",
)

# sub-quadratic (SSM core): long_500k applies.
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

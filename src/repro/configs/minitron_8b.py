"""minitron-8b [arXiv:2407.14679; hf]: pruned nemotron, dense,
32L d4096 32H GQA(kv=8) ff16384 vocab 256000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000,
)

SMOKE = ModelConfig(
    arch_id="minitron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab=512,
    dtype="float32",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")

"""rwkv6-1.6b 'Finch' [arXiv:2404.05892]: attention-free, 24L d2048 ff7168
vocab 65536, data-dependent decay."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # heads = D/64
    d_ff=7168, vocab=65536, rwkv=True,
)

SMOKE = ModelConfig(
    arch_id="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=256, vocab=256, rwkv=True,
    dtype="float32",
)

# attention-free: long_500k applies (state is O(1)).
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

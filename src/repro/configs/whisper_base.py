"""whisper-base [arXiv:2212.04356]: enc-dec, 6+6L d512 8H ff2048 vocab 51865;
conv audio frontend is a STUB (input_specs provides frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865,
    enc_dec=True, n_encoder_layers=6, n_audio_frames=1500,
)

SMOKE = ModelConfig(
    arch_id="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    enc_dec=True, n_encoder_layers=2, n_audio_frames=32,
    dtype="float32",
)

# enc-dec: decoder KV-cache decode applies; long_500k (full attention) skipped.
SHAPES = ("train_4k", "prefill_32k", "decode_32k")

"""Architecture registry: --arch <id> resolves here.

Each config module defines CONFIG (full, exact published numbers), SMOKE
(same family, tiny), and SHAPES (which assigned input shapes apply).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "mixtral_8x7b",
    "deepseek_v3_671b",
    "deepseek_coder_33b",
    "gemma_7b",
    "minitron_8b",
    "llama3_8b",
    "zamba2_7b",
    "rwkv6_1b6",
    "llama32_vision_90b",
    "whisper_base",
)

# canonical ids as given in the assignment (hyphenated) -> module name
ALIASES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gemma-7b": "gemma_7b",
    "minitron-8b": "minitron_8b",
    "llama3-8b": "llama3_8b",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "whisper-base": "whisper_base",
}

# assigned LM shapes: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get(arch_id: str):
    mod_name = ALIASES.get(arch_id, arch_id)
    if mod_name not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; known: "
                         f"{sorted(ALIASES) + list(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{mod_name}")


def config(arch_id: str, **overrides):
    cfg = get(arch_id).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def smoke_config(arch_id: str, **overrides):
    cfg = get(arch_id).SMOKE
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def shapes_for(arch_id: str) -> tuple[str, ...]:
    return get(arch_id).SHAPES

"""llama-3.2-vision-90b [hf]: 100L d8192 64H GQA(kv=8) ff28672 vocab 128256;
gated cross-attention to vision tokens every 5th layer; vision tower is a
STUB (input_specs provides patch embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, rope_theta=5e5,
    cross_attn_every=5, n_vision_tokens=1601,
)

SMOKE = ModelConfig(
    arch_id="llama32v-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    cross_attn_every=2, n_vision_tokens=16,
    dtype="float32",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")

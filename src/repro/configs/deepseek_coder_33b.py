"""deepseek-coder-33b [arXiv:2401.14196; hf]: llama-arch dense,
62L d7168 56H GQA(kv=8) ff19200 vocab 32256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, rope_theta=1e5,
)

SMOKE = ModelConfig(
    arch_id="deepseek-coder-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab=256,
    dtype="float32",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")

"""mixtral-8x7b [arXiv:2401.04088; hf]: 32L d4096 32H GQA(kv=8) ff14336
vocab 32000, MoE 8 experts top-2, sliding-window attention."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, moe_d_ff=14336,
    attn_window=4096, rope_theta=1e6,
)

SMOKE = ModelConfig(
    arch_id="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256,
    n_experts=4, top_k=2, moe_d_ff=128,
    attn_window=16,
    dtype="float32",
)

# full attention over 32k context (SWA bounds the window but the published
# config uses 32k context); long_500k skipped per assignment rule.
SHAPES = ("train_4k", "prefill_32k", "decode_32k")

"""llama3-8b [arXiv:2407.21783]: 32L d4096 32H GQA(kv=8) ff14336 vocab 128256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, rope_theta=5e5,
)

SMOKE = ModelConfig(
    arch_id="llama3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab=256,
    dtype="float32",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")

"""deepseek-v3-671b [arXiv:2412.19437; hf]: 61L d7168 128H MLA ff2048(routed)
vocab 129280, 1 shared + 256 routed experts top-8. MTP head omitted
(DESIGN.md §Arch-applicability)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,                   # dense-layer FFN width
    vocab=129280,
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    n_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    arch_id="deepseek-v3-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab=256,
    n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=48,
    n_dense_layers=1,
    use_mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    dtype="float32",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")  # full (latent) attention

"""gemma-7b [arXiv:2403.08295; hf]: 28L d3072 16H (kv=16) ff24576
vocab 256000, GeGLU, head_dim 256, tied embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab=256000, head_dim=256,
    ffn_kind="geglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="gemma-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab=512, head_dim=32,
    ffn_kind="geglu", tie_embeddings=True,
    dtype="float32",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")

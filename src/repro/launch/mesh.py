"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
pure data parallelism (gradient all-reduce crosses DCN/pod links only once
per step).

make_production_mesh is a FUNCTION so importing this module never touches
jax device state (smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **compat.axis_types_kwarg(len(axes)))


def make_host_mesh(model_axis: int | None = None):
    """Degenerate mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    m = model_axis or 1
    assert n % m == 0
    return jax.make_mesh((n // m, m), ("data", "model"))

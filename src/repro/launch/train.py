"""Production training driver.

On real hardware this runs under `jax.distributed` across hosts; on this
container it runs reduced configs end-to-end (CPU) or full configs in
abstract dry-run mode (--dryrun delegates to launch/dryrun.py).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import registry
from repro.data.pipeline import SyntheticLM, make_global_batch
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw
from repro.train import fault
from repro.train import loop as tl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--quantized-state", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=None,
                    help="TP size over local devices")
    args = ap.parse_args()

    cfg = (registry.smoke_config(args.arch) if args.smoke
           else registry.config(args.arch))
    model = lm.build(cfg)
    mesh = make_host_mesh(args.model_axis)
    jax.set_mesh(mesh)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                             total_steps=args.steps,
                             quantized_state=args.quantized_state)
    step, shardings = tl.make_train_step(model, ocfg, mesh,
                                         n_micro=args.n_micro, donate=False)
    params = model.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq_len,
                     global_batch=args.global_batch)

    def data_fn(s):
        return make_global_batch(mesh, {"tokens": ds.batch_at(s)})

    ckpt_dir = args.ckpt_dir or os.path.join("/tmp", "repro_ckpt", args.arch)
    sup = fault.Supervisor(ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every)
    state = {"params": params, "opt_state": adamw.init(ocfg, params)}
    final, hist = sup.run(state=state, step_fn=step, data_fn=data_fn,
                          n_steps=args.steps)
    losses = [h["loss"] for h in hist]
    print(f"steps={len(hist)} first_loss={losses[0]:.4f} "
          f"final_loss={losses[-1]:.4f} "
          f"mean_step_s={np.mean([h['time_s'] for h in hist]):.3f}")


if __name__ == "__main__":
    main()

"""Production serving driver: wave-batched prefill+decode engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = (registry.smoke_config(args.arch) if args.smoke
           else registry.config(args.arch))
    model = lm.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=args.slots,
                 max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(2, cfg.vocab,
                                    rng.integers(4, args.max_len // 4))
                    .astype(np.int32), max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    results = eng.serve(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

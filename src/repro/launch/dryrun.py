import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
# The 512 placeholder CPU devices exist ONLY for this dry-run; smoke tests and
# benchmarks see the single real device.
#
# Multi-pod dry-run: .lower().compile() every (architecture x input shape) on
# the production meshes and extract the roofline terms:
#   compute_s    = HLO_FLOPs / (chips * 197e12)          [bf16 MXU peak]
#   memory_s     = HLO_bytes / (chips * 819e9)           [HBM bandwidth]
#   collective_s = collective_bytes / (chips * 50e9)     [ICI per-link]
# cost_analysis() on the SPMD-partitioned module reports PER-DEVICE flops and
# bytes, so term = per_device / peak. Collective bytes are parsed from the
# post-optimization HLO with ring-algorithm multipliers (see _collectives).
#
# Usage:
#   python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
#   python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import registry
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.optim import adamw
from repro.sharding import rules
from repro.train import loop as train_loop

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
LINK_BW = 50e9          # bytes/s per ICI link

VOCAB_TP = True

# Per-arch dry-run options. fsdp: shard params over data too (needed when
# bf16 params exceed HBM at TP=16). quantized: int8 AdamW moments.
# n_micro: gradient-accumulation microbatches for the train_4k cell.
# attn_impl / ep_axes / grad_dtype / constrain_grads: §Perf optimizations
# (EXPERIMENTS.md) — the baseline PLANS keep the paper-faithful einsum path.
@dataclasses.dataclass(frozen=True)
class Plan:
    fsdp: bool = False
    quantized: bool = False
    n_micro: int = 1
    attn_impl: str = "einsum"
    ep_axes: tuple | None = None
    grad_dtype: str | None = None
    constrain_grads: bool = False


PLANS: dict[str, Plan] = {
    "mixtral-8x7b": Plan(n_micro=2),
    "deepseek-v3-671b": Plan(quantized=True, n_micro=8, fsdp=True),
    "deepseek-coder-33b": Plan(n_micro=4),
    "gemma-7b": Plan(n_micro=2),
    "minitron-8b": Plan(n_micro=2),
    "llama3-8b": Plan(n_micro=2),
    "zamba2-7b": Plan(n_micro=2),
    "rwkv6-1.6b": Plan(n_micro=1),
    "llama-3.2-vision-90b": Plan(fsdp=True, quantized=True, n_micro=8),
    "whisper-base": Plan(n_micro=1),
}

# §Perf optimized plans (--opt): grouped-GQA attention is already the
# default model path (iteration 1); these add grad-accumulator sharding
# constraints, two-level EP dispatch for deepseek-v3, and bf16 accumulators
# for the 100B+ archs. attn_impl="flash" (the Pallas kernel via shard_map)
# was evaluated and REFUTED for the 4k/32k cells on the CPU-derived
# roofline (EXPERIMENTS.md §Perf iteration 3) — the kernels remain as the
# validated TPU path, selectable per arch.
OPT_PLANS: dict[str, Plan] = dict(PLANS)
# grad-accumulator sharding constraints were hillclimbed per arch: they fix
# deepseek-v3's 20 TB/dev scan-backward resharding but CAUSE recompute on
# the dense archs (llama3 train compute +76% — §Perf it.6, refuted there).
OPT_PLANS["deepseek-v3-671b"] = dataclasses.replace(
    OPT_PLANS["deepseek-v3-671b"], ep_axes=("data", "model"),
    grad_dtype="bfloat16", fsdp=False, constrain_grads=True)
OPT_PLANS["llama-3.2-vision-90b"] = dataclasses.replace(
    OPT_PLANS["llama-3.2-vision-90b"], grad_dtype="bfloat16")


def _batch_groups(mesh, global_batch: int) -> int:
    """Number of MoE dispatch groups = number of batch shards."""
    ba = rules._batch_axes_for(mesh, global_batch)
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    return max(n, 1)


# --- per-cell programs ----------------------------------------------------------
def build_cell(arch: str, shape: str, mesh, plan: Plan):
    """Returns (jitted_fn, abstract_args) for the cell's step program."""
    c = specs.cell(arch, shape)
    cfg = registry.config(arch)
    cfg = dataclasses.replace(
        cfg,
        moe_groups=_batch_groups(
            mesh, c.global_batch if c.kind != "train"
            else c.global_batch // plan.n_micro),
        attn_impl=plan.attn_impl,
        ep_axes=plan.ep_axes)
    model = lm.build(cfg)
    pspecs = specs.params_specs(model)
    pshard = rules.params_shardings(pspecs, mesh, fsdp=plan.fsdp)

    if c.kind == "train":
        ocfg = adamw.AdamWConfig(quantized_state=plan.quantized)
        sspecs = specs.opt_state_specs(ocfg, pspecs)
        sshard = train_loop.state_shardings(ocfg, pspecs, mesh,
                                            fsdp=plan.fsdp)
        batch = specs.model_inputs(cfg, c)
        bshard = rules.batch_shardings(batch, mesh)
        gspecs = (jax.tree.map(lambda s: s.spec, pshard)
                  if plan.constrain_grads else None)
        gdt = jnp.dtype(plan.grad_dtype) if plan.grad_dtype else None
        fn = train_loop.make_train_fn(model, ocfg, plan.n_micro,
                                      grad_specs=gspecs, grad_dtype=gdt)
        jitted = jax.jit(fn, in_shardings=(pshard, sshard, bshard),
                         out_shardings=(pshard, sshard, None),
                         donate_argnums=(0, 1))
        return jitted, (pspecs, sspecs, batch), cfg, c

    if c.kind == "prefill":
        batch = specs.model_inputs(cfg, c)
        bshard = rules.batch_shardings(batch, mesh)
        cspecs = specs.cache_specs(model, c.global_batch, c.seq_len)
        cshard = rules.cache_shardings(cspecs, mesh)
        ba = rules._batch_axes_for(mesh, c.global_batch)
        lshard = NamedSharding(mesh, P(
            ba if ba else None,
            "model" if VOCAB_TP and cfg.vocab % mesh.shape["model"] == 0
            else None))

        def prefill(p, b):
            return model.prefill(p, b, max_len=c.seq_len)

        jitted = jax.jit(prefill, in_shardings=(pshard, bshard),
                         out_shardings=(lshard, cshard))
        return jitted, (pspecs, batch), cfg, c

    # decode: one new token against a seq_len KV cache
    cspecs = specs.cache_specs(model, c.global_batch, c.seq_len)
    cshard = rules.cache_shardings(cspecs, mesh)
    toks = specs.decode_token_specs(c)
    ba = rules._batch_axes_for(mesh, c.global_batch)
    tshard = NamedSharding(mesh, P(ba if ba else None))
    lshard = NamedSharding(mesh, P(
        ba if ba else None,
        "model" if VOCAB_TP and cfg.vocab % mesh.shape["model"] == 0
        else None))
    jitted = jax.jit(model.decode,
                     in_shardings=(pshard, cshard, tshard),
                     out_shardings=(lshard, cshard),
                     donate_argnums=(1,))
    return jitted, (pspecs, cspecs, toks), cfg, c


def run_cell(arch: str, shape: str, mesh_kind: str, hlo_dir: str | None = None,
             opt: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    plan = (OPT_PLANS if opt else PLANS)[arch]
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "chips": chips, "opt": opt, "plan": dataclasses.asdict(plan)}
    try:
        t0 = time.time()
        compat.set_mesh(mesh)   # ambient mesh for shard_map'd Pallas kernels
        with mesh:
            jitted, args, cfg, c = build_cell(arch, shape, mesh, plan)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
        ca = hlo_analysis.xla_cost_analysis(compiled)
        ma = compiled.memory_analysis()
        # XLA's cost_analysis counts while bodies ONCE (no trip
        # multiplication) — recorded for reference only; the roofline uses
        # the trip-adjusted numbers from hlo_analysis.
        rec["xla_cost"] = {"flops_per_dev": ca.get("flops", 0.0),
                           "bytes_per_dev": ca.get("bytes accessed", 0.0)}
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes
                           + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes
                           - ma.alias_size_in_bytes),
        }
        hlo = compiled.as_text()
        an = hlo_analysis.analyze(hlo)
        rec["cost"] = {"flops_per_dev": an["flops_per_dev"],
                       "bytes_per_dev": an["bytes_per_dev"]}
        rec["collectives"] = dict(an["collectives"],
                                  total_bytes=an["collective_bytes_per_dev"])
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            with open(os.path.join(
                    hlo_dir, f"{arch}__{shape}__{mesh_kind}.hlo"), "w") as f:
                f.write(hlo)
        # roofline terms (seconds)
        fl = rec["cost"]["flops_per_dev"]
        by = rec["cost"]["bytes_per_dev"]
        cb = an["collective_bytes_per_dev"]
        rec["roofline"] = {
            "compute_s": fl / PEAK_FLOPS,
            "memory_s": by / HBM_BW,
            "collective_s": cb / LINK_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["roofline"]["dominant"] = dom
        # model flops: 6 * N_active * tokens (train has fwd+bwd = 3x fwd;
        # decode/prefill are fwd-only = 2 * N_active * tokens)
        n_active = cfg.active_param_count()
        tokens = (c.global_batch * c.seq_len if c.kind != "decode"
                  else c.global_batch)
        factor = 6.0 if c.kind == "train" else 2.0
        rec["model_flops_total"] = factor * n_active * tokens
        hlo_total = fl * chips
        rec["useful_flops_frac"] = (rec["model_flops_total"] / hlo_total
                                    if hlo_total else 0.0)
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def cells_to_run(args) -> list[tuple[str, str]]:
    cells = []
    for arch in registry.ALIASES:
        if args.arch and arch != args.arch:
            continue
        for shape in registry.shapes_for(arch):
            if args.shape and shape != args.shape:
                continue
            cells.append((arch, shape))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo", default=None, help="dir to dump HLO text")
    ap.add_argument("--opt", action="store_true",
                    help="use OPT_PLANS (flash attention, EP dispatch, ...)")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)
    cells = cells_to_run(args)
    n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            path = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
            rec = run_cell(arch, shape, mk, hlo_dir=args.hlo, opt=args.opt)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["ok"]:
                r = rec["roofline"]
                print(f"OK   {arch:22s} {shape:12s} {mk:6s} "
                      f"lower={rec['lower_s']:6.1f}s "
                      f"compile={rec['compile_s']:6.1f}s "
                      f"comp={r['compute_s']:.3e} mem={r['memory_s']:.3e} "
                      f"coll={r['collective_s']:.3e} dom={r['dominant']} "
                      f"useful={rec['useful_flops_frac']:.2f}",
                      flush=True)
            else:
                n_fail += 1
                print(f"FAIL {arch:22s} {shape:12s} {mk:6s} {rec['error']}",
                      flush=True)
    print(f"done: {len(cells) * len(meshes) - n_fail} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation — these drive .lower()/.compile() in the dry-run and
give the roofline terms. Modality frontends are STUBS per the assignment:
[audio] supplies post-conv frame embeddings, [vlm] supplies patch embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str             # train | prefill | decode


def cell(arch: str, shape: str) -> Cell:
    seq, batch, kind = registry.SHAPES[shape]
    return Cell(arch, shape, seq, batch, kind)


def all_cells() -> list[Cell]:
    out = []
    for arch in registry.ARCH_IDS:
        for shape in registry.shapes_for(arch):
            out.append(cell(arch, shape))
    return out


def model_inputs(cfg: ModelConfig, c: Cell) -> dict:
    """Batch ShapeDtypeStructs for train/prefill. Decode uses cache_specs."""
    b = {"tokens": SDS((c.global_batch, c.seq_len), jnp.int32)}
    if cfg.cross_attn_every:
        b["vision"] = SDS((c.global_batch, cfg.n_vision_tokens, cfg.d_model),
                          jnp.float32)
    if cfg.enc_dec:
        b["frames"] = SDS((c.global_batch, cfg.n_audio_frames, cfg.d_model),
                          jnp.float32)
    return b


def params_specs(model: lm.LM):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def opt_state_specs(opt_cfg: adamw.AdamWConfig, pspecs):
    return jax.eval_shape(lambda p: adamw.init(opt_cfg, p), pspecs)


def cache_specs(model: lm.LM, batch_size: int, max_len: int):
    return jax.eval_shape(
        lambda: model.init_cache(batch_size, max_len))


def decode_token_specs(c: Cell):
    return SDS((c.global_batch,), jnp.int32)

"""Trip-count-aware roofline analysis of post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so a
scanned 61-layer model under-reports flops/bytes/collectives by ~61x. This
module re-derives the three roofline terms by parsing the compiled HLO,
multiplying every ``while`` body by its ``known_trip_count`` (recursively —
gradient-accumulation scans contain layer scans contain MoE chunk maps).

Cost model (per-device — post-SPMD shapes are per-partition):

  flops:
    dot            2 * prod(result) * prod(contracting dims)
    convolution    2 * prod(result) * prod(kernel) / out_features
    elementwise    prod(result)   (1 flop/element; transcendentals too)
    reduce/map/... prod(largest operand)
    fusion         flops of the fused computation (inner dots counted)

  bytes (HBM traffic):
    instruction    sum(operand bytes) + result bytes
    fusion         operands + result of the FUSION only (fused intermediates
                   never leave registers — that is the point of fusion)
    dynamic-slice / gather              ~2 * result (reads only the slice)
    dynamic-update-slice / scatter      ~2 * update operand
    parameter/constant/tuple/gte/bitcast  0 (aliasing, no traffic)

  collective bytes (per-device bytes over ICI, ring algorithms):
    all-reduce       2(n-1)/n * size
    all-gather         (n-1)/n * size     (size = gathered result)
    reduce-scatter     (n-1)   * size     (size = scattered result)
    all-to-all         (n-1)/n * size
    collective-permute       1 * size

Used by launch/dryrun.py for EXPERIMENTS.md §Roofline and by the §Perf loop
(``top_contributors`` shows which op_name dominates each term).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred|c64|c128|"
    r"f8e4m3fn|f8e5m2|s4|u4)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
          "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1,
          "f8e5m2": 1, "s4": 0.5, "u4": 0.5}
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_OPCODE_RE = re.compile(r"^([\w\[\]{},.]+\s+)?([a-z][a-z0-9\-]*)\(")
_BARE_OPCODE_RE = re.compile(r"^\s*([a-z][a-z0-9\-]*)\(")
_PARAM_RE = re.compile(r"([\w.\-]+):\s+((?:\([^)]*\))|(?:[\w\[\]{},]+))")
_TRIP_RE = re.compile(r"known_trip_count\D*(\d+)")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SKIP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "opt-barrier"}
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "power", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "compare", "select",
    "and", "or", "xor", "not", "clamp", "convert", "cosine", "sine", "tan",
    "atan2", "erf", "is-finite", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "stochastic-convert"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: list[str]
    attrs: str
    metadata_op: str = ""
    raw_operands: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]            # param name -> type string
    instrs: list[Instr]
    shapes: dict[str, str]            # value name -> type string


def _split_operands(rest: str, op_end: int) -> tuple[str, str]:
    """rest[op_end:] starts right after the opcode's '('. Returns
    (operand substring, attribute substring)."""
    depth = 1
    i = op_end
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    return rest[op_end:i - 1], rest[i:]


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.endswith("{"):
                params = dict(_PARAM_RE.findall(m.group(2)))
                cur = Computation(m.group(1), params, [], dict(params))
            continue
        if line.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        if rest.startswith("("):
            # tuple result type — find the matching ')' by paren counting
            # (regexes break on /*index=N*/ comments inside the tuple)
            depth, i = 1, 1
            while i < len(rest) and depth:
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                i += 1
            result_type = rest[:i]
            om = _BARE_OPCODE_RE.match(rest[i:])
            if not om:
                continue
            opcode = om.group(1)
            operands_str, attrs = _split_operands(rest, i + om.end())
        else:
            om = _OPCODE_RE.match(rest)
            if not om:
                continue
            result_type = (om.group(1) or "").strip()
            opcode = om.group(2)
            operands_str, attrs = _split_operands(rest, om.end())
        operands = _OPERAND_RE.findall(operands_str)
        meta = ""
        mm = re.search(r'op_name="([^"]*)"', attrs)
        if mm:
            meta = mm.group(1)
        cur.instrs.append(Instr(name, opcode, result_type, operands,
                                attrs, meta, operands_str))
        cur.shapes[name] = result_type
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0       # dot / convolution flops (exact shapes)
    eflops: float = 0.0      # elementwise / reduction flops (cappable)
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.eflops += mult * other.eflops
        self.bytes += mult * other.bytes
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll.items():
            slot = self.coll.setdefault(k, {"count": 0.0, "bytes": 0.0})
            slot["count"] += mult * v["count"]
            slot["bytes"] += mult * v["bytes"]


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out = _shape_elems(ins.result_type)
    cm = _CDIM_RE.search(ins.attrs)
    k = 1
    if cm and ins.operands:
        lhs_t = comp.shapes.get(ins.operands[0], "")
        dims = _shape_dims(lhs_t)
        for d in cm.group(1).split(","):
            if d and int(d) < len(dims):
                k *= dims[int(d)]
    return 2.0 * out * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out = _shape_elems(ins.result_type)
    kern = _shape_dims(comp.shapes.get(ins.operands[1], "")) \
        if len(ins.operands) > 1 else []
    kprod = 1
    for d in kern:
        kprod *= d
    odims = _shape_dims(ins.result_type)
    feat = max(odims) if odims else 1  # crude: kernel includes out-features
    return 2.0 * out * max(kprod // max(feat, 1), 1)


def _coll_moved(ins: Instr) -> float:
    size = _shape_bytes(ins.result_type)
    g = _GROUP_RE.search(ins.attrs)
    n = int(g.group(2)) if g else 2
    op = ins.opcode.replace("-start", "")
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * size
    if op == "all-gather":
        return (n - 1) / n * size
    if op == "reduce-scatter":
        return float(n - 1) * size
    if op in ("all-to-all", "ragged-all-to-all"):
        return (n - 1) / n * size
    return float(size)   # collective-permute


def _instr_bytes(ins: Instr, comp: Computation) -> float:
    res = _shape_bytes(ins.result_type)
    if ins.opcode in ("dynamic-slice", "gather"):
        return 2.0 * res
    if ins.opcode in ("dynamic-update-slice", "scatter"):
        upd = (_shape_bytes(comp.shapes.get(ins.operands[1], ""))
               if len(ins.operands) > 1 else res)
        return 2.0 * upd
    ops = sum(_shape_bytes(comp.shapes.get(o, "")) for o in ins.operands)
    return ops + res


# Pallas kernels lowered with interpret=True appear as plain HLO loops; the
# kernel body computes in VMEM on real TPUs, and its HBM traffic is exactly
# the BlockSpec streaming the interpreter expresses as dynamic-slice /
# dynamic-update-slice on the full operands. Instructions scoped to these
# op_names charge bytes only for that streaming.
_VMEM_SCOPE_RE = re.compile(
    r"jit\((flash_attention\w*_blocks|rmsnorm\w*_blocks|topsis\w*_blocks)\)"
    r"|pallas_call")


class Analyzer:
    def __init__(self, hlo: str):
        self.comps = parse_module(hlo)
        # computation-level VMEM scoping: metadata-less instructions (layout
        # copies etc.) inherit the scope of their computation
        self._comp_vmem: dict[str, bool] = {}
        for name, comp in self.comps.items():
            tagged = [i for i in comp.instrs if i.metadata_op]
            hits = sum(bool(_VMEM_SCOPE_RE.search(i.metadata_op))
                       for i in tagged)
            self._comp_vmem[name] = bool(tagged) and hits >= len(tagged) / 2
        self._memo: dict[str, Cost] = {}
        entry = [c for c in self.comps if "main" in c]
        self.entry = entry[0] if entry else next(iter(self.comps))
        # contributor ledger: op_name -> [flops, bytes, coll_bytes]
        self.contrib: dict[str, list[float]] = {}

    def _record(self, ins: Instr, fl: float, by: float, cb: float,
                mult: float):
        key = ins.metadata_op or ins.opcode
        slot = self.contrib.setdefault(key, [0.0, 0.0, 0.0])
        slot[0] += fl * mult
        slot[1] += by * mult
        slot[2] += cb * mult

    def cost_of(self, comp_name: str, mult: float = 1.0) -> Cost:
        """Cost of one execution of `comp_name`; contributor ledger is
        accumulated with the cumulative trip multiplier `mult`."""
        if comp_name in self._memo:
            c = self._memo[comp_name]
            self._bump_contrib(comp_name, mult)
            return c
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        for ins in comp.instrs:
            op = ins.opcode
            if op in _SKIP:
                continue
            fl = by = cb = 0.0
            # an instruction is VMEM-resident if its own scope matches OR it
            # lives in a majority-Pallas computation (interpret-mode loop
            # carries drag in boundary-tagged copies that Mosaic keeps in
            # VMEM on real hardware)
            in_vmem = (bool(_VMEM_SCOPE_RE.search(ins.metadata_op))
                       or self._comp_vmem.get(comp_name, False))
            if in_vmem and op not in ("while", "fusion", "call",
                                      "conditional", "dynamic-slice",
                                      "dynamic-update-slice", "gather",
                                      "scatter", "dot", "convolution"):
                # VMEM-resident compute inside a Pallas kernel body: flops
                # count, HBM bytes do not.
                if op in _ELEMENTWISE or op in ("reduce", "reduce-window",
                                                "map", "sort", "top-k"):
                    total.eflops += float(_shape_elems(ins.result_type))
                    if mult:
                        self._record(
                            ins, float(_shape_elems(ins.result_type)),
                            0.0, 0.0, mult)
                continue
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = int(tm.group(1))
                body = _BODY_RE.search(ins.attrs)
                cond = _COND_RE.search(ins.attrs)
                sub = Cost()
                if body:
                    sub.add(self.cost_of(body.group(1), mult * trip), trip)
                if cond:
                    sub.add(self.cost_of(cond.group(1), mult * trip), trip)
                total.add(sub)
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(ins.attrs)
                if cm:
                    inner = self.cost_of(cm.group(1), 0.0)  # ledger: skip
                    # XLA fusions compute only the elements the output
                    # needs: cap the ELEMENTWISE portion of inner flops at
                    # (#elementwise ops x output elements). Dots/convs keep
                    # their true shapes.
                    if in_vmem:
                        # Pallas-interpret loop-carry fusions shuffle full
                        # arrays that live in VMEM/registers on real TPUs;
                        # only genuine MXU (dot) work counts here.
                        efl = 0.0
                    else:
                        efl = min(inner.eflops,
                                  self._ew_count(cm.group(1))
                                  * _shape_elems(ins.result_type))
                    fl = inner.flops + efl
                    if in_vmem:
                        by = self._streaming_bytes(cm.group(1))
                    elif self._is_legalization_convert(cm.group(1)):
                        # XLA CPU float-normalization (bf16<->f32 wrapper):
                        # free on native-bf16 TPU hardware — excluded from
                        # the roofline memory term.
                        fl = by = 0.0
                    else:
                        by = self._fusion_bytes(ins, comp, cm.group(1))
                else:
                    by = _instr_bytes(ins, comp)
            elif op == "call":
                cm = _CALLS_RE.search(ins.attrs) or re.search(
                    r"to_apply=%?([\w.\-]+)", ins.attrs)
                if cm:
                    total.add(self.cost_of(cm.group(1), mult))
                continue
            elif op == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"true_computation=%?([\w.\-]+)|"
                    r"false_computation=%?([\w.\-]+))", ins.attrs)
                names: list[str] = []
                for tup in branches:
                    for part in tup:
                        if part:
                            names.extend(
                                x.strip().lstrip("%")
                                for x in part.split(",") if x.strip())
                if names:
                    worst = max((self.cost_of(n, 0.0) for n in names),
                                key=lambda c: c.flops + c.bytes,
                                default=Cost())
                    total.add(worst)
                continue
            elif op == "dot":
                fl = _dot_flops(ins, comp)
                by = 0.0 if in_vmem else _instr_bytes(ins, comp)
            elif op == "convolution":
                fl = _conv_flops(ins, comp)
                by = 0.0 if in_vmem else _instr_bytes(ins, comp)
            elif op.replace("-start", "") in _COLLECTIVES:
                cb = _coll_moved(ins)
                by = _instr_bytes(ins, comp)
                key = op.replace("-start", "")
                slot = total.coll.setdefault(key,
                                             {"count": 0.0, "bytes": 0.0})
                slot["count"] += 1
                slot["bytes"] += cb
            elif op.endswith("-done") or op.endswith("-update"):
                continue
            elif op == "convert" and ins.operands:
                src = comp.shapes.get(ins.operands[0], "")
                sm, dm = _SHAPE_RE.search(src), _SHAPE_RE.search(
                    ins.result_type)
                if sm and dm and {sm.group(1), dm.group(1)} == {"bf16",
                                                                "f32"}:
                    continue   # CPU float-normalization; free on TPU
                fl = 0.0       # precision conversion: no arithmetic
                by = _instr_bytes(ins, comp)
            elif op in _ELEMENTWISE or op in ("copy", "broadcast", "reshape",
                                              "transpose", "pad", "slice",
                                              "concatenate", "reverse",
                                              "reduce", "reduce-window",
                                              "map", "sort", "select-and-scatter",
                                              "rng", "rng-bit-generator",
                                              "cholesky", "triangular-solve",
                                              "dynamic-slice",
                                              "dynamic-update-slice",
                                              "gather", "scatter",
                                              "custom-call", "top-k"):
                if op in _ELEMENTWISE or op in ("reduce", "reduce-window",
                                                "map", "sort", "top-k"):
                    fl = float(_shape_elems(ins.result_type))
                by = _instr_bytes(ins, comp)
            else:
                by = _instr_bytes(ins, comp)
            if op == "dot" or op == "convolution" or op == "fusion":
                total.flops += fl
            else:
                total.eflops += fl
            total.bytes += by
            total.coll_bytes += cb
            if mult:
                self._record(ins, fl, by, cb, mult)
        self._memo[comp_name] = total
        return total

    def _ew_count(self, comp_name: str) -> int:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0
        return sum(1 for i in comp.instrs
                   if i.opcode in _ELEMENTWISE
                   or i.opcode in ("reduce", "reduce-window", "map"))

    def _streaming_bytes(self, called: str) -> float:
        """HBM traffic of a VMEM-scoped (Pallas-interpret) fused computation:
        only its block loads/stores move data."""
        inner = self.comps.get(called)
        if inner is None:
            return 0.0
        total = 0.0
        for ii in inner.instrs:
            if ii.opcode in ("dynamic-slice", "gather"):
                total += 2.0 * _shape_bytes(ii.result_type)
            elif ii.opcode in ("dynamic-update-slice", "scatter"):
                upd = (inner.shapes.get(ii.operands[1], "")
                       if len(ii.operands) > 1 else "")
                total += 2.0 * _shape_bytes(upd)
        return total

    def _is_legalization_convert(self, called: str) -> bool:
        """True when the fused computation is a bare bf16<->f32 convert."""
        inner = self.comps.get(called)
        if inner is None:
            return False
        body = [i for i in inner.instrs if i.opcode != "parameter"]
        if len(body) != 1 or body[0].opcode != "convert":
            return False
        src = inner.shapes.get(body[0].operands[0], "") if body[0].operands \
            else ""
        dst = body[0].result_type
        kinds = {t.split("[")[0] for t in
                 (_SHAPE_RE.search(src).group(1) if _SHAPE_RE.search(src)
                  else "",
                  _SHAPE_RE.search(dst).group(1) if _SHAPE_RE.search(dst)
                  else "")}
        return kinds == {"bf16", "f32"}

    def _fusion_bytes(self, ins: Instr, comp: Computation,
                      called: str) -> float:
        """HBM traffic of a fusion = operands + result, EXCEPT operands that
        the fused computation only slices/gathers from (embedding lookups,
        KV-cache reads/writes): those cost ~the slice, not the buffer."""
        inner = self.comps.get(called)
        if inner is None:
            return _instr_bytes(ins, comp)
        # map fusion operand position -> inner parameter name
        param_of: dict[int, str] = {}
        for ii in inner.instrs:
            if ii.opcode == "parameter":
                try:
                    param_of[int(ii.raw_operands.strip())] = ii.name
                except ValueError:
                    pass
        # result side: a fusion rooted in dynamic-update-slice over a buffer
        # of the fusion's own result shape is an IN-PLACE carry update on
        # TPU (output aliasing) — charge the update slice, not the buffer.
        total = _shape_bytes(ins.result_type)
        for ii in inner.instrs:
            if ii.opcode == "dynamic-update-slice" \
                    and _shape_dims(ii.result_type) \
                    == _shape_dims(ins.result_type):
                upd = (inner.shapes.get(ii.operands[1], "")
                       if len(ii.operands) > 1 else "")
                total = min(total, 2.0 * _shape_bytes(upd))
                break

        def charge(vname: str, full: float, depth: int = 0) -> float:
            """Bytes actually read from value `vname` inside the fusion.
            Sees through single-use converts (XLA CPU's bf16->f32
            legalization wraps cache updates in converts; on native-bf16
            TPU hardware those are free)."""
            uses = [ii for ii in inner.instrs if vname in ii.operands]
            if not uses or depth > 3:
                return full
            sliced = 0.0
            for u in uses:
                if u.opcode in ("dynamic-slice", "gather") \
                        and u.operands and u.operands[0] == vname:
                    sliced += _shape_bytes(u.result_type)
                elif u.opcode in ("dynamic-update-slice", "scatter") \
                        and u.operands and u.operands[0] == vname:
                    upd = (inner.shapes.get(u.operands[1], "")
                           if len(u.operands) > 1 else u.result_type)
                    sliced += _shape_bytes(upd)
                elif u.opcode in ("convert", "bitcast", "copy",
                                  "reshape") and len(uses) == 1:
                    sliced += charge(u.name, full, depth + 1)
                else:
                    return full
            return min(sliced, full)

        for pos, oname in enumerate(ins.operands):
            full = _shape_bytes(comp.shapes.get(oname, ""))
            pname = param_of.get(pos)
            total += full if pname is None else charge(pname, full)
        return total

    def _bump_contrib(self, comp_name: str, mult: float):
        # memoized path: re-credit contributors without re-walking
        comp = self.comps.get(comp_name)
        if comp is None or not mult:
            return
        for ins in comp.instrs:
            if ins.opcode in _SKIP:
                continue
            # cheap re-credit for leaf instrs only (nested whiles re-walk)
            if ins.opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = int(tm.group(1))
                for attr_re in (_BODY_RE, _COND_RE):
                    m = attr_re.search(ins.attrs)
                    if m:
                        self._bump_contrib(m.group(1), mult * trip)
                continue

    def analyze(self) -> Cost:
        return self.cost_of(self.entry, 1.0)


def xla_cost_analysis(compiled) -> dict[str, float]:
    """XLA's own per-device cost report as a flat dict queryable by key.

    jax <= 0.4.x returns ``compiled.cost_analysis()`` as a list of
    per-device dicts (one entry per addressable device, identical under
    SPMD); jax >= 0.5 returns the dict directly. Normalizes both to a dict
    so callers can index by name ("flops", "bytes accessed", ...).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def analyze(hlo: str) -> dict[str, Any]:
    """Top-level: per-device trip-adjusted flops / HBM bytes / collective
    bytes + per-collective breakdown."""
    a = Analyzer(hlo)
    c = a.analyze()
    return {"flops_per_dev": c.flops + c.eflops, "bytes_per_dev": c.bytes,
            "collective_bytes_per_dev": c.coll_bytes,
            "collectives": {k: {"count": v["count"], "bytes": v["bytes"]}
                            for k, v in sorted(c.coll.items())}}


def top_contributors(hlo: str, n: int = 15, key: str = "bytes"
                     ) -> list[tuple[str, list[float]]]:
    """Largest contributors by 'flops' | 'bytes' | 'coll' — the dry-run
    profiler for the §Perf hypothesis loop."""
    a = Analyzer(hlo)
    a.analyze()
    idx = {"flops": 0, "bytes": 1, "coll": 2}[key]
    return sorted(a.contrib.items(), key=lambda kv: -kv[1][idx])[:n]

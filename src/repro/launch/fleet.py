"""Beyond-paper integration: GreenPod TOPSIS as the fleet placement engine.

The paper schedules K8s pods onto a heterogeneous set of VM node classes
(Table I: frugal A, balanced B, fast-but-hungry C) by five criteria. On a
TPU fleet the analogous decision is placing a JOB (architecture x input
shape, i.e. a compiled dry-run cell, which runs at its compiled mesh size)
onto a SLICE of a heterogeneous fleet (chip generations differ in speed,
HBM, and power — the exact heterogeneity axis of the paper's Table I).

The criteria vector is derived from the job's compiled roofline terms
(launch/dryrun.py output) evaluated on the candidate slice's generation:

  0 step_time (cost)    — dominant roofline term / gen speed x slice health
  1 energy    (cost)    — step_time x chips x gen power at the job's
                          compute utilization (+ idle wake-up share for a
                          previously-idle slice — the consolidation signal,
                          same mechanism as core/energy.predicted_task_*)
  2 chips     (benefit) — free chips after placement
  3 hbm_headroom (benefit) — free HBM/chip after the job's peak bytes
  4 balance   (benefit) — 1 - |compute_term - memory_term| / step_time

This is the honest TPU-native adaptation (DESIGN.md §2b): "energy profiling"
is exact arithmetic over the compiled artifact instead of a wattmeter; the
TOPSIS engine and weighting schemes are byte-identical to the paper
reproduction in repro/core.

Straggler mitigation (train/fault.py): a StragglerAlert marks the slice
degraded (health multiplier on step_time) and `replace_slice` re-ranks —
the paper's adaptive response to system conditions, applied to fleet health.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

import numpy as np

from repro.core import topsis
from repro.core.criteria import FLEET_CRITERIA

_BENEFIT = np.array([c.benefit for c in FLEET_CRITERIA], dtype=bool)

# Fleet-level weighting schemes (Table-III profiles re-expressed for the
# fleet criteria). The cluster-simulator schemes in core/weighting.py are
# calibrated to the paper's GKE dynamics; the fleet's step-time/energy
# dynamic range is different (2-3x speed spread between generations), so the
# profiles are stated directly: same intent, fleet-scaled emphasis.
FLEET_SCHEMES: dict[str, np.ndarray] = {
    "general": np.array([0.20, 0.20, 0.20, 0.20, 0.20]),
    "energy_centric": np.array([0.10, 0.60, 0.10, 0.10, 0.10]),
    "performance_centric": np.array([0.60, 0.05, 0.15, 0.15, 0.05]),
    "resource_efficient": np.array([0.10, 0.25, 0.25, 0.25, 0.15]),
}


def fleet_weights(scheme: str) -> np.ndarray:
    w = FLEET_SCHEMES[scheme]
    return w / w.sum()

# Heterogeneous fleet generations — the Table-I node classes of the TPU
# world. speed: relative step-rate; hbm: bytes/chip; tdp/idle: W/chip.
GENERATIONS: dict[str, dict[str, float]] = {
    # class-A analog: slow-ish, frugal, HBM-constrained (best J/step)
    "v5e": {"speed": 1.0, "hbm": 16e9, "tdp": 250.0, "idle": 70.0},
    # class-B analog: balanced
    "v4":  {"speed": 0.85, "hbm": 32e9, "tdp": 240.0, "idle": 75.0},
    # class-C analog: fastest step, worst J/step (turbo DVFS profile;
    # board + fabric power — illustrative class profile mirroring Table I)
    "v5p": {"speed": 2.3, "hbm": 95e9, "tdp": 700.0, "idle": 250.0},
}


@dataclasses.dataclass
class Slice:
    name: str
    chips: int
    free_chips: int
    gen: str = "v5e"
    health: float = 1.0          # >1 = degraded (straggler multiplier)
    awake: bool = False          # hosting at least one job

    @property
    def hbm_per_chip(self) -> float:
        return GENERATIONS[self.gen]["hbm"]

    def degrade(self, factor: float = 2.0):
        self.health *= factor

    def heal(self):
        self.health = 1.0


@dataclasses.dataclass(frozen=True)
class Job:
    """One dry-run cell, as a schedulable unit (runs at its compiled size)."""
    arch: str
    shape: str
    chips_wanted: int            # mesh size the cell was compiled for
    compute_s: float             # roofline terms on the reference gen (v5e)
    memory_s: float
    collective_s: float
    peak_bytes_per_dev: float

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def utilization(self) -> float:
        """Compute-term share of the step — the MFU-ish factor that scales
        dynamic chip power."""
        t = self.step_time_s
        return min(self.compute_s / t, 1.0) if t > 0 else 0.0


def load_jobs(dryrun_dir: str, mesh: str = "single") -> list[Job]:
    """Jobs from launch/dryrun.py JSON records."""
    jobs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        r = rec["roofline"]
        jobs.append(Job(rec["arch"], rec["shape"], rec["chips"],
                        r["compute_s"], r["memory_s"], r["collective_s"],
                        rec["memory"]["peak_bytes"]))
    return jobs


def job_on_slice(job: Job, s: Slice) -> tuple[float, float]:
    """(step_time_s, energy_J) of the job on slice s's generation."""
    g = GENERATIONS[s.gen]
    step = job.step_time_s / g["speed"] * s.health
    util = job.utilization()
    power = g["idle"] + (g["tdp"] - g["idle"]) * util
    energy = step * job.chips_wanted * power
    if not s.awake:
        # waking an idle slice bills its idle power for the step duration —
        # the marginal-energy consolidation signal (paper §V.D / core.energy)
        energy += step * s.chips * g["idle"]
    return step, energy


def feasible(job: Job, s: Slice) -> bool:
    return (s.free_chips >= job.chips_wanted
            and job.peak_bytes_per_dev <= s.hbm_per_chip)


def decision_matrix(job: Job, slices: list[Slice]) -> np.ndarray:
    rows = []
    for s in slices:
        step, energy = job_on_slice(job, s)
        # fractional benefit criteria, like the paper's cores/memory columns
        free_after = max(s.free_chips - job.chips_wanted, 0) / s.chips
        hbm_free = max(s.hbm_per_chip - job.peak_bytes_per_dev, 0.0) \
            / s.hbm_per_chip
        g = GENERATIONS[s.gen]
        comp = job.compute_s / g["speed"]
        balance = 1.0 - abs(comp - step) / max(step, 1e-12)
        rows.append([step, energy, free_after, hbm_free, balance])
    return np.asarray(rows, dtype=np.float64)


def place(job: Job, slices: list[Slice], scheme: str = "energy_centric"
          ) -> tuple[int | None, dict]:
    """TOPSIS-selected slice index for the job (None if unschedulable)."""
    valid = np.array([feasible(job, s) for s in slices])
    if not valid.any():
        return None, {"reason": "unschedulable"}
    M = decision_matrix(job, slices)
    w = fleet_weights(scheme)
    res = topsis.closeness_np(M, w, _BENEFIT, valid)
    idx = int(res.ranking[0])
    return idx, {"closeness": res.closeness, "matrix": M}


def bind(job: Job, s: Slice):
    assert feasible(job, s)
    s.free_chips -= job.chips_wanted
    s.awake = True


def replace_slice(job: Job, slices: list[Slice], current: int,
                  scheme: str = "energy_centric") -> int | None:
    """Straggler mitigation: degrade the current slice and re-place."""
    slices[current].degrade()
    idx, _ = place(job, slices, scheme)
    return idx


def schedule_queue(jobs: list[Job], slices: list[Slice],
                   scheme: str = "energy_centric"
                   ) -> list[tuple[Job, int | None]]:
    """FIFO placement of a job queue with chip accounting."""
    out = []
    for job in jobs:
        idx, _ = place(job, slices, scheme)
        if idx is not None:
            bind(job, slices[idx])
        out.append((job, idx))
    return out

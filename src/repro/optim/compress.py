"""Error-feedback int8 gradient compression for data-parallel all-reduce.

Each data shard quantizes its local gradient to int8 (blockwise absmax),
all-reduces the int8 payload (as int32 accumulators to avoid overflow), and
keeps the quantization residual locally, adding it back into the next step's
gradient (error feedback — Karimireddy et al., 2019). Cuts DP all-reduce
bytes 4x vs f32 / 2x vs bf16.

Used inside shard_map over the data axis (see repro.train.loop.
make_compressed_dp_step and tests/test_compression.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale[:, None], 1e-20)),
                 -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale[:, None]
    return q, scale, deq.reshape(-1)[:x.size].reshape(x.shape)


def compressed_psum(g: jax.Array, err: jax.Array, axis: str):
    """Inside shard_map: returns (mean-reduced g_hat, new local error).

    g_hat = dequant(psum(quant(g + err))) / n ; err' = (g + err) - local deq.
    Scales are psum-averaged — each shard's contribution is exact under its
    own scale only, so we reduce int32 payloads and average dequantized
    values by summing per-shard (q * own-scale) via a second psum of the
    f32 block sums... kept simple: psum(q)*mean_scale is the standard
    approximation; error feedback absorbs the residual.
    """
    x = g.astype(jnp.float32) + err
    q, scale, deq_local = _quantize(x)
    q32 = q.astype(jnp.int32)
    qsum = jax.lax.psum(q32, axis)
    ssum = jax.lax.psum(scale, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean_scale = ssum / n
    blocks = qsum.astype(jnp.float32) * mean_scale[:, None]
    g_hat = blocks.reshape(-1)[:g.size].reshape(g.shape) / n
    new_err = x - deq_local
    return g_hat.astype(g.dtype), new_err


def tree_compressed_psum(grads, errs, axis: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out = [compressed_psum(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

"""AdamW with optional block-quantized (int8) moments and ZeRO-1 sharding.

No optax in this environment — implemented from scratch. The int8 moment
store (blockwise absmax quantization, fp32 scales per 128-value block) cuts
optimizer-state HBM by ~3.5x, which is what lets deepseek-v3-671b training
state fit 512 x 16 GB chips (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 128


# --- blockwise int8 quantization -------------------------------------------
@jax.tree_util.register_pytree_with_keys_class
class QTensor:
    """int8 moment store with SHAPE-PRESERVING layout: ``q`` has the param's
    shape (last dim padded to a BLOCK multiple) and ``scale`` has one f32
    absmax per last-dim block. Because q's dims mirror the param's, the
    moments take the PARAM's PartitionSpec verbatim — the optimizer update
    is then collective-free (no flat-view resharding; §Perf deepseek-v3).
    ``shape`` is static pytree aux data (never traced)."""

    def __init__(self, q: jax.Array, scale: jax.Array, shape: tuple):
        self.q = q           # int8, shape[:-1] + (padded last,)
        self.scale = scale   # f32, shape[:-1] + (n_blocks,)
        self.shape = tuple(shape)

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.DictKey("q"), self.q),
                 (jax.tree_util.DictKey("scale"), self.scale)), self.shape)

    @classmethod
    def tree_unflatten(cls, shape, children):
        return cls(children[0], children[1], shape)

    def __repr__(self):
        return f"QTensor(shape={self.shape})"


def quantize(x: jax.Array) -> QTensor:
    shape = x.shape
    x = x.astype(jnp.float32)
    if x.ndim == 0:
        x = x[None]
    last = x.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(*x.shape[:-1], -1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-20)
                  ).astype(jnp.int8)
    return QTensor(q.reshape(*x.shape[:-1], -1), scale, shape)


def dequantize(t: QTensor) -> jax.Array:
    blocks = t.q.reshape(*t.q.shape[:-1], -1, BLOCK).astype(jnp.float32) \
        * t.scale[..., None]
    out = blocks.reshape(*t.q.shape)
    if not t.shape:
        return out[0]
    last = t.shape[-1]
    if out.shape[-1] != last:
        out = jax.lax.slice_in_dim(out, 0, last, axis=-1)
    return out.reshape(t.shape)


# --- AdamW -------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False     # int8 m/v
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * cos


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(cfg: AdamWConfig, params) -> OptState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return quantize(z) if cfg.quantized_state else z
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(zero_like, params),
                    jax.tree.map(zero_like, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = dequantize(m) if cfg.quantized_state else m
        vf = dequantize(v) if cfg.quantized_state else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        u = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        if cfg.quantized_state:
            return newp, quantize(mf), quantize(vf)
        return newp, mf, vf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v), metrics

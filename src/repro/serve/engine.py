"""Batched serving engine: prefill + decode with KV cache, greedy or
temperature sampling, wave-based continuous batching.

Requests are grouped into fixed-size waves (all slots prefill together and
decode in lockstep; finished sequences are masked). Per-slot variable start
positions (true continuous batching) are a documented extension — the
assigned decode_* roofline shapes are uniform-length, which this engine
lowers exactly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = 1


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray


class Engine:
    def __init__(self, model: LM, params, *, batch_slots: int,
                 max_len: int, extra_inputs: dict | None = None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.extra = extra_inputs or {}
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode)

    def run_wave(self, requests: list[Request]) -> list[Result]:
        assert len(requests) <= self.B
        reqs = list(requests)
        while len(reqs) < self.B:                 # pad with a dummy slot
            reqs.append(Request(uid=-1, prompt=reqs[0].prompt,
                                max_new_tokens=reqs[0].max_new_tokens))
        plen = max(len(r.prompt) for r in reqs)
        prompts = np.zeros((self.B, plen), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(prompts), **self.extra}
        logits, cache = self._prefill(self.params, batch)
        out = [[] for _ in range(self.B)]
        done = np.zeros((self.B,), bool)
        tok = jnp.argmax(logits, axis=-1)
        max_new = max(r.max_new_tokens for r in reqs)
        for step in range(max_new):
            t_np = np.asarray(tok)
            for i, r in enumerate(reqs):
                if not done[i] and step < r.max_new_tokens:
                    out[i].append(int(t_np[i]))
                    if int(t_np[i]) == r.eos_id:
                        done[i] = True
                elif step >= r.max_new_tokens:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)
        return [Result(r.uid, np.asarray(o, np.int32))
                for r, o in zip(reqs, out) if r.uid >= 0]

    def serve(self, requests: list[Request]) -> list[Result]:
        """Process a request queue in waves of B slots."""
        results = []
        for i in range(0, len(requests), self.B):
            results.extend(self.run_wave(requests[i:i + self.B]))
        return results

"""Deterministic synthetic token pipeline — shard-aware, prefetching.

Production semantics without a dataset dependency: every (step, position) is
a pure function of the seed, so restarts resume bit-identically from any step
(checkpoint stores only the step counter), and each data shard generates only
its local slice (no host broadcast at 1000-node scale).
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class SyntheticLM:
    """Zipfian token stream with a learnable bigram structure so the training
    loss actually decreases (tests assert it)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab, self.seq_len, self.global_batch = vocab, seq_len, global_batch
        self.seed = seed
        probs = 1.0 / np.arange(1, vocab + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch_at(self, step: int, lo: int = 0, hi: int | None = None
                 ) -> np.ndarray:
        """Rows [lo, hi) of the global batch for `step` (shard-local gen)."""
        hi = self.global_batch if hi is None else hi
        # generate the full batch index stream cheaply but slice locally:
        # rows are independent streams keyed by (seed, step, row)
        out = np.empty((hi - lo, self.seq_len), np.int32)
        for i, row in enumerate(range(lo, hi)):
            r = np.random.default_rng((self.seed, step, row))
            toks = r.choice(self.vocab, size=self.seq_len, p=self._probs)
            # inject bigram structure: every odd position repeats f(prev)
            toks[1::2] = (toks[0::2] * 31 + 7) % self.vocab
            out[i] = toks
        return out


def make_global_batch(mesh: Mesh, arrays: dict[str, np.ndarray]):
    """Host numpy -> globally-sharded jax arrays (batch dim over pod+data)."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
    out = {}
    for k, v in arrays.items():
        spec = P(ba, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


class Prefetcher:
    """Background-thread prefetch of the next N batches."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item

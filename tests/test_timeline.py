"""Sim-time metric timelines, the benchmark regression gate, and the
operator HTML report.

The timeline layer extends the pure-observer invariant to series: same
scenario ⇒ bit-identical series on every backend (placements are already
bitwise, and every sample is a deterministic function of sim state).
test_telemetry.py pins that recording doesn't change the run; this file
pins what the recorder itself produces.
"""
import json
import math
import xml.etree.ElementTree as ET

import pytest

from engine_golden_spec import run_cell
from repro.core import telemetry
from repro.core.telemetry import (DEFAULT_SERIES_MAX_POINTS, Telemetry,
                                  TimeSeries)
from repro.telemetry.baseline import (append_history, cell_key,
                                     compare_reports, format_verdict,
                                     history_entries)
from repro.telemetry.report import html_report, write_html_report


@pytest.fixture(autouse=True)
def _clean_registry():
    telemetry.disable()
    yield
    telemetry.disable()


# --- TimeSeries primitive ----------------------------------------------------
def test_series_append_and_last_write_wins():
    s = TimeSeries("q")
    s.record(0.0, 1.0)
    s.record(1.0, 2.0)
    s.record(1.0, 3.0)          # same sim instant: overwrite
    assert s.points() == [(0.0, 1.0), (1.0, 3.0)]
    assert s.samples == 3       # pre-decimation count keeps every call
    with pytest.raises(ValueError, match="backwards"):
        s.record(0.5, 9.0)


def test_series_decimation_bound_and_endpoints():
    s = TimeSeries("q", max_points=8)
    for i in range(1000):
        s.record(float(i), float(i * i))
    assert len(s) <= 8
    assert s.samples == 1000
    # endpoints are always exact, interior points are a subset
    assert s.times[0] == 0.0 and s.values[0] == 0.0
    assert s.times[-1] == 999.0 and s.values[-1] == 999.0 ** 2
    assert all(v == t * t for t, v in s.points())
    assert list(s.times) == sorted(s.times)
    with pytest.raises(ValueError, match=">= 4"):
        TimeSeries("q", max_points=2)


def test_series_decimation_deterministic():
    def build():
        s = TimeSeries("q", max_points=16)
        for i in range(257):
            s.record(i * 0.5, math.sin(i))
        return s.snapshot()

    assert build() == build()


def test_registry_series_cells_and_snapshot():
    tel = Telemetry(series_max_points=32)
    tel.record("power", 0.0, 5.0, region="eu")
    tel.record("power", 1.0, 6.0, region="eu")
    tel.record("power", 0.0, 2.0, region="us")
    tel.record("depth", 3.0, 1.0)
    assert tel.series_names() == ["depth", "power"]
    assert tel.series("power", region="eu").points() == [(0.0, 5.0),
                                                         (1.0, 6.0)]
    assert tel.series("power", region="us").max_points == 32
    assert tel.series("power") is None          # label-distinct cell
    snap = tel.snapshot()
    assert {s["name"] for s in snap["series"]} == {"power", "depth"}
    # the null registry swallows records (hot paths never branch)
    telemetry.NULL.record("power", 0.0, 1.0)
    assert not telemetry.NULL.enabled


# --- engine timelines: determinism and physics -------------------------------
def _record_run(backend):
    with telemetry.enabled() as tel:
        res = run_cell("carbon_autoscale", backend)
    return tel, res


def test_engine_series_present_and_consistent():
    tel, res = _record_run("numpy")
    names = tel.series_names()
    for want in ("engine_pending_depth", "engine_running_tasks",
                 "fleet_awake_nodes", "fleet_power_w",
                 "fleet_energy_cum_kj", "fleet_carbon_cum_g",
                 "fleet_state_nodes", "state_power_w",
                 "carbon_intensity_g_per_kwh", "region_carbon_cum_g",
                 "scheduler_energy_cum_kj"):
        assert want in names, want
    # every series is on the sim clock: non-negative, monotone timestamps
    for s in tel.timeseries.values():
        assert list(s.times) == sorted(s.times)
        assert s.times[0] >= 0.0
        assert len(s) <= DEFAULT_SERIES_MAX_POINTS
    # cumulative sampled energy/carbon never exceed the exact ledger
    # totals (left-rectangle sampling stops at the last visited instant)
    tl = res._timeline()
    cum_e = tel.series("fleet_energy_cum_kj").values
    assert all(b >= a for a, b in zip(cum_e, cum_e[1:]))
    assert 0.0 < cum_e[-1] <= tl.fleet_energy_kj() + 1e-9
    cum_c = tel.series("fleet_carbon_cum_g").values
    assert 0.0 < cum_c[-1] <= tl.fleet_carbon_g() + 1e-9
    # the ledger-published per-scheduler series ends at the exact total
    for sched in ("topsis", "default"):
        s = tel.series("scheduler_energy_cum_kj", scheduler=sched)
        assert s.values[-1] == pytest.approx(res.energy_kj(sched),
                                             rel=1e-12)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_series_bitwise_identical_across_backends(backend):
    tel_np, _ = _record_run("numpy")
    tel_acc, _ = _record_run(backend)
    snap_np = {k: s.snapshot() for k, s in tel_np.timeseries.items()}
    snap_acc = {k: s.snapshot() for k, s in tel_acc.timeseries.items()}
    assert snap_np == snap_acc


# --- regression gate ---------------------------------------------------------
def _cells():
    return [{"profile": "mixed", "n_nodes": 8, "backend": "numpy",
             "energy_topsis_kj": 10.0, "preemptions": 3,
             "mean_sched_time_topsis_ms": 5.0},
            {"profile": "mixed", "n_nodes": 8, "backend": "pallas",
             "energy_topsis_kj": 11.0,
             "mean_sched_time_topsis_ms": 50.0}]


def _rep(cells, prov=None):
    rep = {"bench": "scenario_sweep", "results": cells}
    if prov is not None:
        rep["provenance"] = prov
    return rep


def test_gate_passes_on_identical_reports():
    v = compare_reports(_rep(_cells()), _rep(_cells()))
    assert v["status"] == "pass" and v["regressions"] == 0
    assert all(r["status"] == "ok" for r in v["rows"])
    assert "[PASS]" in format_verdict(v)


def test_gate_trips_on_exact_drift_both_directions():
    for factor in (1.01, 0.99):
        cur = _cells()
        cur[0]["energy_topsis_kj"] *= factor
        v = compare_reports(_rep(cur), _rep(_cells()))
        assert v["status"] == "regression"
        bad = [r for r in v["rows"] if r["status"] == "regression"]
        assert [r["metric"] for r in bad] == ["energy_topsis_kj"]
        assert "[REGRESSION]" in format_verdict(v)
        assert "energy_topsis_kj" in format_verdict(v)


def test_gate_timing_is_one_sided_with_headroom():
    cur = _cells()
    cur[0]["mean_sched_time_topsis_ms"] *= 1.5     # within +75%
    assert compare_reports(_rep(cur), _rep(_cells()))["status"] == "pass"
    cur[0]["mean_sched_time_topsis_ms"] = 5.0 * 2.0  # +100%: trips
    v = compare_reports(_rep(cur), _rep(_cells()))
    assert v["status"] == "regression"
    cur[0]["mean_sched_time_topsis_ms"] = 0.5      # 10x faster: improved
    v = compare_reports(_rep(cur), _rep(_cells()))
    assert v["status"] == "pass"
    assert any(r["status"] == "improved" for r in v["rows"])


def test_gate_interpret_mode_skips_timings_not_physics():
    cur = _rep(_cells(), prov={"pallas_interpret": True})
    base = _rep(_cells(), prov={"pallas_interpret": False})
    cur["results"][1]["mean_sched_time_topsis_ms"] = 5000.0  # 100x slower
    cur["results"][1]["energy_topsis_kj"] = 11.5             # and wrong
    v = compare_reports(cur, base)
    skipped = [r for r in v["rows"] if r["status"] == "skipped"]
    assert [(r["metric"], r["cell"].split("/")[0]) for r in skipped] \
        == [("mean_sched_time_topsis_ms", "backend=pallas")]
    assert "interpret_mode" in skipped[0]["reason"]
    # the physics drift on the same cell still trips
    assert v["status"] == "regression"
    # a per-cell interpret_mode annotation wins over report provenance
    cur["results"][1]["interpret_mode"] = False
    v2 = compare_reports(cur, base)
    assert not any(r["status"] == "skipped" for r in v2["rows"])


def test_gate_platform_mismatch_skips_all_timings():
    cur = _rep(_cells(), prov={"jax_platform": "tpu"})
    base = _rep(_cells(), prov={"jax_platform": "cpu"})
    cur["results"][0]["mean_sched_time_topsis_ms"] = 5000.0
    v = compare_reports(cur, base)
    assert v["status"] == "pass"
    timing = [r for r in v["rows"]
              if r["metric"] == "mean_sched_time_topsis_ms"]
    assert timing and all(r["status"] == "skipped" for r in timing)
    assert all("jax_platform" in r["reason"] for r in timing)


def test_gate_missing_cells_and_unknown_metrics_surface():
    cur = _cells()[:1]
    cur[0]["shiny_new_metric"] = 1.23          # unregistered float
    base = _cells()
    base[1]["n_nodes"] = 64                    # cell only in baseline
    v = compare_reports(_rep(cur), _rep(base))
    assert v["status"] == "pass"               # warnings, not failures
    assert len(v["missing_in_current"]) == 1
    assert "n_nodes=64" in v["missing_in_current"][0]
    assert v["unchecked_metrics"] == ["shiny_new_metric"]
    assert "shiny_new_metric" in format_verdict(v)
    # the unknown float is excluded from identity, so the cell matched
    assert cell_key(cur[0]) == cell_key(_cells()[0])


def test_check_cli_exit_codes(tmp_path, monkeypatch):
    import benchmarks.common
    import benchmarks.run as run_mod
    monkeypatch.setattr(benchmarks.common, "HISTORY_DIR",
                        str(tmp_path / "history"))
    monkeypatch.chdir(tmp_path)
    (tmp_path / "baselines").mkdir()
    report = _rep(_cells())
    (tmp_path / "BENCH_scenarios.json").write_text(json.dumps(report))
    (tmp_path / "baselines" / "BENCH_scenarios.json").write_text(
        json.dumps(report))
    files = ("BENCH_scenarios.json",)
    assert run_mod.check(files=files,
                         baseline_dir=str(tmp_path / "baselines")) == 0
    # perturb the physics: nonzero exit
    report["results"][0]["energy_topsis_kj"] *= 1.05
    (tmp_path / "BENCH_scenarios.json").write_text(json.dumps(report))
    assert run_mod.check(files=files,
                         baseline_dir=str(tmp_path / "baselines")) == 1
    # both runs appended to the history trajectory
    entries = history_entries(tmp_path / "history"
                              / "scenario_sweep.jsonl")
    assert [e["status"] for e in entries] == ["pass", "regression"]
    assert all(e["kind"] == "check" for e in entries)
    # missing baseline: warn and pass
    assert run_mod.check(files=files,
                         baseline_dir=str(tmp_path / "nowhere")) == 0


def test_write_report_appends_history(tmp_path, monkeypatch):
    import benchmarks.common as common
    monkeypatch.setattr(common, "HISTORY_DIR", str(tmp_path / "history"))
    out = tmp_path / "BENCH_x.json"
    common.write_report({"bench": "x_sweep", "config": {"seed": 0},
                         "results": [{"a": 1}]}, str(out))
    common.write_report({"bench": "x_sweep", "config": {"seed": 0},
                         "results": [{"a": 2}]}, str(out))
    entries = history_entries(tmp_path / "history" / "x_sweep.jsonl")
    assert [e["kind"] for e in entries] == ["record", "record"]
    assert entries[1]["results"] == [{"a": 2}]
    assert entries[0]["provenance"]["python"]
    # out=None records nothing
    common.write_report({"bench": "y_sweep", "results": []}, None)
    assert history_entries(tmp_path / "history" / "y_sweep.jsonl") == []


def test_history_round_trip(tmp_path):
    path = tmp_path / "h.jsonl"
    assert history_entries(path) == []
    append_history({"kind": "check", "status": "pass"}, path)
    append_history({"kind": "record", "bench": "b"}, path)
    entries = history_entries(path)
    assert len(entries) == 2 and entries[0]["status"] == "pass"


def test_aggregate_warns_on_mismatched_provenance(capsys):
    from benchmarks.run import _provenance_warnings
    summary = {
        "BENCH_a.json": {"provenance": {"git_sha": "aaa",
                                        "pallas_interpret": True}},
        "BENCH_b.json": {"provenance": {"git_sha": "bbb",
                                        "pallas_interpret": True}},
    }
    warnings = _provenance_warnings(summary)
    assert len(warnings) == 1 and "git SHAs" in warnings[0]
    summary["BENCH_b.json"]["provenance"] = {"git_sha": "aaa",
                                             "pallas_interpret": False}
    warnings = _provenance_warnings(summary)
    assert len(warnings) == 1 and "interpret" in warnings[0]
    # coherent fingerprints: silent
    summary["BENCH_b.json"]["provenance"] = {"git_sha": "aaa",
                                             "pallas_interpret": True}
    assert _provenance_warnings(summary) == []


# --- HTML report -------------------------------------------------------------
def test_html_report_well_formed_and_complete(tmp_path):
    tel, res = _record_run("numpy")
    doc = html_report(tel=tel, result=res, title="golden <run> & report")
    root = ET.fromstring(doc)            # well-formed XML or bust
    assert root.tag == "html"
    for name in tel.series_names():
        assert name in doc, f"series {name} missing from report"
    # the title is escaped, summary tiles and registry render
    assert "golden &lt;run&gt; &amp; report" in doc
    assert "Pods placed" in doc
    assert "scheduler_decision_seconds" in doc
    path = write_html_report(tmp_path / "run.html", tel=tel, result=res)
    assert ET.fromstring(open(path).read()).tag == "html"


def test_html_report_degenerate_inputs_still_parse():
    assert ET.fromstring(html_report()).tag == "html"
    tel = Telemetry()
    tel.record("lonely_series", 0.0, 1.0)
    doc = html_report(tel=tel)
    ET.fromstring(doc)
    assert "lonely_series" in doc
    # single label variant: no legend box (the chart title names it)
    assert 'class="legend"' not in doc
    tel.record("lonely_series", 0.0, 2.0, region="eu")
    tel.record("lonely_series", 0.0, 3.0, region="us")
    doc2 = html_report(tel=tel)
    ET.fromstring(doc2)
    assert 'class="legend"' in doc2      # >=2 variants: legend present

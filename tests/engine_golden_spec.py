"""The recorded golden scenario, in one place.

tests/golden_engine_scenarios.json pins the kernel refactor bitwise
against the pre-refactor engine; this module is the single source of the
scenario it was recorded under — the signal/policy constructors, the
arrival process, the fleet, and the policy matrix. Both the recorder
(scripts/record_engine_golden.py) and the pin (tests/test_engine.py)
import it, so the two can never drift apart silently. If the engine's
behaviour is changed *intentionally*, re-record the golden with the
script and say so in the PR.
"""
from repro.core.carbon import CarbonPolicy, diurnal_fleet_signal
from repro.core.elastic import AutoscalePolicy
from repro.cluster.node import make_scenario_cluster
from repro.cluster.simulator import run_scenario
from repro.cluster.workload import PoissonArrivals

PERIOD_S = 1800.0

# scenario name -> which policies are attached and whether the arrival
# stream carries deferrable pods
SCENARIOS = {
    "policy_free": dict(carbon=False, autoscale=False, deferrable=False),
    "carbon_only": dict(carbon=True, autoscale=False, deferrable=True),
    "autoscale_only": dict(carbon=False, autoscale=True, deferrable=False),
    "carbon_autoscale": dict(carbon=True, autoscale=True, deferrable=True),
}


def make_carbon() -> CarbonPolicy:
    sig = diurnal_fleet_signal(base=300.0, amplitude=200.0,
                               period_s=PERIOD_S, phase_s=PERIOD_S / 4.0,
                               stagger_s=PERIOD_S / 16.0)
    return CarbonPolicy(sig, defer_threshold=300.0, preempt_threshold=450.0,
                        check_interval_s=30.0)


def make_autoscale() -> AutoscalePolicy:
    return AutoscalePolicy(idle_timeout_s=20.0, min_awake=1,
                           consolidate_interval_s=60.0,
                           consolidate_util_below=0.3)


def arrivals(deferrable: bool, seed: int = 7) -> PoissonArrivals:
    return PoissonArrivals(rate_per_s=0.3, n_bursts=3, burst_size=4,
                           seed=seed,
                           deferrable_share=0.5 if deferrable else 0.0,
                           deadline_s=300.0)


def fleet(seed: int = 3):
    return lambda: make_scenario_cluster("mixed", 8, seed=seed)


def run_cell(name: str, backend: str):
    """One golden cell: the named policy combination on one backend."""
    spec = SCENARIOS[name]
    return run_scenario(
        arrivals(spec["deferrable"]), "energy_centric",
        cluster_factory=fleet(), batch=True, batch_backend=backend,
        carbon=make_carbon() if spec["carbon"] else None,
        autoscale=make_autoscale() if spec["autoscale"] else None)

"""Beyond-paper fleet scheduler: TOPSIS over heterogeneous TPU slices with
roofline-derived criteria."""
import pytest

from repro.launch import fleet


def mk_job(chips=256, comp=1.0, mem=2.0, coll=0.5, peak=8e9,
           arch="llama3-8b", shape="train_4k"):
    return fleet.Job(arch, shape, chips, comp, mem, coll, peak)


def mk_fleet():
    return [fleet.Slice("e0", 256, 256, "v5e"),
            fleet.Slice("p0", 256, 256, "v5p"),
            fleet.Slice("v0", 256, 256, "v4")]


def test_feasibility_chips_and_hbm():
    job = mk_job(chips=256, peak=8e9)
    assert fleet.feasible(job, fleet.Slice("s", 256, 256, "v5e"))
    assert not fleet.feasible(job, fleet.Slice("s", 256, 128, "v5e"))
    # 20 GB/chip peak: too big for v5e (16 GB), fits v5p (95 GB)
    big = mk_job(peak=20e9)
    assert not fleet.feasible(big, fleet.Slice("s", 256, 256, "v5e"))
    assert fleet.feasible(big, fleet.Slice("s", 256, 256, "v5p"))


def test_job_on_slice_physics():
    job = mk_job(comp=1.0, mem=2.0, coll=0.5)
    e = fleet.Slice("e", 256, 256, "v5e", awake=True)
    p = fleet.Slice("p", 256, 256, "v5p", awake=True)
    step_e, en_e = fleet.job_on_slice(job, e)
    step_p, en_p = fleet.job_on_slice(job, p)
    assert step_p < step_e                       # v5p is faster
    assert en_p > en_e * 0.5                     # but not proportionally frugal
    # waking an idle slice costs extra energy
    e_idle = fleet.Slice("e2", 256, 256, "v5e", awake=False)
    _, en_wake = fleet.job_on_slice(job, e_idle)
    assert en_wake > en_e


def test_energy_vs_performance_scheme_preference():
    """Energy-centric prefers the frugal v5e; performance-centric the fast
    v5p — the TPU analog of paper §V.D (class A vs class C allocation).
    The job fits all generations comfortably (peak 2 GB/chip), like the
    paper's pods on class-A nodes."""
    job = mk_job(peak=2e9)
    ie, _ = fleet.place(job, mk_fleet(), "energy_centric")
    ip, _ = fleet.place(job, mk_fleet(), "performance_centric")
    assert mk_fleet()[ie].gen == "v5e"
    assert mk_fleet()[ip].gen == "v5p"


def test_hbm_tight_job_resource_efficient_moves_off():
    """A job that nearly fills v5e HBM: resource-efficient weighting (high
    availability emphasis) moves off the tight slice; energy-centric may
    still take it (it fits). Paper §V.C: high contention needs hybrid
    resource-aware profiles."""
    job = mk_job(peak=15e9)
    ir, _ = fleet.place(job, mk_fleet(), "resource_efficient")
    assert mk_fleet()[ir].gen != "v5e"
    ie, _ = fleet.place(job, mk_fleet(), "energy_centric")
    assert ie is not None    # still schedulable


def test_consolidation_prefers_awake_slice():
    job = mk_job(chips=64)
    slices = [fleet.Slice("a", 256, 256, "v5e", awake=False),
              fleet.Slice("b", 256, 192, "v5e", awake=True)]
    idx, _ = fleet.place(job, slices, "energy_centric")
    assert slices[idx].awake


def test_place_avoids_degraded_slice():
    job = mk_job()
    slices = [fleet.Slice("a", 256, 256, "v5e"),
              fleet.Slice("b", 256, 256, "v5e")]
    slices[0].degrade(10.0)
    idx, _ = fleet.place(job, slices)
    assert idx == 1


def test_replace_slice_moves_away():
    job = mk_job()
    slices = mk_fleet()
    cur, _ = fleet.place(job, slices)
    new = fleet.replace_slice(job, slices, current=cur)
    assert slices[cur].health > 1.0
    assert new != cur


def test_schedule_queue_accounts_chips():
    jobs = [mk_job() for _ in range(3)]
    slices = mk_fleet()            # 3 x 256 chips
    placed = fleet.schedule_queue(jobs, slices)
    assert all(idx is not None for _, idx in placed)
    assert sum(s.free_chips for s in slices) == 0
    idx, diag = fleet.place(mk_job(), slices)
    assert idx is None and diag["reason"] == "unschedulable"


def test_unschedulable_when_hbm_everywhere_too_small():
    job = mk_job(peak=200e9)
    idx, _ = fleet.place(job, mk_fleet())
    assert idx is None


def test_load_jobs_from_dryrun(tmp_path):
    import json
    rec = {"arch": "llama3-8b", "shape": "train_4k", "mesh": "single",
           "chips": 256, "ok": True,
           "roofline": {"compute_s": 1.0, "memory_s": 2.0,
                        "collective_s": 0.5, "dominant": "memory_s"},
           "memory": {"peak_bytes": 8e9}}
    (tmp_path / "llama3-8b__train_4k__single.json").write_text(
        json.dumps(rec))
    (tmp_path / "bad__x__single.json").write_text(json.dumps(
        {"ok": False, "arch": "x", "shape": "y"}))
    jobs = fleet.load_jobs(str(tmp_path))
    assert len(jobs) == 1
    assert jobs[0].step_time_s == 2.0
    assert jobs[0].utilization() == pytest.approx(0.5)

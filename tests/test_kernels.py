"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles in ref.py.

Kernels execute in interpret mode on CPU (the kernel body runs in Python);
on a real TPU the same pallas_call compiles to Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# --- TOPSIS kernel ------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 4, 100, 128, 1000, 4096])
@pytest.mark.parametrize("c", [2, 5, 8])
def test_topsis_kernel_sweep(n, c):
    key = jax.random.PRNGKey(n * 31 + c)
    mat = jax.random.uniform(key, (n, c), jnp.float32, 0.05, 10.0)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (c,), jnp.float32,
                           0.1, 1.0)
    benefit = jax.random.bernoulli(jax.random.fold_in(key, 2), shape=(c,))
    got = ops.topsis_closeness(mat, w, benefit)
    want = ref.topsis_closeness_ref(mat, w, benefit)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("block_n", [128, 256, 2048])
def test_topsis_kernel_block_shapes(block_n):
    key = jax.random.PRNGKey(0)
    mat = jax.random.uniform(key, (700, 5), jnp.float32, 0.05, 10.0)
    w = jnp.ones((5,)) / 5
    benefit = jnp.array([0, 0, 1, 1, 1], bool)
    got = ops.topsis_closeness(mat, w, benefit, block_n=block_n)
    want = ref.topsis_closeness_ref(mat, w, benefit)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_topsis_kernel_matches_core_engine():
    """Kernel == repro.core.topsis.closeness (the paper-semantics engine)."""
    from repro.core.topsis import closeness
    key = jax.random.PRNGKey(3)
    mat = jax.random.uniform(key, (64, 5), jnp.float32, 0.1, 5.0)
    w = jnp.asarray([.2, .35, .15, .15, .15])
    benefit = jnp.array([0, 0, 1, 1, 1], bool)
    got = ops.topsis_closeness(mat, w, benefit)
    want = closeness(mat, w, benefit).closeness
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# --- RMSNorm kernel -------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 64), (2, 7, 96), (1, 128), (3, 300),
                                   (256, 1024), (5, 2, 3, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_sweep(shape, dtype):
    key = jax.random.PRNGKey(hash(shape) % (2 ** 31))
    x = jax.random.normal(key, shape, dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],),
                          jnp.float32)
    got = ops.rmsnorm(x, g)
    want = ref.rmsnorm_ref(x, g)
    assert got.dtype == x.dtype and got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("block_rows", [8, 64, 512])
def test_rmsnorm_block_shapes(block_rows):
    x = jax.random.normal(jax.random.PRNGKey(0), (100, 384), jnp.float32)
    g = jnp.ones((384,))
    got = ops.rmsnorm(x, g, block_rows=block_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.rmsnorm_ref(x, g)),
                               atol=2e-5, rtol=2e-5)


# --- Flash attention kernel ------------------------------------------------------
@pytest.mark.parametrize("s,h,hkv,d", [
    (64, 4, 4, 32),          # MHA
    (128, 8, 2, 64),         # GQA 4:1
    (256, 4, 1, 64),         # MQA
    (96, 2, 2, 80),          # ragged seq + odd head dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, hkv, d, causal, dtype):
    key = jax.random.PRNGKey(s + h * 7 + d)
    ks = jax.random.split(key, 3)
    q = (jax.random.normal(ks[0], (2, h, s, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (2, hkv, s, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (2, hkv, s, d)) * 0.5).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    """Mixtral-style SWA against the masked reference."""
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)) * 0.5
    k = jax.random.normal(ks[1], (1, 2, 128, 64)) * 0.5
    v = jax.random.normal(ks[2], (1, 2, 128, 64)) * 0.5
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(bq, bk):
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64)) * 0.5
    k = jax.random.normal(ks[1], (1, 2, 256, 64)) * 0.5
    v = jax.random.normal(ks[2], (1, 2, 256, 64)) * 0.5
    got = ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_sdpa():
    """Kernel semantics == the model's _sdpa (what runs in the dry-run HLO)."""
    from repro.models.layers import _sdpa
    key = jax.random.PRNGKey(13)
    ks = jax.random.split(key, 3)
    B, S, H, D = 2, 64, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, D)) * 0.5
    want = _sdpa(q, k, v, causal=True, window=None)          # (B, S, H, D)
    got = ops.flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               atol=2e-5, rtol=2e-5)

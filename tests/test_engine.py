"""Discrete-event simulation kernel: typed events, the golden policy
matrix, event-log determinism, and policy-composition properties.

The refactor contract: the kernel (``repro.cluster.engine``) composed with
``CarbonScheduling`` / ``AutoscaleScheduling`` must reproduce the
pre-kernel engine's outputs *bitwise* for every policy combination
(policy-free, carbon-only, autoscale-only, carbon+autoscale) on every
backend — pinned against tests/golden_engine_scenarios.json, which was
recorded on the pre-refactor engine (scripts/record_engine_golden.py).
"""
import json
import os

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    def settings(*args, **kwargs):
        def wrap(f):
            return f
        return wrap

    def given(*args, **kwargs):
        def wrap(f):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from engine_golden_spec import (SCENARIOS, arrivals, fleet, make_autoscale,
                                make_carbon, run_cell)
from repro.core.carbon import CarbonScheduling
from repro.core.elastic import AutoscaleScheduling
from repro.core.policy import (ARRIVAL, CARBON_CHECK, COMPLETION,
                               CONSOLIDATE_TICK, EVENT_KINDS, WAKE_DONE,
                               Event, SchedulingPolicy)
from repro.cluster.engine import RunningTask, simulate
from repro.cluster.workload import WORKLOADS, Pod

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__),
                                     "golden_engine_scenarios.json")))


# --- golden policy matrix: bitwise reproduction of the pre-kernel engine -----
@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_policy_matrix_bitwise(name, backend):
    """Every (policy combination x backend) cell reproduces the recorded
    pre-refactor output exactly: placements, start/runtimes, energy and
    carbon totals, and every event counter."""
    res = run_cell(name, backend)
    g = GOLDEN["runs"][f"{name}/{backend}"]
    assert [r.node for r in res.records] == g["nodes"]
    assert [r.pod.uid for r in res.records] == g["uids"]
    assert [r.start_s for r in res.records] == g["start_s"]
    assert [r.runtime_s for r in res.records] == g["runtime_s"]
    assert res.energy_kj("topsis") == g["energy_topsis_kj"]
    assert res.energy_kj("default") == g["energy_default_kj"]
    assert res.unschedulable == g["unschedulable"]
    assert res.preemptions == g["preemptions"]
    assert res.migrations == g["migrations"]
    assert res.wakes == g["wakes"]
    assert res.sleeps == g["sleeps"]
    if SCENARIOS[name]["carbon"]:
        assert res.total_carbon_g("topsis") == g["carbon_topsis_g"]
        assert (res.mean_deferral_latency_s("topsis")
                == g["mean_deferral_latency_s"])
    if SCENARIOS[name]["autoscale"]:
        assert res.fleet_idle_energy_kj() == g["fleet_idle_energy_kj"]
        assert res.state_energy_kj() == g["state_energy_kj"]


# --- typed events ------------------------------------------------------------
def test_event_tie_break_order():
    """At one instant: COMPLETION before ARRIVAL before wake-like — the
    kernel's clock-advance precedence, encoded in Event ordering."""
    c = Event.make(5.0, COMPLETION)
    a = Event.make(5.0, ARRIVAL)
    w = Event.make(5.0, CARBON_CHECK)
    assert c < a < w
    assert min([w, a, c]) is c
    # time dominates priority
    assert Event.make(4.0, WAKE_DONE) < c
    assert Event.make(5.0, CONSOLIDATE_TICK) > a
    # payload never participates in ordering
    assert Event.make(1.0, COMPLETION, "x") == Event.make(1.0, COMPLETION, "y")


def test_running_task_heap_order():
    """RunningTask orders by (end_s, uid) exactly like the legacy bare
    tuples — pods and indices never compare."""
    p0 = Pod(0, WORKLOADS["light"], "topsis")
    p1 = Pod(1, WORKLOADS["light"], "topsis")
    a = RunningTask(10.0, 1, p1, 0, 0, 0)
    b = RunningTask(10.0, 0, p0, 5, 9, 9)
    c = RunningTask(9.0, 7, p1, 0, 0, 0)
    assert sorted([a, b, c]) == [c, b, a]


# --- event-log determinism ---------------------------------------------------
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_event_log_deterministic(name):
    """A fixed scenario replays to the identical processed-event log —
    same kinds, same instants, same payloads, in the same order."""
    a = run_cell(name, "numpy")
    b = run_cell(name, "numpy")
    assert a.events is not None and len(a.events) > 0
    assert a.events == b.events
    assert {kind for _, kind, _ in a.events} <= set(EVENT_KINDS)
    # every arrival burst and every completion shows up
    n_arrivals = sum(1 for _, kind, _ in a.events if kind == ARRIVAL)
    assert n_arrivals == 3                       # one per Poisson burst
    completions = [payload for _, kind, payload in a.events
                   if kind == COMPLETION]
    assert set(completions) == {r.pod.uid for r in a.records}


def test_event_log_policy_kinds_present():
    """The carbon+autoscale cell exercises the policy event kinds: carbon
    checks fire while pods defer, consolidation ticks while tasks run."""
    res = run_cell("carbon_autoscale", "numpy")
    kinds = {kind for _, kind, _ in res.events}
    assert CARBON_CHECK in kinds
    assert CONSOLIDATE_TICK in kinds


# --- policy composition ------------------------------------------------------
def _both_orders(seed_a: int, seed_f: int, backend: str = "numpy"):
    out = []
    for order in ((CarbonScheduling, AutoscaleScheduling),
                  (AutoscaleScheduling, CarbonScheduling)):
        policies = [cls(make_carbon()) if cls is CarbonScheduling
                    else cls(make_autoscale()) for cls in order]
        out.append(simulate(arrivals(True, seed=seed_a), "energy_centric",
                            cluster_factory=fleet(seed_f), batch=True,
                            batch_backend=backend, policies=policies))
    return out


def test_policy_order_invariant_on_recorded_scenario():
    """[carbon, autoscale] and [autoscale, carbon] place the golden
    scenario identically (and match the recorded golden)."""
    ab, ba = _both_orders(7, 3)
    g = GOLDEN["runs"]["carbon_autoscale/numpy"]
    for res in (ab, ba):
        assert [r.node for r in res.records] == g["nodes"]
        assert [r.start_s for r in res.records] == g["start_s"]
        assert res.energy_kj("topsis") == g["energy_topsis_kj"]


@settings(max_examples=5, deadline=None)
@given(seed_a=st.integers(0, 2 ** 31 - 1), seed_f=st.integers(0, 100))
def test_property_policy_composition_order_invariant(seed_a, seed_f):
    """Property: composing [carbon, autoscale] vs [autoscale, carbon]
    yields identical placements, starts, energies, and counters on
    recorded Poisson scenarios."""
    ab, ba = _both_orders(seed_a, seed_f)
    assert [r.node for r in ab.records] == [r.node for r in ba.records]
    assert ([r.start_s for r in ab.records]
            == [r.start_s for r in ba.records])
    for s in ("topsis", "default"):
        assert ab.energy_kj(s) == ba.energy_kj(s)
    assert ab.unschedulable == ba.unschedulable
    assert (ab.preemptions, ab.migrations, ab.wakes, ab.sleeps) \
        == (ba.preemptions, ba.migrations, ba.wakes, ba.sleeps)
    assert ab.fleet_idle_energy_kj() == ba.fleet_idle_energy_kj()


def test_noop_policy_is_bitwise_inert():
    """A policy that overrides nothing composes with the kernel as a pure
    no-op: same placements and energies as the policy-free run."""
    ref = simulate(arrivals(False), "energy_centric",
                   cluster_factory=fleet(), batch=True,
                   batch_backend="numpy")
    res = simulate(arrivals(False), "energy_centric",
                   cluster_factory=fleet(), batch=True,
                   batch_backend="numpy", policies=[SchedulingPolicy()])
    assert [r.node for r in res.records] == [r.node for r in ref.records]
    assert [r.start_s for r in res.records] == [r.start_s for r in ref.records]
    for s in ("topsis", "default"):
        assert res.energy_kj(s) == ref.energy_kj(s)
    assert res.events == ref.events


# --- SimResult.summary -------------------------------------------------------
def test_summary_matches_handrolled_metrics():
    """summary() returns exactly the per-scheduler metrics the sweeps
    hand-roll from individual SimResult calls."""
    res = run_cell("carbon_autoscale", "numpy")
    s = res.summary()
    assert s["pods"] == len({r.pod.uid for r in res.records}) \
        + res.unschedulable
    assert s["unschedulable_rate"] == res.unschedulable_rate()
    assert s["preemptions"] == res.preemptions
    assert s["migrations"] == res.migrations
    assert s["wakes"] == res.wakes and s["sleeps"] == res.sleeps
    assert set(s["schedulers"]) == {r.pod.scheduler for r in res.records}
    for name, m in s["schedulers"].items():
        assert m["energy_kj"] == res.energy_kj(name)
        assert m["mean_energy_kj"] == res.mean_energy_kj(name)
        assert m["mean_sched_time_ms"] == res.mean_sched_time_ms(name)
        assert m["mean_exec_time_s"] == res.mean_exec_time_s(name)
        assert m["allocation"] == res.allocation(name)
        assert m["pods"] == len({r.pod.uid for r in res.records
                                 if r.pod.scheduler == name})

"""TOPSIS engine: unit + property tests (paper's core contribution).

The property-based block needs ``hypothesis`` (requirements-dev.txt); when
it is absent those tests skip with a clear reason and the unit tests still
run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ModuleNotFoundError:
    # Degrade gracefully: stand-in decorators collect each property test as
    # a no-arg test that skips at runtime (mirrors @given consuming the
    # function's parameters, so pytest never looks for fixtures).
    def settings(*args, **kwargs):
        def wrap(f):
            return f
        return wrap

    def given(*args, **kwargs):
        def wrap(f):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = hnp = _AnyStrategy()

from repro.core.topsis import (closeness, closeness_np, normalize_matrix,
                               ideal_points, select)

BENEFIT5 = np.array([False, False, True, True, True])  # paper's 5 criteria


def rand_matrix(n, c, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.1, 10.0, (n, c))


# --- unit: hand-checked example -------------------------------------------------
def test_known_example():
    # 2 alternatives, 1 benefit criterion: higher value must win
    M = np.array([[1.0], [3.0]])
    r = closeness_np(M, np.array([1.0]), np.array([True]))
    assert r.ranking[0] == 1
    assert r.closeness[1] > r.closeness[0]
    # cost criterion flips it
    r = closeness_np(M, np.array([1.0]), np.array([False]))
    assert r.ranking[0] == 0


def test_ideal_points_directions():
    M = jnp.asarray(rand_matrix(6, 5))
    v = normalize_matrix(M)
    a_pos, a_neg = ideal_points(v, jnp.asarray(BENEFIT5))
    # benefit columns: ideal is max; cost columns: ideal is min
    np.testing.assert_allclose(a_pos[2:], v[:, 2:].max(0), rtol=1e-6)
    np.testing.assert_allclose(a_pos[:2], v[:, :2].min(0), rtol=1e-6)
    np.testing.assert_allclose(a_neg[2:], v[:, 2:].min(0), rtol=1e-6)
    np.testing.assert_allclose(a_neg[:2], v[:, :2].max(0), rtol=1e-6)


# --- property tests --------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(hnp.arrays(np.float64, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=2, max_side=32),
                  elements=st.floats(0.01, 1e4)),
       st.integers(0, 2 ** 31 - 1))
def test_closeness_in_unit_interval(M, wseed):
    c = M.shape[1]
    rng = np.random.default_rng(wseed)
    w = rng.uniform(0.01, 1.0, c)
    benefit = rng.uniform(size=c) < 0.5
    r = closeness_np(M, w, benefit)
    assert np.all(r.closeness >= -1e-12) and np.all(r.closeness <= 1 + 1e-12)
    assert np.all(np.isfinite(r.closeness))


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 40), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_jnp_np_equivalence(n, c, seed):
    rng = np.random.default_rng(seed)
    M = rng.uniform(0.01, 100.0, (n, c))
    w = rng.uniform(0.01, 1.0, c)
    benefit = rng.uniform(size=c) < 0.5
    r_np = closeness_np(M, w, benefit)
    r_j = closeness(jnp.asarray(M), jnp.asarray(w), jnp.asarray(benefit))
    np.testing.assert_allclose(r_np.closeness, np.asarray(r_j.closeness),
                               atol=1e-5)


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 20), st.integers(0, 2 ** 31 - 1),
       st.floats(0.1, 100.0))
def test_scale_invariance(n, seed, scale):
    """Multiplying a criterion column by a positive constant must not change
    the ranking (vector normalization property)."""
    rng = np.random.default_rng(seed)
    M = rng.uniform(0.1, 10.0, (n, 5))
    w = rng.uniform(0.1, 1.0, 5)
    r1 = closeness_np(M, w, BENEFIT5)
    M2 = M.copy()
    M2[:, 3] *= scale
    r2 = closeness_np(M2, w, BENEFIT5)
    np.testing.assert_allclose(r1.closeness, r2.closeness, atol=1e-8)


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 20), st.integers(0, 2 ** 31 - 1))
def test_dominant_alternative_wins(n, seed):
    """An alternative strictly better on every criterion must rank first."""
    rng = np.random.default_rng(seed)
    M = rng.uniform(1.0, 5.0, (n, 5))
    M[0, :2] = 0.5          # strictly lowest cost
    M[0, 2:] = 6.0          # strictly highest benefit
    w = rng.uniform(0.1, 1.0, 5)
    r = closeness_np(M, w, BENEFIT5)
    assert r.ranking[0] == 0


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
def test_permutation_equivariance(n, seed):
    rng = np.random.default_rng(seed)
    M = rng.uniform(0.1, 10.0, (n, 5))
    w = rng.uniform(0.1, 1.0, 5)
    perm = rng.permutation(n)
    r1 = closeness_np(M, w, BENEFIT5)
    r2 = closeness_np(M[perm], w, BENEFIT5)
    np.testing.assert_allclose(r1.closeness[perm], r2.closeness, atol=1e-9)


@settings(max_examples=50, deadline=None)
@given(st.integers(3, 16), st.integers(0, 2 ** 31 - 1))
def test_invalid_rows_never_selected(n, seed):
    rng = np.random.default_rng(seed)
    M = rng.uniform(0.1, 10.0, (n, 5))
    w = rng.uniform(0.1, 1.0, 5)
    valid = rng.uniform(size=n) < 0.5
    valid[rng.integers(n)] = True          # at least one feasible
    r = closeness_np(M, w, BENEFIT5, valid=valid)
    assert valid[r.ranking[0]]
    assert np.all(np.isneginf(r.closeness[~valid]))


def test_weight_shift_changes_winner():
    """Putting all weight on a criterion makes its best alternative win."""
    M = np.array([
        [1.0, 5.0, 1.0, 1.0, 1.0],     # cheapest on criterion 0 (cost)
        [5.0, 1.0, 1.0, 1.0, 1.0],     # cheapest on criterion 1 (cost)
    ])
    w0 = np.array([1.0, 1e-9, 1e-9, 1e-9, 1e-9])
    w1 = np.array([1e-9, 1.0, 1e-9, 1e-9, 1e-9])
    assert closeness_np(M, w0, BENEFIT5).ranking[0] == 0
    assert closeness_np(M, w1, BENEFIT5).ranking[0] == 1


def test_degenerate_all_equal():
    M = np.ones((4, 5))
    r = closeness_np(M, np.ones(5), BENEFIT5)
    assert np.all(np.isfinite(r.closeness))
    np.testing.assert_allclose(r.closeness, 0.5, atol=1e-9)


def test_select_jit():
    M = jnp.asarray(rand_matrix(8, 5, 1))
    w = jnp.ones(5)
    i = jax.jit(select)(M, w, jnp.asarray(BENEFIT5))
    assert 0 <= int(i) < 8

"""Flight-recorder tests: the pure-observer invariant, the registry
primitives, the exporters, and TOPSIS decision explainability.

The load-bearing half is the golden matrix: every recorded scenario cell
(tests/golden_engine_scenarios.json, tests/golden_table6.json) must
reproduce **bitwise with telemetry enabled** — recording is write-only
from the simulation's point of view, so turning the flight recorder on
can never change a placement, an energy total, or an event counter.
"""
import json
import math
import os

import numpy as np
import pytest

from engine_golden_spec import SCENARIOS, arrivals, fleet, run_cell
from repro.core import telemetry
from repro.core.telemetry import (DEFAULT_LATENCY_BUCKETS, Histogram,
                                  Telemetry, log_buckets)
from repro.core.topsis import closeness_np, explain_np
from repro.telemetry.export import (json_snapshot, parse_prometheus,
                                   perfetto_trace, prometheus_text,
                                   validate_trace, write_perfetto)
from repro.cluster.simulator import run_scenario, table6

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__),
                                     "golden_engine_scenarios.json")))
GOLDEN_T6 = json.load(open(os.path.join(os.path.dirname(__file__),
                                        "golden_table6.json")))


@pytest.fixture(autouse=True)
def _clean_registry():
    """Never leak an active registry into (or out of) a test."""
    telemetry.disable()
    yield
    telemetry.disable()


# --- the pure-observer invariant: golden runs, recording on ------------------
@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_matrix_bitwise_with_telemetry(name, backend):
    """Every (policy combination x backend) golden cell reproduces the
    recorded output exactly with the flight recorder on — and the
    recorder demonstrably recorded (so this isn't vacuously passing)."""
    with telemetry.enabled() as tel:
        res = run_cell(name, backend)
    g = GOLDEN["runs"][f"{name}/{backend}"]
    assert [r.node for r in res.records] == g["nodes"]
    assert [r.pod.uid for r in res.records] == g["uids"]
    assert [r.start_s for r in res.records] == g["start_s"]
    assert [r.runtime_s for r in res.records] == g["runtime_s"]
    assert res.energy_kj("topsis") == g["energy_topsis_kj"]
    assert res.energy_kj("default") == g["energy_default_kj"]
    assert res.unschedulable == g["unschedulable"]
    assert res.preemptions == g["preemptions"]
    assert res.migrations == g["migrations"]
    assert res.wakes == g["wakes"]
    assert res.sleeps == g["sleeps"]
    if SCENARIOS[name]["carbon"]:
        assert res.total_carbon_g("topsis") == g["carbon_topsis_g"]
        assert (res.mean_deferral_latency_s("topsis")
                == g["mean_deferral_latency_s"])
    if SCENARIOS[name]["autoscale"]:
        assert res.fleet_idle_energy_kj() == g["fleet_idle_energy_kj"]
        assert res.state_energy_kj() == g["state_energy_kj"]
    # the recorder saw the run: kernel counters, round spans, decision
    # latency histograms, energy rollups
    assert tel.counter_value("engine_events", kind="arrival") > 0
    assert tel.counter_value("engine_events", kind="completion") > 0
    assert any(s["name"] == "engine_round" for s in tel.spans)
    assert any(h.name in ("scheduler_decision_seconds",
                          "scheduler_batch_seconds") and h.count > 0
               for h in tel.histograms.values())
    assert any(g_.name == "fleet_energy_kj" for g_ in tel.gauges.values())


def test_golden_table6_bitwise_with_telemetry():
    """The paper-mode factorial (Table VI) reproduces its golden with the
    flight recorder on."""
    with telemetry.enabled():
        t6 = table6()
    for level, d in GOLDEN_T6["table6"].items():
        for scheme, row in d.items():
            for key, want in row.items():
                got = t6[level][scheme][key]
                assert abs(got - want) < 1e-9, (level, scheme, key)


def test_telemetry_scoped_enable_restores_null():
    with telemetry.enabled() as tel:
        assert telemetry.active() is tel
    assert telemetry.active() is telemetry.NULL
    assert not telemetry.active().enabled


# --- histogram bucket math ---------------------------------------------------
def test_log_buckets_exact_powers():
    edges = log_buckets(1e-6, 10.0, per_decade=4)
    assert edges == tuple(10.0 ** (k / 4) for k in range(-24, 5))
    assert DEFAULT_LATENCY_BUCKETS == edges
    assert edges[0] == 1e-6 and edges[-1] == 10.0
    # two registries configured alike agree bitwise on boundaries
    assert log_buckets(1e-6, 10.0, per_decade=4) == edges
    with pytest.raises(ValueError):
        log_buckets(1.0, 1.0)
    with pytest.raises(ValueError):
        log_buckets(1e-3, 1.0, per_decade=0)


def test_histogram_le_semantics():
    h = Histogram("h", edges=(1.0, 10.0, 100.0))
    for v in (0.5, 1.0, 1.0000001, 10.0, 150.0):
        h.observe(v)
    # le semantics: a value equal to an edge lands in that bucket
    assert h.counts == [2, 2, 0, 1]
    assert h.cumulative() == [2, 4, 4, 5]
    assert h.count == 5
    assert h.sum == pytest.approx(0.5 + 1.0 + 1.0000001 + 10.0 + 150.0)
    assert h.min == 0.5 and h.max == 150.0
    snap = h.snapshot()
    assert snap["counts"] == [2, 2, 0, 1] and snap["count"] == 5
    with pytest.raises(ValueError):
        Histogram("bad", edges=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        Histogram("bad", edges=(2.0, 1.0))


def test_registry_counters_gauges_spans():
    tel = Telemetry()
    tel.inc("c", kind="a")
    tel.inc("c", value=2.0, kind="a")
    tel.inc("c", kind="b")
    assert tel.counter_value("c", kind="a") == 3.0
    assert tel.counter_value("c", kind="b") == 1.0
    assert tel.counter_value("missing") == 0.0
    tel.set_gauge("g", 5.0)
    tel.set_gauge("g", 2.0)
    g = tel.gauges[("g", ())]
    assert (g.value, g.min, g.max, g.samples) == (2.0, 2.0, 5.0, 2)
    with tel.span("outer") as outer:
        with tel.span("inner") as inner:
            pass
    assert outer.duration_s >= inner.duration_s >= 0.0
    assert [s["name"] for s in tel.spans] == ["inner", "outer"]
    assert [s["depth"] for s in tel.spans] == [1, 0]
    assert tel.histogram("outer_seconds").count == 1
    snap = json_snapshot(tel, include_spans=True)
    assert snap["spans"] == 2 and len(snap["span_log"]) == 2
    assert {c["name"] for c in snap["counters"]} == {"c"}


def test_null_telemetry_span_still_times():
    """The disabled default records nothing, but its spans still time —
    PodRecord.scheduling_time_s depends on this single code path."""
    null = telemetry.NULL
    with null.span("x") as sp:
        acc = sum(range(1000))
    assert acc == 499500
    assert sp.duration_s > 0.0


# --- Prometheus exposition round-trip ----------------------------------------
def test_prometheus_round_trip():
    tel = Telemetry(latency_buckets=(1e-3, 1e-2, 1e-1))
    tel.inc("engine_events", value=7.0, kind="arrival")
    tel.inc("engine_events", value=3.0, kind="completion")
    tel.set_gauge("engine_pending_depth", 12.0)
    for v in (5e-4, 5e-3, 5e-2, 5.0):
        tel.observe("lat_seconds", v, backend="numpy")
    text = prometheus_text(tel)
    # one TYPE line per metric name, declared before its samples
    assert text.count("# TYPE engine_events counter") == 1
    assert "# TYPE engine_pending_depth gauge" in text
    assert "# TYPE lat_seconds histogram" in text
    parsed = parse_prometheus(text)
    assert parsed[("engine_events", (("kind", "arrival"),))] == 7.0
    assert parsed[("engine_events", (("kind", "completion"),))] == 3.0
    assert parsed[("engine_pending_depth", ())] == 12.0
    h = tel.histogram("lat_seconds", backend="numpy")
    cum = h.cumulative()
    for edge, want in zip(h.edges, cum):
        key = ("lat_seconds_bucket",
               tuple(sorted({"backend": "numpy", "le": repr(edge)}.items())))
        assert parsed[key] == want
    inf_key = ("lat_seconds_bucket",
               tuple(sorted({"backend": "numpy", "le": "+Inf"}.items())))
    assert parsed[inf_key] == cum[-1] == 4
    assert parsed[("lat_seconds_sum", (("backend", "numpy"),))] == h.sum
    assert parsed[("lat_seconds_count", (("backend", "numpy"),))] == 4


def test_prometheus_label_escaping_round_trips():
    tel = Telemetry()
    nasty = 'a"b\\c\nd'
    tel.inc("c", value=1.5, node=nasty)
    parsed = parse_prometheus(prometheus_text(tel))
    assert parsed[("c", (("node", nasty),))] == 1.5


def test_prometheus_rejects_bad_metric_name():
    tel = Telemetry()
    tel.inc("bad-name")
    with pytest.raises(ValueError):
        prometheus_text(tel)


# --- Perfetto / Chrome trace export ------------------------------------------
def test_perfetto_trace_valid_and_complete(tmp_path):
    res = run_cell("carbon_autoscale", "numpy")
    trace = perfetto_trace(res, trace_name="golden carbon_autoscale")
    stats = validate_trace(trace)
    assert stats["spans"] > 0          # task + power-state intervals
    assert stats["instants"] > 0       # policy events + wake surges
    names = {ev["args"]["name"] for ev in trace["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert "policies" in names
    assert any(n.startswith("node ") for n in names)
    cats = {ev.get("cat") for ev in trace["traceEvents"] if ev["ph"] != "M"}
    assert {"task", "state", "event"} <= cats
    # every scheduled record shows up as exactly one task span
    task_b = [ev for ev in trace["traceEvents"]
              if ev["ph"] == "B" and ev.get("cat") == "task"]
    assert len(task_b) == sum(1 for r in res.records if r.runtime_s > 0.0)
    path = write_perfetto(res, tmp_path / "run.trace.json")
    reloaded = json.load(open(path))
    assert validate_trace(reloaded) == stats


def test_validate_trace_catches_violations():
    ok = [{"ph": "B", "ts": 0.0, "pid": 1, "tid": 1, "name": "x"},
          {"ph": "E", "ts": 2.0, "pid": 1, "tid": 1, "name": "x"}]
    assert validate_trace(ok)["spans"] == 1
    with pytest.raises(ValueError, match="unknown phase"):
        validate_trace([{"ph": "Q", "ts": 0.0}])
    with pytest.raises(ValueError, match="not sorted"):
        validate_trace([{"ph": "i", "ts": 5.0, "pid": 1, "tid": 1},
                        {"ph": "i", "ts": 1.0, "pid": 1, "tid": 1}])
    with pytest.raises(ValueError, match="no open B"):
        validate_trace([{"ph": "E", "ts": 0.0, "pid": 1, "tid": 1,
                         "name": "x"}])
    with pytest.raises(ValueError, match="does not match"):
        validate_trace([{"ph": "B", "ts": 0.0, "pid": 1, "tid": 1,
                         "name": "x"},
                        {"ph": "E", "ts": 1.0, "pid": 1, "tid": 1,
                         "name": "y"}])
    with pytest.raises(ValueError, match="unclosed"):
        validate_trace([{"ph": "B", "ts": 0.0, "pid": 1, "tid": 1,
                         "name": "x"}])
    with pytest.raises(ValueError, match="bad ts"):
        validate_trace([{"ph": "i", "ts": -1.0, "pid": 1, "tid": 1}])


# --- TOPSIS decision explainability ------------------------------------------
def _toy_decision():
    rng = np.random.default_rng(11)
    matrix = rng.uniform(0.1, 1.0, size=(6, 4))
    weights = np.array([0.4, 0.3, 0.2, 0.1])
    benefit = np.array([True, False, True, False])
    return matrix, weights, benefit


def test_explain_np_contributions_sum_to_gap():
    matrix, weights, benefit = _toy_decision()
    exp = explain_np(matrix, weights, benefit,
                     criteria_names=["cpu", "mem", "eff", "carbon"])
    res = closeness_np(matrix, weights, benefit)
    assert exp["winner"] == int(np.argmax(res.closeness))
    assert exp["runner_up"] != exp["winner"]
    assert exp["gap"] == pytest.approx(
        exp["closeness_winner"] - exp["closeness_runner_up"], abs=0.0)
    total = sum(c["delta_cc"] for c in exp["contributions"])
    assert total == pytest.approx(exp["gap"], abs=1e-12)
    assert [c["criterion"] for c in exp["contributions"]] == [
        "cpu", "mem", "eff", "carbon"]
    for j, c in enumerate(exp["contributions"]):
        assert c["winner_value"] == matrix[exp["winner"], j]
        assert c["runner_up_value"] == matrix[exp["runner_up"], j]


def test_explain_np_single_feasible_row():
    matrix, weights, benefit = _toy_decision()
    valid = np.zeros(matrix.shape[0], dtype=bool)
    valid[2] = True
    exp = explain_np(matrix, weights, benefit, valid)
    assert exp["winner"] == 2
    assert exp["runner_up"] is None and exp["contributions"] == []


def test_run_scenario_explain_records_attributions():
    res = run_scenario(arrivals(False), "energy_centric",
                       cluster_factory=fleet(), batch=True,
                       batch_backend="numpy", explain=True)
    assert res.explanations
    for exp in res.explanations:
        assert exp["node"] is not None
        assert exp["pod"]
        if exp["runner_up"] is not None:
            total = sum(c["delta_cc"] for c in exp["contributions"])
            assert total == pytest.approx(exp["gap"], abs=1e-9)
    assert "explanations" in res.summary()


def test_explain_does_not_change_placements():
    plain = run_scenario(arrivals(False), "energy_centric",
                         cluster_factory=fleet(), batch=True,
                         batch_backend="numpy")
    explained = run_scenario(arrivals(False), "energy_centric",
                             cluster_factory=fleet(), batch=True,
                             batch_backend="numpy", explain=True)
    assert ([r.node for r in plain.records]
            == [r.node for r in explained.records])
    assert plain.energy_kj("topsis") == explained.energy_kj("topsis")


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_explain_rejects_accelerated_backends(backend):
    with pytest.raises(ValueError, match="numpy"):
        run_scenario(arrivals(False), "energy_centric",
                     cluster_factory=fleet(), batch=True,
                     batch_backend=backend, explain=True)


# --- benchmark provenance ----------------------------------------------------
def test_write_report_stamps_provenance():
    from benchmarks.common import write_report
    rep = write_report({"bench": "x", "results": []}, out=None)
    prov = rep["provenance"]
    for key in ("platform", "python", "git_sha", "utc_timestamp",
                "jax_version"):
        assert key in prov
    assert prov["python"].count(".") == 2
    # an explicit provenance block is preserved, not overwritten
    rep2 = write_report({"provenance": {"pinned": True}}, out=None)
    assert rep2["provenance"] == {"pinned": True}


# --- gauge min/max/samples envelopes in the exposition -----------------------
def test_prometheus_gauge_envelope_round_trip():
    tel = Telemetry()
    for v in (3.0, -1.0, 7.0):
        tel.set_gauge("engine_pending_depth", v, policy="fifo")
    text = prometheus_text(tel)
    for suffix in ("_min", "_max", "_samples"):
        assert f"# TYPE engine_pending_depth{suffix} gauge" in text
    parsed = parse_prometheus(text)
    labels = (("policy", "fifo"),)
    assert parsed[("engine_pending_depth", labels)] == 7.0
    assert parsed[("engine_pending_depth_min", labels)] == -1.0
    assert parsed[("engine_pending_depth_max", labels)] == 7.0
    assert parsed[("engine_pending_depth_samples", labels)] == 3.0
    # an unset gauge family emits no envelope series
    assert "_min" not in prometheus_text(Telemetry())


# --- Perfetto counter ("C") events -------------------------------------------
def test_perfetto_counter_tracks_from_registry(tmp_path):
    with telemetry.enabled() as tel:
        res = run_cell("carbon_autoscale", "numpy")
    trace = perfetto_trace(res, tel=tel)
    stats = validate_trace(trace)
    assert stats["counters"] > 0
    c_names = {ev["name"] for ev in trace["traceEvents"]
               if ev["ph"] == "C"}
    assert "fleet_power_w" in c_names
    assert "engine_pending_depth" in c_names
    assert any(n.startswith("fleet_carbon_cum_g") for n in c_names)
    proc_names = {ev["args"]["name"] for ev in trace["traceEvents"]
                  if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert "counters" in proc_names
    # without a registry the ledger-derived power counter still exists
    trace2 = perfetto_trace(res)
    c2 = {ev["name"] for ev in trace2["traceEvents"] if ev["ph"] == "C"}
    assert "fleet_power_w" in c2
    assert validate_trace(trace2)["counters"] > 0
    path = write_perfetto(res, tmp_path / "run.trace.json", tel=tel)
    assert validate_trace(json.load(open(path))) == stats


def test_validate_trace_counter_violations():
    ok = [{"ph": "C", "ts": 0.0, "pid": 9, "tid": 0, "name": "p",
           "args": {"value": 1.5}},
          {"ph": "C", "ts": 1.0, "pid": 9, "tid": 0, "name": "p",
           "args": {"value": 2.0}}]
    assert validate_trace(ok)["counters"] == 2
    with pytest.raises(ValueError, match="no args"):
        validate_trace([{"ph": "C", "ts": 0.0, "pid": 9, "tid": 0,
                         "name": "p", "args": {}}])
    with pytest.raises(ValueError, match="finite number"):
        validate_trace([{"ph": "C", "ts": 0.0, "pid": 9, "tid": 0,
                         "name": "p", "args": {"value": "fast"}}])
    with pytest.raises(ValueError, match="finite number"):
        validate_trace([{"ph": "C", "ts": 0.0, "pid": 9, "tid": 0,
                         "name": "p", "args": {"value": math.nan}}])
    # duplicate timestamp on one counter track: rejected; distinct
    # tracks at one instant: fine
    with pytest.raises(ValueError, match="strictly increasing"):
        validate_trace([{"ph": "C", "ts": 1.0, "pid": 9, "tid": 0,
                         "name": "p", "args": {"value": 1.0}},
                        {"ph": "C", "ts": 1.0, "pid": 9, "tid": 0,
                         "name": "p", "args": {"value": 2.0}}])
    two_tracks = [{"ph": "C", "ts": 1.0, "pid": 9, "tid": 0, "name": "p",
                   "args": {"value": 1.0}},
                  {"ph": "C", "ts": 1.0, "pid": 9, "tid": 0, "name": "q",
                   "args": {"value": 2.0}}]
    assert validate_trace(two_tracks)["counters"] == 2

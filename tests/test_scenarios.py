"""Event-driven scenario engine: golden paper-mode reproduction, arrival
processes, time-resolved energy accounting.

The golden fixture (tests/golden_table6.json) was recorded by running
``table6()`` and ``run_experiment`` on the pre-refactor legacy simulator
(post-hoc ``_union_length`` accounting, hand-rolled all-at-t0 loop). The
event-driven engine must reproduce it through ``PaperArrivals`` — same
placements, same energies — and the power-timeline accounting must match
the legacy idle+dynamic decomposition exactly.
"""
import json
import os

import numpy as np
import pytest

from repro.core.energy import (NODE_ENERGY_PROFILES, PowerTimeline,
                               merge_intervals, union_length)
from repro.cluster.node import (Node, SCENARIO_PROFILES,
                                make_scenario_cluster)
from repro.cluster.simulator import run_experiment, run_scenario, table6
from repro.cluster.workload import (PaperArrivals, PoissonArrivals,
                                    TraceArrivals, make_pods)

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__),
                                     "golden_table6.json")))


# --- golden paper-mode reproduction ------------------------------------------
def test_table6_matches_prerefactor_golden():
    """table6() through the event-driven engine == the recorded output of
    the pre-refactor legacy simulator, to float-roundoff."""
    t6 = table6()
    for level, d in GOLDEN["table6"].items():
        for scheme, vals in d.items():
            for key, want in vals.items():
                got = t6[level][scheme][key]
                assert abs(got - want) < 1e-9, (level, scheme, key, got, want)


@pytest.mark.parametrize("level", ["low", "medium", "high"])
def test_paper_mode_placements_match_golden(level):
    res = run_experiment(level, "energy_centric")
    g = GOLDEN["placements"][level]
    assert [r.node for r in res.records] == g["nodes"]
    assert abs(res.energy_kj("topsis") - g["energy_topsis_kj"]) < 1e-9
    assert abs(res.energy_kj("default") - g["energy_default_kj"]) < 1e-9


def test_run_experiment_is_paper_arrivals_scenario():
    """run_experiment is exactly PaperArrivals through run_scenario."""
    a = run_experiment("medium", "general")
    b = run_scenario(PaperArrivals("medium"), "general")
    assert [r.node for r in a.records] == [r.node for r in b.records]
    assert a.energy_kj("topsis") == b.energy_kj("topsis")


# --- energy conservation: timeline vs legacy decomposition -------------------
def _legacy_energy_kj(records, scheduler):
    """The pre-refactor SimResult.energy_kj: per-pod dynamic energy + idle
    power x union busy time per node (verbatim legacy arithmetic)."""
    dyn = sum(r.energy_j for r in records if r.pod.scheduler == scheduler)
    idle, by_node, classes = 0.0, {}, {}
    for r in records:
        if r.pod.scheduler == scheduler:
            by_node.setdefault(r.node, []).append(
                (r.start_s, r.start_s + r.runtime_s))
            classes[r.node] = r.node_class
    for node, ivs in by_node.items():
        idle += (NODE_ENERGY_PROFILES[classes[node]]["idle_power"]
                 * union_length(ivs))
    return (dyn + idle) / 1000.0


@pytest.mark.parametrize("level", ["low", "medium", "high"])
def test_timeline_reproduces_legacy_decomposition(level):
    """Timeline idle+dynamic accounting == legacy union-of-intervals
    decomposition, exactly (1e-9), in paper mode."""
    res = run_experiment(level, "energy_centric")
    for scheduler in ("topsis", "default"):
        legacy = _legacy_energy_kj(res.records, scheduler)
        assert abs(res.energy_kj(scheduler) - legacy) < 1e-9
        # the decomposition itself also matches term by term
        dyn = sum(r.energy_j for r in res.records
                  if r.pod.scheduler == scheduler)
        assert abs(res.timeline.dynamic_energy_j(scheduler) - dyn) < 1e-9


def test_energy_series_integrates_to_scalar_total():
    res = run_experiment("medium", "energy_centric")
    for scheduler in ("topsis", "default", None):
        edges, joules = res.energy_series(scheduler)
        want = (res.timeline.dynamic_energy_j(scheduler)
                + res.timeline.idle_energy_j(scheduler))
        assert abs(joules[-1] - want) < 1e-6 * max(want, 1.0)
        assert np.all(np.diff(joules) >= -1e-9)          # cumulative
        assert np.all(np.diff(edges) > 0)
        _, watts = res.power_series(scheduler)
        assert len(watts) == len(edges) - 1
        assert np.all(watts >= -1e-9)


def test_dynamic_energy_invariant_across_arrival_processes():
    """Identical placements => identical dynamic energy, regardless of the
    arrival process that produced them: replaying the paper stream as a
    t=0 trace gives the same placements and the same dynamic energy sum."""
    for level in ("low", "medium"):
        trace = TraceArrivals([
            {"t": 0.0, "kind": p.workload.kind, "scheduler": p.scheduler}
            for p in make_pods(level)])
        a = run_experiment(level, "energy_centric")
        b = run_scenario(trace, "energy_centric")
        assert [r.node for r in a.records] == [r.node for r in b.records]
        for scheduler in ("topsis", "default"):
            assert (a.timeline.dynamic_energy_j(scheduler)
                    == b.timeline.dynamic_energy_j(scheduler))


# --- interval helpers --------------------------------------------------------
def test_merge_intervals_and_union_length():
    ivs = [(5.0, 7.0), (0.0, 2.0), (1.0, 3.0), (6.5, 6.6)]
    assert merge_intervals(ivs) == [(0.0, 3.0), (5.0, 7.0)]
    assert union_length(ivs) == 5.0
    assert union_length([]) == 0.0
    assert merge_intervals([]) == []


def test_power_timeline_direct():
    tl = PowerTimeline()
    tl.add("n0", "A", "topsis", 0.0, 10.0, 3.0)
    tl.add("n0", "A", "topsis", 5.0, 10.0, 2.0)   # overlaps -> one idle span
    idle = NODE_ENERGY_PROFILES["A"]["idle_power"]
    assert tl.dynamic_energy_j("topsis") == 3.0 * 10 + 2.0 * 10
    assert abs(tl.idle_energy_j("topsis") - idle * 15.0) < 1e-12
    edges, watts = tl.power_series("topsis")
    np.testing.assert_allclose(edges, [0.0, 5.0, 10.0, 15.0])
    np.testing.assert_allclose(watts, [3.0 + idle, 5.0 + idle, 2.0 + idle])
    assert tl.energy_kj("default") == 0.0


# --- Poisson scenarios -------------------------------------------------------
def test_poisson_scenario_end_to_end():
    """Poisson bursts on a mixed fleet: every pod accounted for, placements
    deterministic under the seed, no overcommit, energy invariants hold."""
    make_run = lambda: run_scenario(
        PoissonArrivals(rate_per_s=0.5, n_bursts=5, burst_size=4, seed=7),
        "energy_centric",
        cluster_factory=lambda: make_scenario_cluster("mixed", 16, seed=2),
        batch=True, batch_backend="numpy")
    res, res2 = make_run(), make_run()
    arrivals = PoissonArrivals(rate_per_s=0.5, n_bursts=5, burst_size=4,
                               seed=7)
    assert len(res.records) + res.unschedulable == arrivals.total_pods()
    assert res.unschedulable == 0
    # deterministic replay
    assert [r.node for r in res.records] == [r.node for r in res2.records]
    assert res.energy_kj("topsis") == res2.energy_kj("topsis")
    # starts at/after the pod's burst arrival
    arrival_t = {p.uid: t for t, pods in arrivals.events() for p in pods}
    for r in res.records:
        assert r.start_s >= arrival_t[r.pod.uid] - 1e-12
    # dynamic energy conserves: equals per-record sum, independent of timing
    for scheduler in ("topsis", "default"):
        dyn = sum(r.energy_j for r in res.records
                  if r.pod.scheduler == scheduler)
        assert abs(res.timeline.dynamic_energy_j(scheduler) - dyn) < 1e-9
    edges, joules = res.energy_series()
    assert np.all(np.diff(joules) >= -1e-9) and joules[-1] > 0


def test_poisson_events_sorted_and_seeded():
    a = PoissonArrivals(rate_per_s=1.0, n_bursts=8, burst_size=3, seed=1)
    evs = a.events()
    ts = [t for t, _ in evs]
    assert ts == sorted(ts) and len(evs) == 8
    assert all(len(pods) == 3 for _, pods in evs)
    uids = [p.uid for _, pods in evs for p in pods]
    assert len(set(uids)) == len(uids)                # unique across bursts
    assert [t for t, _ in a.events()] == ts           # regeneration is stable
    b = PoissonArrivals(rate_per_s=1.0, n_bursts=8, burst_size=3, seed=2)
    assert [t for t, _ in b.events()] != ts


def test_scenario_unschedulable_counted():
    """Pods that can never fit are counted once the cluster drains."""
    res = run_scenario(
        TraceArrivals([{"t": 0.0, "kind": "complex", "scheduler": "topsis",
                        "count": 1}]),
        "energy_centric",
        cluster_factory=lambda: [Node("tiny", "A", 0.1, 0.1)])
    assert res.unschedulable == 1 and not res.records
    assert res.unschedulable_rate() == 1.0


# --- trace scenarios ---------------------------------------------------------
def test_trace_from_file_replays(tmp_path):
    entries = [
        {"t": 0.0, "kind": "complex", "scheduler": "topsis", "count": 2},
        {"t": 40.0, "kind": "light", "scheduler": "default", "count": 3},
        {"t": 40.0, "kind": "medium", "scheduler": "topsis"},
    ]
    path = tmp_path / "trace.json"
    path.write_text(json.dumps(entries))
    run = lambda arr: run_scenario(arr, "energy_centric")
    a = run(TraceArrivals.from_file(str(path)))
    b = run(TraceArrivals(entries))
    assert [r.node for r in a.records] == [r.node for r in b.records]
    assert a.energy_kj("topsis") == b.energy_kj("topsis")
    assert len(a.records) == 6 and a.unschedulable == 0
    # the second burst starts at its trace time, not at t=0
    late = [r for r in a.records if r.pod.workload.kind != "complex"]
    assert all(r.start_s >= 40.0 for r in late)
    # time-resolved series spans both bursts
    edges, _ = a.energy_series()
    assert edges[0] == 0.0 and edges[-1] > 40.0


def test_trace_validates_entries():
    for bad in (
        [{"t": 0.0, "kind": "nope"}],                          # unknown kind
        [{"t": 0.0}],                                          # missing kind
        [{"t": 0.0, "kind": "light", "scheduler": "huh"}],
        [{"kind": "light"}],                                   # missing t
        [{"t": -1.0, "kind": "light"}],
        [{"t": float("nan"), "kind": "light"}],
        [{"t": float("inf"), "kind": "light"}],
        [{"t": "soon", "kind": "light"}],                      # non-numeric t
        [{"t": 0.0, "kind": "light", "count": 0}],             # non-positive
        [{"t": 0.0, "kind": "light", "count": -3}],
        [{"t": 0.0, "kind": "light", "count": 1.5}],           # non-integer
        [{"t": 0.0, "kind": "light", "count": "two"}],
        [{"t": 0.0, "kind": "light", "deadline_s": 0.0}],
        [{"t": 0.0, "kind": "light", "deadline_s": float("inf")}],
        ["not-a-dict"],
    ):
        with pytest.raises(ValueError):
            TraceArrivals(bad)
    # the error message names the offending entry and field
    with pytest.raises(ValueError, match="count.*positive integer"):
        TraceArrivals([{"t": 0.0, "kind": "light", "count": 0}])
    with pytest.raises(ValueError, match="unknown workload kind"):
        TraceArrivals([{"t": 0.0, "kind": "nope"}])
    # valid deferral fields round-trip into pods
    arr = TraceArrivals([{"t": 0.0, "kind": "light", "count": 2,
                          "deferrable": True, "deadline_s": 120.0}])
    (_, pods), = arr.events()
    assert len(pods) == 2
    assert all(p.deferrable and p.deadline_s == 120.0 for p in pods)


def test_arrival_exactly_at_completion_sees_freed_capacity():
    """A burst arriving at exactly a completion's end time schedules against
    the freed resources ([start, end) semantics): on a one-pod node the
    second pod starts at the tie instant instead of deferring."""
    one_node = lambda: [Node("solo", "B", vcpus=1.2, mem_gb=2.5)]
    first = run_scenario(
        TraceArrivals([{"t": 0.0, "kind": "complex", "scheduler": "topsis"}]),
        "energy_centric", cluster_factory=one_node)
    end_t = first.records[0].start_s + first.records[0].runtime_s
    res = run_scenario(
        TraceArrivals([
            {"t": 0.0, "kind": "complex", "scheduler": "topsis"},
            {"t": end_t, "kind": "complex", "scheduler": "topsis"}]),
        "energy_centric", cluster_factory=one_node)
    assert res.unschedulable == 0 and len(res.records) == 2
    assert res.records[1].start_s == end_t


# --- scenario fleets ---------------------------------------------------------
def test_make_scenario_cluster_profiles():
    for profile, mix in SCENARIO_PROFILES.items():
        nodes = make_scenario_cluster(profile, 512, seed=0)
        assert len(nodes) == 512
        # first four nodes: one per class (heterogeneity floor)
        assert [n.node_class for n in nodes[:4]] == list(mix)
        counts = {}
        for n in nodes:
            counts[n.node_class] = counts.get(n.node_class, 0) + 1
        if profile != "mixed":      # uniform mix has no dominant class
            dominant = max(mix, key=mix.get)
            assert counts[dominant] == max(counts.values())
    # deterministic in seed
    a = make_scenario_cluster("edge_heavy", 64, seed=3)
    b = make_scenario_cluster("edge_heavy", 64, seed=3)
    assert [(n.name, n.node_class, n.vcpus) for n in a] == \
           [(n.name, n.node_class, n.vcpus) for n in b]
    with pytest.raises(ValueError):
        make_scenario_cluster("nope", 8)
    with pytest.raises(ValueError):
        make_scenario_cluster("mixed", 2)


def test_scenario_batch_backends_agree():
    """numpy and jax batched backends place Poisson scenarios identically
    (the engine's burst path is backend-invariant)."""
    runs = {}
    for backend in ("numpy", "jax"):
        runs[backend] = run_scenario(
            PoissonArrivals(rate_per_s=0.3, n_bursts=4, burst_size=6, seed=5),
            "energy_centric",
            cluster_factory=lambda: make_scenario_cluster("cloud_heavy", 32,
                                                          seed=4),
            batch=True, batch_backend=backend)
    assert ([r.node for r in runs["numpy"].records]
            == [r.node for r in runs["jax"].records])
    assert abs(runs["numpy"].energy_kj("topsis")
               - runs["jax"].energy_kj("topsis")) < 1e-9

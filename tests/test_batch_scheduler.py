"""Fleet-scale batched scheduling path: equivalence vs the numpy reference.

Every batched backend (vectorized decision matrix, BatchScheduler backends,
the valid-masked Pallas wrapper) must match ``topsis.closeness_np`` within
1e-5 — including valid-masked rows, padded criteria (C < C_PAD), and the
degenerate all-equal matrix.

The property-based block (randomized fleets and pod queues via
``hypothesis``) needs ``hypothesis`` (requirements-dev.txt); when it is
absent those tests skip with a clear reason and the unit tests still run.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # Degrade gracefully: stand-in decorators collect each property test as
    # a no-arg test that skips at runtime (mirrors @given consuming the
    # function's parameters, so pytest never looks for fixtures).
    def settings(*args, **kwargs):
        def wrap(f):
            return f
        return wrap

    def given(*args, **kwargs):
        def wrap(f):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core import topsis
from repro.core.criteria import benefit_mask
from repro.core.scheduler import (BatchScheduler, GreenPodScheduler,
                                  decision_matrix, decision_matrix_batch)
from repro.core.weighting import SCHEME_NAMES
from repro.cluster.node import Node, NodeTable, make_fleet, make_paper_cluster
from repro.cluster.workload import WORKLOADS, Pod
from repro.kernels import ops

BENEFIT = benefit_mask()


def make_queue(p, seed=0):
    rng = np.random.default_rng(seed)
    kinds = list(WORKLOADS)
    return [Pod(i, WORKLOADS[kinds[int(rng.integers(len(kinds)))]], "topsis")
            for i in range(p)]


# --- vectorized decision matrix ----------------------------------------------
def test_node_table_matches_node_list():
    nodes = make_paper_cluster()
    nodes[1].bind(0.5, 1.0)
    table = NodeTable.from_nodes(nodes)
    np.testing.assert_array_equal(table.fits(0.5, 1.0),
                                  [n.fits(0.5, 1.0) for n in nodes])
    np.testing.assert_allclose(table.free_cpu,
                               [n.free_cpu for n in nodes])
    np.testing.assert_allclose(table.cpu_util,
                               [n.cpu_util for n in nodes])


def test_decision_matrix_batch_rows_match_single():
    """(P, N, 5) batch tensor row p == the single-pod (N, 5) matrix."""
    table = make_fleet(33, seed=1, utilization=0.4)
    pods = make_queue(5)
    batch = decision_matrix_batch(pods, table)
    assert batch.shape == (5, 33, 5)
    for i, p in enumerate(pods):
        np.testing.assert_allclose(batch[i], decision_matrix(p, table),
                                   rtol=0, atol=0)


# --- pallas wrapper with valid mask -----------------------------------------
@pytest.mark.parametrize("n,c", [(4, 5), (100, 3), (700, 5), (1000, 8)])
def test_pallas_valid_mask_matches_closeness_np(n, c):
    rng = np.random.default_rng(n * 7 + c)
    M = rng.uniform(0.1, 10.0, (n, c))
    w = rng.uniform(0.1, 1.0, c)
    benefit = rng.uniform(size=c) < 0.5
    valid = rng.uniform(size=n) < 0.6
    valid[rng.integers(n)] = True
    want = topsis.closeness_np(M, w, benefit, valid).closeness
    got = np.asarray(ops.topsis_closeness(M, w, benefit, valid=valid))
    np.testing.assert_allclose(got[valid], want[valid], atol=1e-5)
    assert np.all(np.isneginf(got[~valid]))


def test_pallas_batched_matches_closeness_np():
    rng = np.random.default_rng(3)
    p, n, c = 6, 300, 5
    mats = rng.uniform(0.1, 10.0, (p, n, c))
    ws = rng.uniform(0.1, 1.0, (p, c))
    valid = rng.uniform(size=(p, n)) < 0.7
    valid[:, 0] = True
    want = topsis.batched_closeness_np(mats, ws, BENEFIT, valid)
    got = np.asarray(ops.topsis_closeness_batched(mats, ws, BENEFIT,
                                                  valid=valid))
    np.testing.assert_allclose(got[valid], want[valid], atol=1e-5)
    assert np.all(np.isneginf(got[~valid]))


def test_pallas_degenerate_all_equal():
    M = np.ones((16, 5))
    got = np.asarray(ops.topsis_closeness(M, np.ones(5), BENEFIT))
    np.testing.assert_allclose(got, 0.5, atol=1e-6)
    batched = np.asarray(ops.topsis_closeness_batched(
        np.ones((3, 16, 5)), np.ones(5), BENEFIT))
    np.testing.assert_allclose(batched, 0.5, atol=1e-6)


# --- scheduler backends ------------------------------------------------------
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_scheduler_backend_matches_numpy(backend):
    """GreenPodScheduler closeness identical across backends (within 1e-5),
    same selected node."""
    table = make_fleet(200, seed=2, utilization=0.5)
    for pod in make_queue(4, seed=5):
        ref = GreenPodScheduler("energy_centric", backend="numpy")
        alt = GreenPodScheduler("energy_centric", backend=backend)
        i_ref, d_ref = ref.select(pod, table)
        i_alt, d_alt = alt.select(pod, table)
        finite = np.isfinite(d_ref["closeness"])
        np.testing.assert_allclose(d_alt["closeness"][finite],
                                   d_ref["closeness"][finite], atol=1e-5)
        assert i_ref == i_alt


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_batch_scheduler_scores_match_numpy(backend):
    pods = make_queue(8, seed=7)
    table = make_fleet(257, seed=4, utilization=0.4)   # non-pow2 N (padding)
    want = BatchScheduler("energy_centric",
                          backend="numpy").score_queue(pods, table)
    got = BatchScheduler("energy_centric",
                         backend=backend).score_queue(pods, table)
    finite = np.isfinite(want)
    np.testing.assert_array_equal(finite, np.isfinite(got))
    np.testing.assert_allclose(got[finite], want[finite], atol=1e-5)


@pytest.mark.parametrize("backend", ["jax"])
def test_batch_scheduler_assignments_match_numpy(backend):
    pods = make_queue(16, seed=11)
    table = make_fleet(64, seed=6, utilization=0.6)
    a_ref, _ = BatchScheduler("energy_centric",
                              backend="numpy").select_many(pods, table)
    a_alt, _ = BatchScheduler("energy_centric",
                              backend=backend).select_many(pods, table)
    assert a_ref == a_alt


def test_batch_scheduler_respects_capacity_ledger():
    """Greedy commit never overcommits a node within one burst, and the
    input table is not mutated."""
    nodes = make_paper_cluster()
    table = NodeTable.from_nodes(nodes)
    used0 = table.used_cpu.copy()
    pods = [Pod(i, WORKLOADS["complex"], "topsis") for i in range(12)]
    sched = BatchScheduler("energy_centric", backend="numpy")
    assignments, _ = sched.select_many(pods, table)
    np.testing.assert_array_equal(table.used_cpu, used0)
    cpu = np.zeros(len(table))
    mem = np.zeros(len(table))
    for pod, idx in zip(pods, assignments):
        if idx is None:
            continue
        cpu[idx] += pod.cpu
        mem[idx] += pod.mem
    assert np.all(cpu <= table.free_cpu + 1e-9)
    assert np.all(mem <= table.free_mem + 1e-9)
    # the queue exceeds the 4-node cluster: some pods must spill
    assert any(a is None for a in assignments)
    assert any(a is not None for a in assignments)


def test_batch_scheduler_infeasible_pod_unplaced():
    table = NodeTable.from_nodes(make_paper_cluster())
    big = Pod(0, WORKLOADS["complex"], "topsis")
    tiny = Pod(1, WORKLOADS["light"], "topsis")
    # saturate everything so 'big' can't fit anywhere
    table.used_cpu[:] = table.vcpus - table.reserved_cpu - 0.25
    table.used_mem[:] = table.mem_gb - table.reserved_mem - 0.6
    assignments, diag = BatchScheduler(
        "energy_centric", backend="numpy").select_many([big, tiny], table)
    assert assignments[0] is None
    assert assignments[1] is not None
    assert np.all(np.isneginf(diag["closeness"][0]))


# --- simulator batch mode ----------------------------------------------------
def test_simulator_batch_mode_schedules_all():
    from repro.cluster.simulator import run_experiment
    for level in ("low", "medium"):
        res = run_experiment(level, "energy_centric", batch=True,
                             batch_backend="numpy")
        assert res.unschedulable == 0
        n_expected = {"low": 8, "medium": 14}[level]
        assert len(res.records) == n_expected
        # both schedulers' pods all completed
        assert sum(1 for r in res.records
                   if r.pod.scheduler == "topsis") == n_expected // 2


def test_simulator_batch_jax_backend_runs():
    from repro.cluster.simulator import run_experiment
    res = run_experiment("low", "energy_centric", batch=True,
                         batch_backend="jax")
    assert res.unschedulable == 0 and len(res.records) == 8


# --- greedy capacity-ledger regressions --------------------------------------
def test_ledger_falls_through_to_next_ranked_node():
    """A pod whose top-ranked node was exhausted by an earlier queue entry
    must take its next-ranked *feasible* node, not drop out."""
    # b-small is the snapshot's top-ranked node for a complex pod under
    # energy_centric weights and fits exactly one (1.2 vcpu / 2.5 GB vs the
    # pod's 1.0 / 2.0 request); two identical pods contend for it.
    nodes = [Node("a-0", "A", vcpus=4, mem_gb=16),
             Node("b-small", "B", vcpus=1.2, mem_gb=2.5),
             Node("c-0", "C", vcpus=8, mem_gb=32)]
    table = NodeTable.from_nodes(nodes)
    pods = [Pod(0, WORKLOADS["complex"], "topsis"),
            Pod(1, WORKLOADS["complex"], "topsis")]
    sched = BatchScheduler("energy_centric", backend="numpy")
    assignments, diag = sched.select_many(pods, table)
    cc = diag["closeness"]
    top = int(np.argmax(cc[0]))
    # preconditions: both pods rank the one-pod node first on the snapshot
    assert top == 1 and int(np.argmax(cc[1])) == top
    assert assignments[0] == top
    # pod 1's top choice is ledger-exhausted: it takes its next-ranked node
    order = np.argsort(-cc[1], kind="stable")
    assert assignments[1] == int(order[1]) != top
    assert assignments[1] is not None


def test_ledger_neginf_break_does_not_skip_feasible_nodes():
    """-inf closeness marks snapshot-infeasible nodes; they sort after every
    finite entry (stable descending argsort), so the early break must never
    hide a finite-scored node that still has ledger capacity."""
    nodes = [Node("a-small", "A", vcpus=1.2, mem_gb=2.5),    # fits one
             Node("b-tiny", "B", vcpus=0.5, mem_gb=1.0),     # never fits
             Node("c-0", "C", vcpus=8, mem_gb=32)]
    table = NodeTable.from_nodes(nodes)
    pods = [Pod(i, WORKLOADS["complex"], "topsis") for i in range(3)]
    sched = BatchScheduler("energy_centric", backend="numpy")
    assignments, diag = sched.select_many(pods, table)
    cc = diag["closeness"]
    assert np.all(np.isneginf(cc[:, 1]))     # b-tiny snapshot-infeasible
    # every pod with any ledger-feasible finite-scored node got placed
    assert assignments == [0, 2, 2]
    # and an exhausted queue leaves later pods unplaced, not misplaced:
    many = [Pod(i, WORKLOADS["complex"], "topsis") for i in range(12)]
    assignments, diag = sched.select_many(many, table)
    cc = diag["closeness"]
    free_cpu, free_mem = table.free_cpu.copy(), table.free_mem.copy()
    for pod, a in zip(many, assignments):
        if a is not None:
            free_cpu[a] -= pod.cpu
            free_mem[a] -= pod.mem
            continue
        # None => no finite-scored node had residual ledger capacity
        for j in np.flatnonzero(np.isfinite(cc[0])):
            assert (free_cpu[j] < pod.cpu - 1e-9
                    or free_mem[j] < pod.mem - 1e-9)


# --- property-based equivalence (hypothesis) ---------------------------------
def _rand_pod(rng, uid=0):
    kinds = list(WORKLOADS)
    return Pod(uid, WORKLOADS[kinds[int(rng.integers(len(kinds)))]], "topsis")


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n=st.integers(4, 200),
       util=st.floats(0.0, 0.8), scheme=st.sampled_from(SCHEME_NAMES))
def test_property_singleton_queue_matches_per_pod_select(seed, n, util,
                                                         scheme):
    """On a singleton queue the batched path must agree with the per-pod
    scheduler for every scheme, over randomized fleets: same node (or both
    unschedulable)."""
    rng = np.random.default_rng(seed)
    table = make_fleet(n, seed=seed, utilization=util)
    pod = _rand_pod(rng)
    idx, _ = GreenPodScheduler(scheme, backend="numpy").select(pod, table)
    assignments, _ = BatchScheduler(scheme,
                                    backend="numpy").select_many([pod], table)
    assert assignments == [idx]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       n=st.sampled_from((4, 64, 257)), p=st.integers(1, 8),
       util=st.floats(0.0, 0.8))
def test_property_backends_equivalent(seed, n, p, util):
    """All three backends score randomized (fleet, queue) pairs within 1e-5
    of the numpy reference, with identical feasibility masks."""
    table = make_fleet(n, seed=seed, utilization=util)
    pods = make_queue(p, seed=seed)
    want = BatchScheduler("energy_centric",
                          backend="numpy").score_queue(pods, table)
    for backend in ("jax", "pallas"):
        got = BatchScheduler("energy_centric",
                             backend=backend).score_queue(pods, table)
        finite = np.isfinite(want)
        np.testing.assert_array_equal(finite, np.isfinite(got))
        np.testing.assert_allclose(got[finite], want[finite], atol=1e-5)

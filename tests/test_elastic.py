"""Elastic fleet subsystem: power-state lifecycle, autoscale policies,
TOPSIS-driven consolidation, and state-ledger energy/carbon accounting.

The backbone invariant mirrors the carbon subsystem's: with the policy
disabled (``autoscale=None``) the engine's output is *bitwise* identical to
the policy-free engine — same placements, same energy totals, empty state
ledger, and ``table6()`` still reproduces the recorded golden exactly —
pinned by a hypothesis property test across all three backends. Elasticity
only changes behaviour when a policy is attached.
"""
import json
import math
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    def settings(*args, **kwargs):
        def wrap(f):
            return f
        return wrap

    def given(*args, **kwargs):
        def wrap(f):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.carbon import CarbonPolicy, ConstantCarbon, TraceCarbon
from repro.core.elastic import (ACTIVE, ASLEEP, IDLE, WAKING,
                                AutoscalePolicy, ElasticFleet,
                                NODE_WAKE_PROFILES)
from repro.core.energy import NODE_ENERGY_PROFILES, PowerTimeline
from repro.core.scheduler import (BatchScheduler, DefaultK8sScheduler,
                                  GreenPodScheduler)
from repro.cluster.engine import RunningTask
from repro.cluster.node import Node, NodeTable, make_scenario_cluster
from repro.cluster.simulator import run_scenario, table6
from repro.cluster.workload import (WORKLOADS, Pod, PoissonArrivals,
                                    TraceArrivals)

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__),
                                     "golden_table6.json")))


# --- policy & profiles -------------------------------------------------------
def test_autoscale_policy_validation():
    AutoscalePolicy()                                      # defaults valid
    AutoscalePolicy(idle_timeout_s=math.inf)               # always-on fleet
    for bad in (dict(idle_timeout_s=0.0), dict(idle_timeout_s=-5.0),
                dict(idle_timeout_s=math.nan),
                dict(consolidate_interval_s=0.0),
                dict(consolidate_interval_s=-1.0),
                dict(consolidate_util_below=1.5),
                dict(consolidate_util_below=-0.1),
                dict(min_awake=-1)):
        with pytest.raises(ValueError):
            AutoscalePolicy(**bad)


def test_wake_profiles_sane():
    """Every node class has a positive wake latency, a sleep draw well
    below idle, and a positive wake surge."""
    for cls, prof in NODE_WAKE_PROFILES.items():
        idle = NODE_ENERGY_PROFILES[cls]["idle_power"]
        assert prof["wake_latency_s"] > 0.0
        assert 0.0 < prof["sleep_power_w"] < idle
        assert prof["wake_energy_j"] > 0.0


def test_power_state_column_feeds_awake():
    """A real power-state column overrides the static used_cpu derivation:
    IDLE/WAKING/ACTIVE nodes are awake (zero marginal idle cost), ASLEEP
    nodes are not; None entries keep the legacy rule."""
    nodes = [Node("n0", "A", 2, 4), Node("n1", "B", 2, 8),
             Node("n2", "C", 4, 16), Node("n3", "B", 2, 8)]
    nodes[3].bind(0.5, 1.0)
    table = NodeTable.from_nodes(nodes)
    np.testing.assert_array_equal(table.awake, [False, False, False, True])
    nodes[0].power_state = IDLE
    nodes[1].power_state = ASLEEP
    nodes[2].power_state = WAKING
    table = NodeTable.from_nodes(nodes)      # n3 stays on the legacy rule
    np.testing.assert_array_equal(table.awake, [True, False, True, True])
    nodes[3].power_state = ACTIVE
    np.testing.assert_array_equal(NodeTable.from_nodes(nodes).awake,
                                  [True, False, True, True])


# --- scheduler exclude masks -------------------------------------------------
def test_select_exclude_masks_nodes():
    nodes = [Node("a-0", "A", 4, 16), Node("b-0", "B", 4, 16),
             Node("c-0", "C", 8, 32)]
    table = NodeTable.from_nodes(nodes)
    pod = Pod(0, WORKLOADS["medium"], "topsis")
    for sched in (GreenPodScheduler("energy_centric"), DefaultK8sScheduler()):
        base, _ = sched.select(pod, table)
        ex = np.zeros(3, dtype=bool)
        ex[base] = True
        alt, _ = sched.select(pod, table, exclude=ex)
        assert alt is not None and alt != base
        none, diag = sched.select(pod, table, exclude=np.ones(3, bool))
        assert none is None and diag["reason"] == "unschedulable"


def test_select_many_exclude_row_and_matrix():
    nodes = [Node("a-0", "A", 4, 16), Node("b-0", "B", 4, 16),
             Node("c-0", "C", 8, 32)]
    table = NodeTable.from_nodes(nodes)
    pods = [Pod(0, WORKLOADS["light"], "topsis"),
            Pod(1, WORKLOADS["light"], "topsis")]
    sched = BatchScheduler("energy_centric", backend="numpy")
    base, _ = sched.select_many(pods, table)
    # (N,) mask applies to every pod
    ex = np.zeros(3, dtype=bool)
    ex[base[0]] = True
    asn, _ = sched.select_many(pods, table, exclude=ex)
    assert all(a is not None and a != base[0] for a in asn)
    # (P, N) mask applies per pod
    ex2 = np.zeros((2, 3), dtype=bool)
    ex2[1, :] = True
    asn2, _ = sched.select_many(pods, table, exclude=ex2)
    assert asn2[0] == base[0] and asn2[1] is None


# --- disabled policy: bitwise identity (satellite property test) -------------
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       profile=st.sampled_from(("mixed", "edge_heavy")))
def test_property_disabled_policy_is_bitwise_inert(seed, profile):
    """autoscale=None ⇒ run_scenario output bitwise identical to the
    policy-free engine on every backend: same placements and start times,
    bitwise-equal energy totals, empty state ledger, zero elastic
    counters."""
    arr = lambda: PoissonArrivals(rate_per_s=0.3, n_bursts=3, burst_size=4,
                                  seed=seed)
    fac = lambda: make_scenario_cluster(profile, 8, seed=seed)
    ref = run_scenario(arr(), "energy_centric", cluster_factory=fac,
                       batch=True, batch_backend="numpy")
    for backend in ("numpy", "jax", "pallas"):
        res = run_scenario(arr(), "energy_centric", cluster_factory=fac,
                           batch=True, batch_backend=backend,
                           autoscale=None)
        assert [r.node for r in res.records] == [r.node for r in ref.records]
        assert ([r.start_s for r in res.records]
                == [r.start_s for r in ref.records])
        for s in ("topsis", "default"):
            assert res.energy_kj(s) == ref.energy_kj(s)
        assert res.unschedulable == ref.unschedulable
        assert not res.timeline.state_intervals
        assert not res.timeline.wake_transitions
        assert res.wakes == res.sleeps == res.migrations == 0
        # with the ledger empty the fleet totals reduce to the legacy ones
        assert res.fleet_idle_energy_kj() * 1000.0 \
            == res.timeline.idle_energy_j(None)


def test_table6_still_matches_golden_bitwise():
    """The elastic stack leaves paper mode untouched: table6() equals the
    recorded pre-refactor golden exactly."""
    t6 = table6()
    for level, d in GOLDEN["table6"].items():
        for scheme, vals in d.items():
            for key, want in vals.items():
                assert t6[level][scheme][key] == want, (level, scheme, key)


# --- always-on accounting ----------------------------------------------------
def test_always_on_policy_accounts_full_fleet_idle():
    """idle_timeout=inf: every node is awake the whole run, so fleet idle
    energy is exactly sum(idle_power) x horizon — the baseline an
    idle-timeout policy is measured against."""
    fac = lambda: make_scenario_cluster("mixed", 8, seed=2)
    res = run_scenario(
        PoissonArrivals(rate_per_s=0.3, n_bursts=3, burst_size=4, seed=5),
        "energy_centric", cluster_factory=fac, batch=True,
        batch_backend="numpy",
        autoscale=AutoscalePolicy(idle_timeout_s=math.inf))
    horizon = max(r.start_s + r.runtime_s for r in res.records)
    want = sum(NODE_ENERGY_PROFILES[n.node_class]["idle_power"]
               for n in fac()) * horizon / 1000.0
    assert abs(res.fleet_idle_energy_kj() - want) < 1e-9 * want
    assert res.sleeps == 0 and res.wakes == 0
    # the state ledger only holds IDLE stretches
    assert res.state_energy_kj(ASLEEP) == 0.0
    assert res.state_energy_kj(WAKING) == 0.0
    assert res.state_energy_kj(IDLE) > 0.0


def test_idle_timeout_cuts_fleet_idle_energy():
    """The acceptance invariant at test scale: an idle-timeout policy
    sleeps empty nodes and measurably cuts fleet idle energy vs the
    always-on baseline; the min_awake floor node never sleeps."""
    arr = lambda: PoissonArrivals(rate_per_s=0.3, n_bursts=3, burst_size=4,
                                  seed=5)
    fac = lambda: make_scenario_cluster("mixed", 8, seed=2)
    run = lambda pol: run_scenario(arr(), "energy_centric",
                                   cluster_factory=fac, batch=True,
                                   batch_backend="numpy", autoscale=pol)
    base = run(AutoscalePolicy(idle_timeout_s=math.inf))
    elastic = run(AutoscalePolicy(idle_timeout_s=20.0, min_awake=1))
    assert elastic.sleeps > 0
    assert elastic.fleet_idle_energy_kj() < base.fleet_idle_energy_kj()
    # the awake floor: node 0 never appears as an ASLEEP interval
    floor = fac()[0].name
    assert all(iv.node != floor
               for iv in elastic.timeline.state_intervals
               if iv.state == ASLEEP)
    # every pod still placed and accounted
    assert elastic.unschedulable == 0
    assert len({r.pod.uid for r in elastic.records}) \
        == len({r.pod.uid for r in base.records})


# --- wake events -------------------------------------------------------------
def _sleepy_cluster():
    return [Node("a-0", "A", 4, 16), Node("b-0", "B", 4, 16)]


def test_pod_arriving_on_sleeping_fleet_starts_after_wake_latency():
    """All nodes asleep: the arrival wakes the TOPSIS-best node and the pod
    starts exactly one wake latency after its arrival."""
    res = run_scenario(
        TraceArrivals([{"t": 100.0, "kind": "light", "scheduler": "topsis"}]),
        "energy_centric", cluster_factory=_sleepy_cluster,
        autoscale=AutoscalePolicy(idle_timeout_s=30.0, min_awake=0))
    assert res.wakes == 1 and res.unschedulable == 0
    r, = res.records
    lat = NODE_WAKE_PROFILES[r.node_class]["wake_latency_s"]
    assert r.start_s == 100.0 + lat
    # the woken node is the TOPSIS-best among the sleeping fleet (not just
    # first-fit): recompute the ranking the wake decision saw
    nodes = _sleepy_cluster()
    for n in nodes:
        n.power_state = ASLEEP
    want, _ = GreenPodScheduler("energy_centric").select(
        Pod(0, WORKLOADS["light"], "topsis"), nodes, now=100.0)
    assert r.node == nodes[want].name
    # the ledger saw the whole lifecycle: idle -> asleep -> waking
    states = {iv.state for iv in res.timeline.state_intervals}
    assert {IDLE, ASLEEP, WAKING} <= states
    assert len(res.timeline.wake_transitions) == 1


def test_pod_arriving_while_chosen_node_is_waking_starts_at_ready():
    """A second pod lands mid-wake on the already-WAKING node: no second
    wake, and both pods start exactly at the wake-completion instant."""
    # which node does the first arrival wake, and how long does it take?
    probe = run_scenario(
        TraceArrivals([{"t": 100.0, "kind": "light", "scheduler": "topsis"}]),
        "energy_centric", cluster_factory=_sleepy_cluster,
        autoscale=AutoscalePolicy(idle_timeout_s=30.0, min_awake=0))
    lat = NODE_WAKE_PROFILES[probe.records[0].node_class]["wake_latency_s"]
    res = run_scenario(
        TraceArrivals([
            {"t": 100.0, "kind": "light", "scheduler": "topsis"},
            {"t": 100.0 + lat / 2.0, "kind": "light", "scheduler": "topsis"},
        ]),
        "energy_centric", cluster_factory=_sleepy_cluster,
        autoscale=AutoscalePolicy(idle_timeout_s=30.0, min_awake=0))
    assert res.unschedulable == 0 and len(res.records) == 2
    first, second = sorted(res.records, key=lambda r: r.arrival_s)
    assert first.node == second.node == probe.records[0].node
    assert first.start_s == second.start_s == 100.0 + lat
    assert res.wakes == 1                      # mid-wake arrival rides along


def test_unschedulable_when_pressure_wake_disabled():
    """wake_on_pressure=False with the whole fleet asleep: the pod can
    never be placed and is counted unschedulable (the engine terminates
    instead of spinning)."""
    res = run_scenario(
        TraceArrivals([{"t": 100.0, "kind": "light", "scheduler": "topsis"}]),
        "energy_centric", cluster_factory=_sleepy_cluster,
        autoscale=AutoscalePolicy(idle_timeout_s=30.0, min_awake=0,
                                  wake_on_pressure=False))
    assert res.unschedulable == 1 and not res.records
    assert res.wakes == 0


# --- consolidation drains ----------------------------------------------------
def test_consolidation_drains_low_util_node_and_preserves_pod_metrics():
    """A low-utilization node is drained at the consolidation tick: its
    task migrates through the preemption machinery (truncated segment +
    requeued full rerun), the node sleeps, and per-pod (not per-attempt)
    metric semantics hold."""
    fac = lambda: [Node("a-0", "A", 4, 16), Node("b-0", "B", 4, 16)]
    res = run_scenario(
        TraceArrivals([{"t": 0.0, "kind": "medium", "scheduler": "topsis"}]),
        "energy_centric", cluster_factory=fac,
        autoscale=AutoscalePolicy(idle_timeout_s=math.inf, min_awake=0,
                                  consolidate_interval_s=10.0,
                                  consolidate_util_below=0.25))
    assert res.migrations == 1
    assert len(res.records) == 2
    first, second = res.records
    assert first.pod.uid == second.pod.uid
    assert second.node != first.node                      # migrated off
    assert first.runtime_s == 10.0                        # truncated at tick
    assert second.start_s == 10.0                         # restarted at once
    # the drained node sleeps immediately (no idle-timeout wait)
    asleep = [iv for iv in res.timeline.state_intervals
              if iv.state == ASLEEP and iv.node == first.node]
    assert asleep and asleep[0].start_s == 10.0
    # per-pod metrics: one pod, both attempts summed, energy counted once
    assert res.mean_exec_time_s("topsis") \
        == first.runtime_s + second.runtime_s
    n_pods = len({r.pod.uid for r in res.records})
    assert n_pods == 1
    assert res.mean_energy_kj("topsis") == res.energy_kj("topsis")
    # timeline dynamic energy equals the split segments' sum
    segs = res.timeline.segments
    assert len(segs) == 2 and segs[0].runtime_s == 10.0
    assert abs(res.timeline.dynamic_energy_j("topsis")
               - (segs[0].energy_j + segs[1].energy_j)) < 1e-12


def test_drain_skipped_when_victims_fit_nowhere_awake():
    """A drain candidate whose tasks only fit on sleeping capacity is left
    alone — consolidation never strands a task (or forces it through a
    wake latency)."""
    fac = lambda: [Node("a-0", "A", 4, 16),
                   Node("b-tiny", "B", 0.4, 0.8)]     # cannot host medium
    res = run_scenario(
        TraceArrivals([{"t": 0.0, "kind": "medium", "scheduler": "topsis"}]),
        "energy_centric", cluster_factory=fac,
        autoscale=AutoscalePolicy(idle_timeout_s=math.inf, min_awake=0,
                                  consolidate_interval_s=10.0,
                                  consolidate_util_below=0.25))
    assert res.migrations == 0
    assert len(res.records) == 1              # ran to completion in place
    assert res.records[0].runtime_s > 70.0


def test_multi_victim_drain_requires_order_independent_fit_for_deferrable():
    """The TOPSIS round re-places drain victims by score, not by the
    eligibility ledger's first-fit order — so a deferrable victim is only
    drained when it fits on some awake node even if every other victim of
    the pass landed there first. First-fit alone passing is not enough."""
    med = Pod(0, WORKLOADS["medium"], "topsis", deferrable=True,
              deadline_s=100.0)
    comp = Pod(1, WORKLOADS["complex"], "topsis")

    def drain_pass(y_caps):
        nodes = [Node("x", "B", 1.0, 2.0), Node("y", "B", *y_caps),
                 Node("z", "B", 4.0, 8.0)]
        fleet = ElasticFleet(
            nodes, AutoscalePolicy(idle_timeout_s=math.inf, min_awake=0,
                                   consolidate_interval_s=10.0,
                                   consolidate_util_below=0.9),
            PowerTimeline())
        for pod in (med, comp):
            fleet.on_commit(2, 0.0)
            nodes[2].bind(pod.cpu, pod.mem)
        running = [RunningTask(50.0, med.uid, med, 2, 0, 0),
                   RunningTask(60.0, comp.uid, comp, 2, 1, 1)]
        return fleet.consolidation_victims(5.0, running,
                                           lambda p: p.deadline_s)
    # roomy y: the deferrable victim fits y even after the complex victim
    # is charged there too -> whole node drained
    drained, victims = drain_pass((1.6, 3.2))
    assert drained == [2] and len(victims) == 2
    # tight y: first-fit packs (medium -> x, complex -> y) so the naive
    # proof passes, but a score-ordered round could take y first and
    # strand the deferrable victim -> the node must not be drained
    drained, victims = drain_pass((1.2, 2.4))
    assert drained == [] and victims == []


def test_drain_colliding_with_deferral_deadline_never_starts_pod_late():
    """Drains interact correctly with carbon deferral deadlines: a drained
    deferrable pod that re-defers (the signal spiked) is started exactly at
    its deadline, never past it; and once the deadline has passed the task
    is not drained at all."""
    sig = TraceCarbon([{"t": 0.0, "intensity": 100.0},
                       {"t": 15.0, "intensity": 500.0}])
    fac = lambda: [Node("a-0", "A", 4, 16), Node("b-0", "B", 4, 16)]
    pol = lambda interval: AutoscalePolicy(idle_timeout_s=math.inf,
                                           min_awake=0,
                                           consolidate_interval_s=interval,
                                           consolidate_util_below=0.25)
    trace = lambda ddl: TraceArrivals([
        {"t": 0.0, "kind": "medium", "scheduler": "topsis",
         "deferrable": True, "deadline_s": ddl}])
    carbon = CarbonPolicy(sig, defer_threshold=300.0, check_interval_s=7.0)
    # signal is low at t=0 (pod starts immediately), spikes at 15; the
    # drain at t=20 requeues the pod, deferral holds it, and it starts
    # exactly at its deadline (t=60) on the other node
    res = run_scenario(trace(60.0), "energy_centric", cluster_factory=fac,
                       carbon=carbon, autoscale=pol(20.0))
    assert res.migrations == 1 and res.unschedulable == 0
    first, second = res.records
    assert first.start_s == 0.0 and first.runtime_s == 20.0
    assert second.start_s == 60.0             # == deadline, never past
    assert second.node != first.node
    # deadline already passed at the drain tick: the task is left running
    res2 = run_scenario(trace(15.0), "energy_centric", cluster_factory=fac,
                        carbon=carbon, autoscale=pol(20.0))
    assert res2.migrations == 0 and len(res2.records) == 1
    assert res2.records[0].start_s == 0.0


def test_preempting_pod_on_waking_node_clamps_to_zero_runtime():
    """Carbon preemption can hit a pod committed to a still-WAKING node
    (its start lies in the future): the partial attempt clamps to zero
    runtime/energy instead of going negative, and the pod reruns in
    full."""
    sig = TraceCarbon([{"t": 0.0, "intensity": 100.0},
                       {"t": 106.5, "intensity": 900.0}])
    fac = lambda: [Node("c-0", "C", 4, 16)]
    res = run_scenario(
        TraceArrivals([
            {"t": 100.0, "kind": "medium", "scheduler": "topsis",
             "deferrable": True, "deadline_s": 600.0},
            # the 107.0 round commits the deferrable pod onto the WAKING
            # node (ready at 108); the 107.5 round preempts it before the
            # wake completes — its start still lies in the future
            {"t": 107.0, "kind": "light", "scheduler": "default"},
            {"t": 107.5, "kind": "light", "scheduler": "default"},
        ]),
        "energy_centric", cluster_factory=fac,
        carbon=CarbonPolicy(sig, defer_threshold=1000.0,
                            preempt_threshold=400.0, check_interval_s=50.0),
        autoscale=AutoscalePolicy(idle_timeout_s=30.0, min_awake=0))
    assert res.preemptions == 1 and res.unschedulable == 0
    lat = NODE_WAKE_PROFILES["C"]["wake_latency_s"]
    attempts = [r for r in res.records if r.pod.deferrable]
    assert len(attempts) == 2
    first, rerun = attempts
    # evicted at t=107, before its wake-delayed start at 108: zero, not -1
    assert first.start_s == 100.0 + lat
    assert first.runtime_s == 0.0 and first.energy_j == 0.0
    assert rerun.start_s == 100.0 + lat and rerun.runtime_s > 0.0
    assert all(s.runtime_s >= 0.0 for s in res.timeline.segments)
    assert all(r.runtime_s >= 0.0 and r.energy_j >= 0.0
               for r in res.records)


def test_waking_node_excluded_for_deadline_late_deferrable_pod():
    """The commit guard: a WAKING node whose ready time lies past a
    deferrable pod's deadline is masked out of its scoring validity."""
    nodes = [Node("a-0", "A", 4, 16), Node("b-0", "B", 4, 16)]
    policy = AutoscalePolicy(idle_timeout_s=30.0, min_awake=0)
    fleet = ElasticFleet(nodes, policy, PowerTimeline())
    fleet.request_wake(0, 100.0)               # ready at 102
    base = fleet.exclude_mask(100.0)
    late = fleet.exclude_for_deadline(base, deadline=101.0)
    ok = fleet.exclude_for_deadline(base, deadline=102.0)   # ready == ddl
    assert late[0] and not ok[0]


# --- state-ledger accounting -------------------------------------------------
def test_state_ledger_energy_and_carbon_accounting():
    """Manual ledger: state intervals and wake lumps sum exactly, and under
    a flat signal carbon is energy x intensity / 3.6e6."""
    tl = PowerTimeline(carbon_signal=ConstantCarbon(400.0),
                       node_region={"n0": "default"})
    tl.add("n0", "A", "topsis", 0.0, 10.0, 3.0)
    tl.add_state("n0", "A", IDLE, 10.0, 40.0, 6.0)
    tl.add_state("n0", "A", ASLEEP, 40.0, 100.0, 0.3)
    tl.add_state("n0", "A", WAKING, 100.0, 102.0, 6.0)
    tl.add_wake("n0", "A", 100.0, 25.0)
    tl.add_state("n0", "A", IDLE, 0.0, 0.0, 6.0)      # empty: dropped
    assert len(tl.state_intervals) == 3
    assert tl.state_energy_j(IDLE) == 180.0
    assert tl.state_energy_j(ASLEEP) == 18.0
    assert tl.state_energy_j(WAKING) == 12.0
    assert tl.state_energy_j() == 210.0
    assert tl.wake_transition_energy_j() == 25.0
    idle_busy = NODE_ENERGY_PROFILES["A"]["idle_power"] * 10.0
    want_idle = (idle_busy + 210.0 + 25.0) / 1000.0
    assert abs(tl.fleet_idle_energy_kj() - want_idle) < 1e-12
    assert abs(tl.fleet_energy_kj() - (30.0 / 1000.0 + want_idle)) < 1e-12
    # carbon: every joule at 400 g/kWh
    want_c = (210.0 + 25.0) * 400.0 / 3.6e6
    assert abs(tl.state_carbon_g() - want_c) < 1e-12
    assert abs(tl.fleet_carbon_g()
               - (tl.total_carbon_g(None) + want_c)) < 1e-12


def test_elastic_scenario_carbon_and_backend_agreement():
    """An elastic + carbon scenario: numpy and jax backends place
    identically, fleet carbon exceeds the task-attributed total (sleep
    residuals and idle stretches emit too), and every deferrable pod
    starts by its deadline."""
    arr = lambda: PoissonArrivals(rate_per_s=0.3, n_bursts=3, burst_size=4,
                                  seed=7, deferrable_share=0.5,
                                  deadline_s=300.0)
    fac = lambda: make_scenario_cluster("mixed", 8, seed=3)
    pol = AutoscalePolicy(idle_timeout_s=20.0, min_awake=1,
                          consolidate_interval_s=60.0)
    carbon = CarbonPolicy(ConstantCarbon(400.0))
    runs = {}
    for backend in ("numpy", "jax"):
        runs[backend] = run_scenario(arr(), "energy_centric",
                                     cluster_factory=fac, batch=True,
                                     batch_backend=backend, carbon=carbon,
                                     autoscale=pol)
    a, b = runs["numpy"], runs["jax"]
    assert [r.node for r in a.records] == [r.node for r in b.records]
    assert a.fleet_idle_energy_kj() == b.fleet_idle_energy_kj()
    assert a.fleet_carbon_g() > a.total_carbon_g(None)
    arrival = {r.pod.uid: r.arrival_s for r in a.records}
    for r in a.records:
        if r.pod.deferrable:
            assert r.start_s <= arrival[r.pod.uid] + r.pod.deadline_s + 1e-9

"""Per-architecture smoke tests: reduced configs of the same family run one
forward/train step on CPU; shapes + finiteness asserted. Full configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm

ARCHS = list(registry.ALIASES)


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.cross_attn_every:
        batch["vision"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_vision_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)) * 0.1,
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = registry.smoke_config(arch)
            model = lm.build(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_finite(built, arch):
    cfg, model, params = built(arch)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    assert jnp.isfinite(metrics["ce"])


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite_and_nonzero(built, arch):
    cfg, model, params = built(arch)
    batch = make_batch(cfg)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(built, arch):
    cfg, model, params = built(arch)
    B, S, ML = 2, 8, 16
    batch = make_batch(cfg, B, S)
    logits, caches = model.prefill(params, batch, ML)
    assert logits.shape == (B, cfg.vocab)
    assert int(caches["len"]) == S
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches = model.decode(params, caches, tok)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all()), arch
    assert int(caches["len"]) == S + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(built, arch):
    """Incremental decode must reproduce teacher-forced logits: run prefill
    on s tokens + decode token s, compare with prefill on s+1 tokens.

    MoE archs: capacity-based routing drops depend on the token GROUP, so
    the invariant only holds exactly under no-drop capacity — rebuild with a
    large capacity factor (standard practice for this equivalence check)."""
    cfg, model, params = built(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
        model = lm.build(cfg)
    B, S = 2, 8
    batch = make_batch(cfg, B, S + 1, seed=1)
    toks = batch["tokens"]
    b1 = dict(batch, tokens=toks[:, :S])
    _, caches = model.prefill(params, b1, S + 4)
    logits_inc, _ = model.decode(params, caches, toks[:, S])
    b2 = dict(batch, tokens=toks)
    logits_full, _ = model.prefill(params, b2, S + 4)
    atol = 1e-3 if cfg.dtype == "float32" else 5e-2
    np.testing.assert_allclose(np.asarray(logits_inc),
                               np.asarray(logits_full), atol=atol,
                               rtol=1e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """Exact published numbers from the assignment table."""
    spec = {
        "mixtral-8x7b": (32, 4096, 32, 8, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
        "deepseek-coder-33b": (62, 7168, 56, 8, 32256),
        "gemma-7b": (28, 3072, 16, 16, 256000),
        "minitron-8b": (32, 4096, 32, 8, 256000),
        "llama3-8b": (32, 4096, 32, 8, 128256),
        "zamba2-7b": (81, 3584, 32, 32, 32000),
        "rwkv6-1.6b": (24, 2048, 32, 32, 65536),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 128256),
        "whisper-base": (6, 512, 8, 8, 51865),
    }[arch]
    cfg = registry.config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.vocab)
    assert got == spec, (arch, got, spec)


def test_param_counts_plausible():
    """param_count() must land near the advertised sizes."""
    expect = {"llama3-8b": (8.0e9, 0.15), "mixtral-8x7b": (46.7e9, 0.15),
              "deepseek-v3-671b": (671e9, 0.15), "gemma-7b": (8.5e9, 0.20),
              "rwkv6-1.6b": (1.6e9, 0.25), "deepseek-coder-33b": (33e9, 0.15),
              "minitron-8b": (8.0e9, 0.35),  # 256k vocab dominates
              "zamba2-7b": (7.0e9, 0.35)}
    for arch, (n, tol) in expect.items():
        got = registry.config(arch).param_count()
        assert abs(got - n) / n < tol, (arch, got, n)


def test_moe_active_params():
    cfg = registry.config("mixtral-8x7b")
    full, active = cfg.param_count(), cfg.active_param_count()
    assert active < full
    # mixtral: ~13B active of ~47B
    assert 0.2 < active / full < 0.4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_same_family_as_full(built, arch):
    cfg_full = registry.config(arch)
    cfg_smoke = registry.smoke_config(arch)
    assert cfg_smoke.family == cfg_full.family
    assert cfg_smoke.is_moe == cfg_full.is_moe
    assert cfg_smoke.rwkv == cfg_full.rwkv
    assert cfg_smoke.enc_dec == cfg_full.enc_dec
    assert bool(cfg_smoke.ssm_state) == bool(cfg_full.ssm_state)
    assert bool(cfg_smoke.cross_attn_every) == bool(cfg_full.cross_attn_every)


def test_long_500k_only_subquadratic():
    for arch in ARCHS:
        shapes = registry.shapes_for(arch)
        if "long_500k" in shapes:
            assert arch in ("rwkv6-1.6b", "zamba2-7b"), arch


def test_moe_capacity_drops_renormalize(built):
    """Capacity overflow must not produce NaNs or unbounded outputs."""
    cfg, model, params = built("mixtral-8x7b")
    cfg2 = dataclasses.replace(cfg, capacity_factor=0.25)   # force drops
    model2 = lm.build(cfg2)
    batch = make_batch(cfg2, 2, 16)
    loss, _ = model2.loss(params, batch)
    assert jnp.isfinite(loss)

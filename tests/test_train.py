"""Training substrate: optimizer, microbatching, loop, fault tolerance,
gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import SyntheticLM, make_global_batch
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw, compress
from repro.train import checkpoint as ckpt
from repro.train import fault
from repro.train import loop as tl


@pytest.fixture(scope="module")
def small():
    cfg = registry.smoke_config("llama3-8b")
    model = lm.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def batch_for(cfg, B=4, S=32, step=0):
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=S, global_batch=B)
    return {"tokens": jnp.asarray(ds.batch_at(step))}


# --- AdamW -----------------------------------------------------------------------
def test_adamw_matches_reference_step():
    """One AdamW step against a hand-written numpy computation."""
    cfg = adamw.AdamWConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8,
                            weight_decay=0.0, grad_clip=1e9,
                            warmup_steps=0, total_steps=10 ** 9)
    p = {"w": jnp.asarray([[1.0, -2.0]])}
    g = {"w": jnp.asarray([[0.5, 0.25]])}
    st = adamw.init(cfg, p)
    newp, st2, m = adamw.update(cfg, g, st, p)
    gn = np.sqrt(0.5 ** 2 + 0.25 ** 2)
    assert abs(float(m["grad_norm"]) - gn) < 1e-6
    mt = 0.1 * np.array([0.5, 0.25])
    vt = 0.05 * np.array([0.25, 0.0625])
    mhat = mt / (1 - 0.9)
    vhat = vt / (1 - 0.95)
    want = np.array([[1.0, -2.0]]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)
    assert int(st2.step) == 1


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           [1, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-2


def test_quantized_adamw_tracks_fp32():
    """Int8 moments stay close to the fp32 trajectory over 20 steps."""
    cfg32 = adamw.AdamWConfig(lr=3e-3, warmup_steps=0)
    cfg8 = adamw.AdamWConfig(lr=3e-3, warmup_steps=0, quantized_state=True)
    key = jax.random.PRNGKey(0)
    p32 = {"w": jax.random.normal(key, (64, 64))}
    p8 = jax.tree.map(jnp.copy, p32)
    s32, s8 = adamw.init(cfg32, p32), adamw.init(cfg8, p8)
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        p32, s32, _ = adamw.update(cfg32, g, s32, p32)
        p8, s8, _ = adamw.update(cfg8, g, s8, p8)
    diff = float(jnp.abs(p32["w"] - p8["w"]).max())
    scale = float(jnp.abs(p32["w"]).max())
    assert diff / scale < 0.2, diff
    # and the trajectories stay strongly aligned
    a, b = np.asarray(p32["w"]).ravel(), np.asarray(p8["w"]).ravel()
    cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
    assert cos > 0.999


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(1), (10000,)) * 3
    err = jnp.abs(adamw.dequantize(adamw.quantize(x)) - x)
    # blockwise absmax: |err| <= absmax/254 per block
    blocks = jnp.pad(x, (0, (-x.size) % adamw.BLOCK)).reshape(-1, adamw.BLOCK)
    bound = jnp.repeat(jnp.abs(blocks).max(1) / 127.0,
                       adamw.BLOCK)[:x.size] * 0.5 + 1e-7
    assert bool(jnp.all(err <= bound))


# --- microbatching ------------------------------------------------------------
def test_microbatch_grads_match_full_batch(small):
    cfg, model, params = small
    batch = batch_for(cfg, B=4)
    l1, _, g1 = tl.microbatch_grads(model, params, batch, 1)
    l2, _, g2 = tl.microbatch_grads(model, params, batch, 4)
    assert abs(float(l1) - float(l2)) < 1e-4
    err = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), g1, g2)
    assert max(jax.tree.leaves(err)) < 1e-3


# --- end-to-end: loss decreases --------------------------------------------------
def test_training_reduces_loss(small):
    cfg, model, params = small
    mesh = make_host_mesh()
    step, _ = tl.make_train_step(model, adamw.AdamWConfig(lr=3e-3,
                                                          warmup_steps=0),
                                 mesh, n_micro=2)
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8)
    state = adamw.init(adamw.AdamWConfig(lr=3e-3, warmup_steps=0), params)
    params_t = jax.tree.map(jnp.copy, params)   # step donates its inputs
    losses = []
    for i in range(30):
        batch = {"tokens": jnp.asarray(ds.batch_at(i))}
        params_t, state, m = step(params_t, state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


# --- checkpointing ---------------------------------------------------------------
def test_checkpoint_roundtrip(small):
    cfg, model, params = small
    ocfg = adamw.AdamWConfig()
    state = {"params": params, "opt_state": adamw.init(ocfg, params)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, state)
        assert ckpt.latest_step(d) == 7
        got = ckpt.restore(d, 7, state)
        ok = jax.tree.map(lambda a, b: bool(np.allclose(np.asarray(a),
                                                        np.asarray(b))),
                          state, got)
        assert all(jax.tree.leaves(ok))


def test_checkpoint_async_save(small):
    cfg, model, params = small
    with tempfile.TemporaryDirectory() as d:
        h = ckpt.save(d, 3, {"params": params}, blocking=False)
        h.join()
        assert ckpt.latest_step(d) == 3
        got = ckpt.restore(d, 3, {"params": params})
        leaves_a = jax.tree.leaves(params)
        leaves_b = jax.tree.leaves(got["params"])
        assert all(np.allclose(np.asarray(a), np.asarray(b))
                   for a, b in zip(leaves_a, leaves_b))


def test_checkpoint_atomicity_no_partial_dirs(small):
    """Interrupted saves leave only .tmp dirs, never half-published steps."""
    cfg, model, params = small
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"params": params})
        # a stale tmp dir must be ignored by latest_step
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert ckpt.latest_step(d) == 1


# --- fault tolerance --------------------------------------------------------------
def test_supervisor_restarts_from_checkpoint(small):
    cfg, model, params = small
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
    mesh = make_host_mesh()
    step, _ = tl.make_train_step(model, ocfg, mesh, donate=False)
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4)
    faults = {"armed": True}

    def fault_hook(s):
        if s == 7 and faults["armed"]:
            faults["armed"] = False
            raise RuntimeError("injected node failure")

    with tempfile.TemporaryDirectory() as d:
        sup = fault.Supervisor(ckpt_dir=d, ckpt_every=5, max_restarts=2)
        state = {"params": params, "opt_state": adamw.init(ocfg, params)}
        final, hist = sup.run(
            state=state, step_fn=step,
            data_fn=lambda s: {"tokens": jnp.asarray(ds.batch_at(s))},
            n_steps=10, fault_hook=fault_hook)
        # completed all 10 steps despite the failure at step 7
        assert int(final["opt_state"].step) == 10
        steps_run = [h["step"] for h in hist]
        assert steps_run.count(5) + steps_run.count(6) >= 2  # re-ran 5/6


def test_supervisor_gives_up_after_max_restarts(small):
    cfg, model, params = small
    ocfg = adamw.AdamWConfig()
    mesh = make_host_mesh()
    step, _ = tl.make_train_step(model, ocfg, mesh, donate=False)

    def always_fail(s):
        raise RuntimeError("dead node")

    with tempfile.TemporaryDirectory() as d:
        sup = fault.Supervisor(ckpt_dir=d, max_restarts=2)
        with pytest.raises(RuntimeError, match="dead node"):
            sup.run(state={"params": params,
                           "opt_state": adamw.init(ocfg, params)},
                    step_fn=step,
                    data_fn=lambda s: batch_for(cfg),
                    n_steps=5, fault_hook=always_fail)


def test_straggler_detection(small):
    cfg, model, params = small
    ocfg = adamw.AdamWConfig()
    mesh = make_host_mesh()
    step, _ = tl.make_train_step(model, ocfg, mesh, donate=False)
    alerts = []
    import time as _t

    # measure a typical step so the injected stall dominates even when the
    # host is busy (dry-run compiles share this CPU)
    b0 = batch_for(cfg)
    p0 = jax.tree.map(jnp.copy, params)
    s0 = adamw.init(ocfg, params)
    step(p0, s0, b0)                       # compile
    t0 = __import__("time").perf_counter()
    step(p0, s0, b0)
    typical = __import__("time").perf_counter() - t0

    def slow_hook(s):
        if s == 8:
            _t.sleep(max(1.0, 10.0 * typical))

    with tempfile.TemporaryDirectory() as d:
        sup = fault.Supervisor(ckpt_dir=d, straggler_factor=3.0,
                               on_straggler=alerts.append)
        sup.run(state={"params": params,
                       "opt_state": adamw.init(ocfg, params)},
                step_fn=step, data_fn=lambda s: batch_for(cfg),
                n_steps=10, fault_hook=slow_hook)
    assert any(a.step == 8 for a in alerts)


# --- gradient compression ----------------------------------------------------------
def test_compressed_psum_single_shard_exact():
    """n=1: compression must be lossless after error feedback converges."""
    mesh = make_host_mesh()
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    g = jax.random.normal(jax.random.PRNGKey(0), (512,))
    err = jnp.zeros((512,))

    f = shard_map(lambda g, e: compress.compressed_psum(g, e, "data"),
                  mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                  check_rep=False)
    g_hat, new_err = f(g, err)
    # one shard: g_hat = dequant(quant(g)); err = g - g_hat
    np.testing.assert_allclose(np.asarray(g_hat + new_err), np.asarray(g),
                               atol=1e-5)


def test_error_feedback_preserves_sum_over_time():
    """Sum of transmitted gradients + residual equals sum of true gradients
    (the invariant that makes EF-SGD converge)."""
    mesh = make_host_mesh()
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    f = shard_map(lambda g, e: compress.compressed_psum(g, e, "data"),
                  mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                  check_rep=False)
    key = jax.random.PRNGKey(1)
    err = jnp.zeros((256,))
    sent, true = jnp.zeros((256,)), jnp.zeros((256,))
    for i in range(10):
        g = jax.random.normal(jax.random.fold_in(key, i), (256,))
        g_hat, err = f(g, err)
        sent += g_hat
        true += g
    np.testing.assert_allclose(np.asarray(sent + err), np.asarray(true),
                               atol=1e-4)


def test_compressed_dp_step_trains(small):
    cfg, model, params = small
    mesh = make_host_mesh()
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0)
    step = tl.make_compressed_dp_step(model, ocfg, mesh)
    state = adamw.init(ocfg, params)
    err = compress.init_error(params)
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8)
    losses = []
    p = params
    for i in range(20):
        batch = {"tokens": jnp.asarray(ds.batch_at(i))}
        p, state, err, m = step(p, state, err, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


# --- data pipeline ------------------------------------------------------------------
def test_data_deterministic_and_shardable():
    ds = SyntheticLM(vocab=1000, seq_len=32, global_batch=8, seed=3)
    full = ds.batch_at(5)
    lo = ds.batch_at(5, 0, 4)
    hi = ds.batch_at(5, 4, 8)
    np.testing.assert_array_equal(full, np.concatenate([lo, hi]))
    np.testing.assert_array_equal(full, ds.batch_at(5))     # deterministic
    assert not np.array_equal(full, ds.batch_at(6))         # varies by step


def test_data_bigram_structure_learnable():
    ds = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=0)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b[:, 1::2], (b[:, 0::2] * 31 + 7) % 100)


def test_make_global_batch_shards():
    mesh = make_host_mesh()
    arrs = {"tokens": np.zeros((8, 4), np.int32)}
    out = make_global_batch(mesh, arrs)
    assert out["tokens"].shape == (8, 4)
    assert out["tokens"].sharding.is_fully_addressable

"""Serving engine: wave batching, padding, correctness vs manual decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = registry.smoke_config("llama3-8b")
    model = lm.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_greedy_matches_manual_loop(setup):
    cfg, model, params = setup
    prompt = np.asarray([5, 9, 3, 7], np.int32)
    eng = Engine(model, params, batch_slots=1, max_len=32)
    res = eng.serve([Request(0, prompt, max_new_tokens=5, eos_id=-1)])[0]
    # manual greedy
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                                  32)
    toks = []
    tok = jnp.argmax(logits, -1)
    for _ in range(5):
        toks.append(int(tok[0]))
        logits, cache = model.decode(params, cache, tok.astype(jnp.int32))
        tok = jnp.argmax(logits, -1)
    np.testing.assert_array_equal(res.tokens, np.asarray(toks))


def test_batched_equals_single(setup):
    """Wave batching must not change any request's greedy output."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, rng.integers(3, 8)).astype(np.int32)
               for _ in range(4)]
    eng1 = Engine(model, params, batch_slots=1, max_len=32)
    eng4 = Engine(model, params, batch_slots=4, max_len=32)
    single = [eng1.serve([Request(i, p, max_new_tokens=4, eos_id=-1)])[0]
              for i, p in enumerate(prompts)]
    # NOTE: left-padding changes positions; engine pads within a wave, so
    # compare waves of equal prompt length only
    same_len = [p[:3] for p in prompts]
    single = [eng1.serve([Request(i, p, max_new_tokens=4, eos_id=-1)])[0]
              for i, p in enumerate(same_len)]
    batched = eng4.serve([Request(i, p, max_new_tokens=4, eos_id=-1)
                          for i, p in enumerate(same_len)])
    for a, b in zip(single, sorted(batched, key=lambda r: r.uid)):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_eos_stops_early(setup):
    cfg, model, params = setup
    prompt = np.asarray([5, 9, 3], np.int32)
    eng = Engine(model, params, batch_slots=1, max_len=64)
    # find what greedy emits first, use it as eos
    r0 = eng.serve([Request(0, prompt, max_new_tokens=1, eos_id=-1)])[0]
    eos = int(r0.tokens[0])
    r = eng.serve([Request(0, prompt, max_new_tokens=30, eos_id=eos)])[0]
    assert len(r.tokens) == 1 and int(r.tokens[0]) == eos


def test_more_requests_than_slots(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(1, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=3, eos_id=-1) for i in range(5)]
    eng = Engine(model, params, batch_slots=2, max_len=32)
    res = eng.serve(reqs)
    assert sorted(r.uid for r in res) == [0, 1, 2, 3, 4]
    assert all(len(r.tokens) == 3 for r in res)

"""HLO roofline analyzer: trip-count-aware flops/bytes/collectives.

Programs are compiled in-process on the single real CPU device (trip-count
handling is device-count independent); the multi-device collective path is
covered by tests/test_dryrun.py via a subprocess with forced devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_plain_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = compiled_text(lambda x, y: x @ y, a, b)
    r = H.analyze(txt)
    assert r["flops_per_dev"] == pytest.approx(2 * 128 * 256 * 64, rel=0.01)
    # bytes: read a (128k) + read b (64k) + write out (32k)
    want_bytes = 4 * (128 * 256 + 256 * 64 + 128 * 64)
    assert r["bytes_per_dev"] == pytest.approx(want_bytes, rel=0.2)


def test_scan_multiplies_by_trip_count():
    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    txt = compiled_text(f, ws, x)
    r = H.analyze(txt)
    want = 12 * 2 * 8 * 64 * 64
    assert r["flops_per_dev"] == pytest.approx(want, rel=0.05)
    # XLA's own cost analysis counts the body ONCE — our analyzer must not
    xla = H.xla_cost_analysis(jax.jit(f).lower(ws, x).compile())["flops"]
    assert r["flops_per_dev"] > 5 * xla


def test_nested_scan_trip_product():
    def f(ws, x):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), ()
            x, _ = jax.lax.scan(inner, x, None, length=5)
            return x, ()
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    ws = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)
    r = H.analyze(compiled_text(f, ws, x))
    want = 3 * 5 * 2 * 4 * 32 * 32
    assert r["flops_per_dev"] == pytest.approx(want, rel=0.05)


def test_remat_recompute_is_visible():
    """jax.checkpoint adds recompute flops that the analyzer must count."""
    def loss(w, x):
        def block(x):
            return jnp.tanh(x @ w)
        h = jax.checkpoint(block)(x)
        return jnp.sum(h * h)

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    plain = H.analyze(compiled_text(lambda w, x: jax.grad(
        lambda w: jnp.sum(jnp.tanh(x @ w) ** 2))(w), w, x))
    # same but no checkpoint wrapper
    def loss2(w, x):
        h = jnp.tanh(x @ w)
        return jnp.sum(h * h)
    base = H.analyze(compiled_text(
        lambda w, x: jax.grad(lambda w: loss2(w, x))(w), w, x))
    assert plain["flops_per_dev"] >= base["flops_per_dev"] * 0.9


def test_dynamic_slice_bytes_not_full_operand():
    """Reading 1 row of a big table must cost ~row bytes, not table bytes."""
    table = jax.ShapeDtypeStruct((4096, 512), jnp.float32)
    idx = jax.ShapeDtypeStruct((), jnp.int32)

    def f(t, i):
        return jax.lax.dynamic_slice_in_dim(t, i, 1, 0) * 2.0

    r = H.analyze(compiled_text(f, table, idx))
    assert r["bytes_per_dev"] < 4096 * 512 * 4 / 10


def test_parse_tuple_result_with_index_comments():
    """Regression: /*index=N*/ comments inside tuple types broke parsing."""
    hlo = """
HloModule test
%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4]{0} get-tuple-element(%p), index=1
  %c = s32[] constant(1)
  %ni = s32[] add(%i, %c)
  %nx = f32[4]{0} add(%x, %x)
  ROOT %t = (s32[], /*index=1*/f32[4]{0}) tuple(%ni, %nx)
}
%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4]{0}) tuple(%z, %a)
  %w = (s32[], /*index=1*/f32[4]{0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
}
"""
    r = H.analyze(hlo)
    # 7 iterations x (body: f32[4] add + s32 add = 5 flops; cond: compare = 1)
    assert r["flops_per_dev"] == 7 * 6


def test_collective_formulas():
    hlo = """
HloModule test
%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[2,8]<=[16], to_apply=%sum
  ROOT %ag = f32[1024]{0} all-gather(%ar), replica_groups=[4,4]<=[16], dimensions={0}
}
"""
    r = H.analyze(hlo)
    c = r["collectives"]
    assert c["all-reduce"]["count"] == 1
    np.testing.assert_allclose(c["all-reduce"]["bytes"],
                               2 * 7 / 8 * 1024 * 4)
    np.testing.assert_allclose(c["all-gather"]["bytes"], 3 / 4 * 1024 * 4)


def test_top_contributors_ranks_dot_first():
    def f(x, w):
        return jnp.sum(jnp.tanh(x @ w))

    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    txt = compiled_text(f, x, w)
    top = H.top_contributors(txt, 3, "flops")
    assert "dot" in top[0][0] or top[0][1][0] > 1e7

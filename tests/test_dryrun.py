"""Dry-run machinery on a forced-8-device CPU mesh (subprocess so the main
pytest process keeps its single real device).

Full production meshes (256/512 devices x full configs) run via
``python -m repro.launch.dryrun --all`` — results in EXPERIMENTS.md.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=1200) -> str:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_tiny_mesh_train_lower_compile_smoke_arch():
    """Smoke config x (data=4, model=2) mesh: lower+compile a sharded train
    step, run the analyzer, and execute one real step on the 8 fake devices
    (numerics + shardings actually work, not just compile)."""
    out = run_py("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.models import lm
        from repro.optim import adamw
        from repro.sharding import rules
        from repro.train import loop as tl
        from repro.launch import hlo_analysis
        from repro import compat
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             **compat.axis_types_kwarg(2))
        cfg = registry.smoke_config("llama3-8b")
        model = lm.build(cfg)
        ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
        fn = tl.make_train_fn(model, ocfg, n_micro=2)
        params = model.init(jax.random.PRNGKey(0))
        state = adamw.init(ocfg, params)
        pshard = rules.params_shardings(params, mesh)
        sshard = tl.state_shardings(ocfg, params, mesh)
        batch = {"tokens": jnp.ones((8, 32), jnp.int32)}
        bshard = rules.batch_shardings(batch, mesh)
        step = jax.jit(fn, in_shardings=(pshard, sshard, bshard),
                       out_shardings=(pshard, sshard, None))
        with mesh:
            lowered = step.lower(
                jax.eval_shape(lambda: params),
                jax.eval_shape(lambda: state),
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             batch))
            compiled = lowered.compile()
            an = hlo_analysis.analyze(compiled.as_text())
            # actually run it
            pp = jax.device_put(params, pshard)
            ss = jax.device_put(state, sshard)
            bb = jax.device_put(batch, bshard)
            p2, s2, m = step(pp, ss, bb)
        print(json.dumps({
            "flops": an["flops_per_dev"],
            "coll_bytes": an["collective_bytes_per_dev"],
            "n_coll": {k: v["count"] for k, v in an["collectives"].items()},
            "loss": float(m["loss"]),
            "step": int(jax.device_get(s2.step)),
        }))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["step"] == 1
    assert r["loss"] > 0 and r["loss"] < 20
    assert r["flops"] > 1e6
    # TP matmuls + DP grad sync must produce collectives
    assert r["coll_bytes"] > 0, r


@pytest.mark.slow
def test_tiny_mesh_decode_and_elastic_restore():
    """Decode path on a mesh + elastic checkpoint restore onto a DIFFERENT
    mesh shape (4x2 -> 2x4)."""
    out = run_py("""
        import json, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.models import lm
        from repro.sharding import rules
        from repro.train import checkpoint as ckpt
        from repro import compat
        mesh1 = jax.make_mesh((4, 2), ("data", "model"),
                              **compat.axis_types_kwarg(2))
        mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                              **compat.axis_types_kwarg(2))
        cfg = registry.smoke_config("llama3-8b")
        model = lm.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        p1 = jax.device_put(params, rules.params_shardings(params, mesh1))
        d = tempfile.mkdtemp()
        ckpt.save(d, 1, {"params": p1})
        # elastic restore onto mesh2
        sh2 = rules.params_shardings(params, mesh2)
        got = ckpt.restore(d, 1, {"params": params},
                           shardings={"params": sh2})
        ok = all(jax.tree.leaves(jax.tree.map(
            lambda a, b: bool(np.allclose(np.asarray(a), np.asarray(b))),
            params, got["params"])))
        # decode on mesh2
        with mesh2:
            caches = model.init_cache(4, 16)
            cshard = rules.cache_shardings(caches, mesh2)
            toks = jnp.ones((4,), jnp.int32)
            logits, caches2 = jax.jit(model.decode)(got["params"], caches,
                                                    toks)
        print(json.dumps({"restore_ok": ok,
                          "logits_finite": bool(jnp.isfinite(logits).all()),
                          "len": int(jax.device_get(caches2["len"]))}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r == {"restore_ok": True, "logits_finite": True, "len": 1}


@pytest.mark.slow
def test_moe_expert_parallel_tiny_mesh():
    """MoE with E=8 experts on (data=2, model=4): E % (data*model) == 0
    triggers expert sharding over both axes; forward must stay exact vs
    single-device run."""
    out = run_py("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.models import lm
        from repro.sharding import rules
        import dataclasses
        from repro import compat
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             **compat.axis_types_kwarg(2))
        cfg = dataclasses.replace(registry.smoke_config("mixtral-8x7b"),
                                  moe_groups=2)
        model = lm.build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((4, 16), jnp.int32)}
        loss_1dev, _ = model.loss(params, batch)        # replicated reference
        pshard = rules.params_shardings(params, mesh)
        bshard = rules.batch_shardings(batch, mesh)
        with mesh:
            pp = jax.device_put(params, pshard)
            bb = jax.device_put(batch, bshard)
            loss_mesh, _ = jax.jit(model.loss)(pp, bb)
        print(json.dumps({"ref": float(loss_1dev),
                          "mesh": float(loss_mesh)}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert abs(r["ref"] - r["mesh"]) < 1e-2, r


def test_dryrun_cells_cover_assignment():
    """40 assigned cells: 10 archs x 4 shapes, with long_500k lowered only
    for sub-quadratic archs (the skip rule) — 32 runnable cells."""
    from repro.configs import registry
    total_assigned = 10 * 4
    runnable = sum(len(registry.shapes_for(a)) for a in registry.ALIASES)
    assert total_assigned == 40
    assert runnable == 32
    for arch in registry.ALIASES:
        assert "train_4k" in registry.shapes_for(arch)
        assert "prefill_32k" in registry.shapes_for(arch)
        assert "decode_32k" in registry.shapes_for(arch)


def test_plans_exist_for_all_archs():
    import importlib
    dr = importlib.import_module("repro.launch.dryrun")
    from repro.configs import registry
    for arch in registry.ALIASES:
        assert arch in dr.PLANS

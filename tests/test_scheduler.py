"""GreenPod + default-K8s scheduler behaviour (paper §III-IV)."""
import numpy as np
import pytest

from repro.cluster.node import Node, make_paper_cluster
from repro.cluster.workload import COMPETITION_LEVELS, WORKLOADS, Pod, make_pods
from repro.core.scheduler import (DefaultK8sScheduler, GreenPodScheduler,
                                  decision_matrix, predict_exec_time)
from repro.core.weighting import SCHEME_NAMES, adaptive_weights, weights_for


def pod(kind="light", uid=0, sched="topsis"):
    return Pod(uid, WORKLOADS[kind], sched)


def test_decision_matrix_shape_and_signs():
    nodes = make_paper_cluster()
    M = decision_matrix(pod(), nodes)
    assert M.shape == (4, 5)
    assert np.all(M[:, 0] > 0) and np.all(M[:, 1] > 0)
    assert np.all(M[:, 2:] >= 0) and np.all(M[:, 2:] <= 1)


def test_filter_excludes_infeasible():
    nodes = make_paper_cluster()
    # fill node A completely
    nodes[0].bind(nodes[0].free_cpu, nodes[0].free_mem)
    s = GreenPodScheduler("energy_centric")
    idx, _ = s.select(pod("complex"), nodes)
    assert idx is not None and idx != 0


def test_unschedulable_returns_none():
    nodes = [Node("tiny", "A", vcpus=0.1, mem_gb=0.1)]
    s = GreenPodScheduler()
    idx, diag = s.select(pod("complex"), nodes)
    assert idx is None and diag["reason"] == "unschedulable"
    d = DefaultK8sScheduler()
    idx, diag = d.select(pod("complex"), nodes)
    assert idx is None


def test_pure_energy_weights_pick_frugal_node():
    """With all weight on the energy criterion, TOPSIS must pick the node
    with minimum predicted energy (class A on an empty cluster). The
    calibrated energy_centric scheme trades this off against availability —
    its aggregate class-A preference is asserted in test_simulator."""
    from repro.core import topsis
    from repro.core.criteria import benefit_mask
    from repro.core.scheduler import decision_matrix, predict_energy
    nodes = make_paper_cluster()
    p = pod("medium")
    M = decision_matrix(p, nodes)
    w = np.array([1e-9, 1.0, 1e-9, 1e-9, 1e-9])
    idx = int(topsis.closeness_np(M, w, benefit_mask()).ranking[0])
    want = int(np.argmin([predict_energy(p, n) for n in nodes]))
    assert idx == want
    assert nodes[idx].node_class == "A"


def test_pure_exec_weights_pick_fast_node():
    from repro.core import topsis
    from repro.core.criteria import benefit_mask
    from repro.core.scheduler import decision_matrix
    nodes = make_paper_cluster()
    M = decision_matrix(pod("medium"), nodes)
    w = np.array([1.0, 1e-9, 1e-9, 1e-9, 1e-9])
    idx = int(topsis.closeness_np(M, w, benefit_mask()).ranking[0])
    assert nodes[idx].node_class == "C"      # highest speed


def test_default_scheduler_spreads():
    """Default K8s LeastRequested spreads load instead of consolidating."""
    nodes = make_paper_cluster()
    d = DefaultK8sScheduler()
    chosen = []
    for i in range(3):
        idx, _ = d.select(pod("light", i, "default"), nodes)
        nodes[idx].bind(0.2, 0.5)
        chosen.append(nodes[idx].name)
    assert len(set(chosen)) >= 2     # not all on one node


def test_greenpod_consolidates_vs_default():
    """The physical mechanism of the paper's savings: energy-centric TOPSIS
    re-uses awake nodes; default spreads across nodes."""
    nodes_t = make_paper_cluster()
    nodes_d = make_paper_cluster()
    s, d = GreenPodScheduler("energy_centric"), DefaultK8sScheduler()
    t_nodes, d_nodes = set(), set()
    for i in range(4):
        it, _ = s.select(pod("light", i), nodes_t)
        nodes_t[it].bind(0.2, 0.5)
        t_nodes.add(it)
        idd, _ = d.select(pod("light", i, "default"), nodes_d)
        nodes_d[idd].bind(0.2, 0.5)
        d_nodes.add(idd)
    assert len(t_nodes) <= len(d_nodes)


def test_exec_time_faster_on_fast_node():
    nodes = make_paper_cluster()
    t_a = predict_exec_time(pod("medium"), nodes[0])
    t_c = predict_exec_time(pod("medium"), nodes[2])
    assert t_c < t_a


def test_all_schemes_valid():
    for s in SCHEME_NAMES:
        w = weights_for(s)
        assert w.shape == (5,)
        assert abs(w.sum() - 1.0) < 1e-9
        assert np.all(w >= 0)
    with pytest.raises(ValueError):
        weights_for("nope")


def test_adaptive_weights_shift_under_load():
    w_idle = adaptive_weights("energy_centric", 0.0)
    w_full = adaptive_weights("energy_centric", 1.0)
    assert w_full[1] < w_idle[1]               # energy weight reduced
    assert w_full[2:5].sum() > w_idle[2:5].sum()
    np.testing.assert_allclose(w_full.sum(), 1.0)
    # below the 0.6 threshold: unchanged
    np.testing.assert_allclose(adaptive_weights("general", 0.3),
                               weights_for("general"))


def test_make_pods_counts_match_table5():
    for level, spec in COMPETITION_LEVELS.items():
        pods = make_pods(level)
        for sched in ("topsis", "default"):
            for kind, count in spec.items():
                got = sum(1 for p in pods
                          if p.scheduler == sched and p.workload.kind == kind)
                assert got == count, (level, sched, kind)


def _legacy_default_select(p, nodes):
    """The pre-vectorization DefaultK8sScheduler.select scoring loop,
    verbatim (per-node Python loop, running-max-with-epsilon tie-break) —
    the reference the NodeTable-column path is pinned against."""
    best, best_score = None, -1.0
    scores = []
    for i, n in enumerate(nodes):
        if not n.fits(p.cpu, p.mem):
            scores.append(-1.0)
            continue
        cpu_frac = (n.reserved_cpu + n.used_cpu + p.cpu) / n.vcpus
        mem_frac = (n.reserved_mem + n.used_mem + p.mem) / n.mem_gb
        least = 100.0 * ((1.0 - cpu_frac) + (1.0 - mem_frac)) / 2.0
        balanced = 100.0 * (1.0 - abs(cpu_frac - mem_frac))
        score = (least + balanced) / 2.0
        scores.append(score)
        if score > best_score + 1e-12:
            best, best_score = i, score
    return best, np.asarray(scores)


def test_default_scheduler_vectorized_matches_legacy_loop():
    """Vectorized (NodeTable-column) DefaultK8sScheduler == the per-node
    loop: identical scores (bitwise — same IEEE ops elementwise) and the
    identical selected node, across paper clusters and random fleets."""
    rng = np.random.default_rng(0)
    cases = [make_paper_cluster()]
    for trial in range(25):
        n = int(rng.integers(2, 60))
        classes = ["A", "B", "C", "default"]
        nodes = []
        for i in range(n):
            cls_i = classes[int(rng.integers(4))]
            vcpus = float(rng.choice([1, 2, 4, 8]))
            mem = float(rng.choice([2, 4, 8, 16]))
            node = Node(f"n{i}", cls_i, vcpus, mem)
            if rng.uniform() < 0.5:      # random pre-existing load
                node.used_cpu = float(rng.uniform(0, vcpus))
                node.used_mem = float(rng.uniform(0, mem))
            nodes.append(node)
        cases.append(nodes)
    d = DefaultK8sScheduler()
    for nodes in cases:
        for kind in WORKLOADS:
            p = pod(kind, sched="default")
            want_idx, want_scores = _legacy_default_select(p, nodes)
            got_idx, diag = d.select(p, nodes)
            if want_idx is None:
                assert got_idx is None
                continue
            assert got_idx == want_idx, (nodes[got_idx].name, kind)
            np.testing.assert_array_equal(diag["scores"], want_scores)


def test_default_scheduler_tie_breaks_to_first_node():
    """Exact score ties resolve to the lowest node index, as the legacy
    running-max loop did."""
    nodes = [Node("twin-0", "B", vcpus=4, mem_gb=8),
             Node("twin-1", "B", vcpus=4, mem_gb=8),
             Node("twin-2", "B", vcpus=4, mem_gb=8)]
    idx, diag = DefaultK8sScheduler().select(pod("medium"), nodes)
    assert idx == 0
    assert diag["scores"][0] == diag["scores"][1] == diag["scores"][2]


def test_default_scheduler_accepts_node_table():
    """select works on a prebuilt NodeTable snapshot (no Node list)."""
    from repro.cluster.node import NodeTable
    nodes = make_paper_cluster()
    table = NodeTable.from_nodes(nodes)
    i_list, d_list = DefaultK8sScheduler().select(pod("light"), nodes)
    i_tab, d_tab = DefaultK8sScheduler().select(pod("light"), table)
    assert i_list == i_tab
    np.testing.assert_array_equal(d_list["scores"], d_tab["scores"])


def test_node_bind_release_roundtrip():
    n = make_paper_cluster()[1]
    free0 = (n.free_cpu, n.free_mem)
    n.bind(1.0, 2.0)
    assert n.free_cpu == free0[0] - 1.0
    n.release(1.0, 2.0)
    assert (n.free_cpu, n.free_mem) == free0
    with pytest.raises(AssertionError):
        n.bind(100, 100)

"""Carbon-aware scheduling subsystem: signals, the sixth TOPSIS criterion,
deferral/preemption events, and timeline carbon accounting.

The backbone invariant: with the carbon criterion at zero weight (any paper
scheme with a signal attached) the 6-criteria stack is *bitwise* inert —
same closeness as the legacy 5-criteria ``closeness_np`` on every backend,
same placements, same energy totals, and ``table6()`` still reproduces the
recorded golden. Carbon only changes behaviour when a scheme weights it or
a policy enables temporal shifting.
"""
import json
import math
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    def settings(*args, **kwargs):
        def wrap(f):
            return f
        return wrap

    def given(*args, **kwargs):
        def wrap(f):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core.carbon import (CarbonPolicy, ConstantCarbon, SinusoidalCarbon,
                               TraceCarbon, J_PER_KWH, carbon_grams,
                               diurnal_fleet_signal)
from repro.core.criteria import (CARBON_CRITERION, benefit_mask,
                                 greenpod_criteria)
from repro.core.energy import NODE_ENERGY_PROFILES, PowerTimeline
from repro.core.scheduler import (BatchScheduler, GreenPodScheduler,
                                  decision_matrix, decision_matrix_batch)
from repro.core.weighting import (CARBON_SCHEME_NAMES, SCHEME_NAMES,
                                  adaptive_weights, weights_for)
from repro.cluster.node import (Node, NodeTable, make_fleet,
                                make_paper_cluster, make_scenario_cluster)
from repro.cluster.simulator import run_scenario, table6
from repro.cluster.workload import (WORKLOADS, Pod, PoissonArrivals,
                                    TraceArrivals)

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__),
                                     "golden_table6.json")))


# --- signals -----------------------------------------------------------------
def test_constant_signal():
    sig = ConstantCarbon(400.0, per_region={"green": 50.0})
    assert sig.intensity("anywhere", 123.0) == 400.0
    assert sig.intensity("green", 0.0) == 50.0
    assert sig.integral("green", 10.0, 30.0) == 50.0 * 20.0
    np.testing.assert_allclose(sig.intensities(["green", "x", "green"], 0.0),
                               [50.0, 400.0, 50.0])
    assert sig.fleet_min(["green", "x"], 0.0) == 50.0
    with pytest.raises(ValueError):
        ConstantCarbon(-1.0)


def test_sinusoidal_signal_values_and_integral():
    sig = SinusoidalCarbon(base=300.0, amplitude=200.0, period_s=1000.0,
                           region_phase_s={"b": 250.0})
    assert abs(sig.intensity("a", 0.0) - 300.0) < 1e-12
    assert abs(sig.intensity("a", 250.0) - 500.0) < 1e-9     # quarter period
    assert abs(sig.intensity("b", 0.0) - 500.0) < 1e-9       # phase shift
    # analytic integral matches numeric (trapezoid) quadrature
    ts = np.linspace(13.0, 789.0, 100001)
    vals = np.asarray([sig.intensity("b", t) for t in ts])
    num = float(np.sum((vals[1:] + vals[:-1]) / 2.0 * np.diff(ts)))
    assert abs(sig.integral("b", 13.0, 789.0) - num) < 1e-3
    # full period integrates to base x period
    assert abs(sig.integral("a", 0.0, 1000.0) - 300.0 * 1000.0) < 1e-6
    # non-negative everywhere when amplitude <= base
    assert min(sig.intensity("a", t) for t in ts) >= 0.0
    with pytest.raises(ValueError):
        SinusoidalCarbon(base=100.0, amplitude=200.0)
    with pytest.raises(ValueError):
        SinusoidalCarbon(period_s=0.0)


def test_diurnal_fleet_signal_staggers_regions():
    sig = diurnal_fleet_signal(("r0", "r1", "r2", "r3"), period_s=800.0)
    # t=50 avoids the sin symmetry points of the default quarter-period
    # stagger, so all four regions read distinct intensities
    vals = [sig.intensity(r, 50.0) for r in ("r0", "r1", "r2", "r3")]
    assert len({round(v, 6) for v in vals}) == 4     # all regions differ


def test_trace_signal_step_lookup_and_integral():
    sig = TraceCarbon([
        {"t": 0.0, "intensity": 100.0, "region": "a"},
        {"t": 10.0, "intensity": 300.0, "region": "a"},
        {"t": 5.0, "intensity": 50.0, "region": "default"},
    ])
    assert sig.intensity("a", 0.0) == 100.0
    assert sig.intensity("a", 9.999) == 100.0
    assert sig.intensity("a", 10.0) == 300.0      # step at the reading
    assert sig.intensity("a", 1e9) == 300.0       # last value persists
    # before the first reading the first value applies
    assert sig.intensity("default", 0.0) == 50.0
    # unknown region falls back to the default series
    assert sig.intensity("unmapped", 7.0) == 50.0
    # piecewise integral: 100 x 10 + 300 x 10 over [0, 20)
    assert abs(sig.integral("a", 0.0, 20.0) - (1000.0 + 3000.0)) < 1e-12
    assert abs(sig.integral("a", 5.0, 15.0) - (500.0 + 1500.0)) < 1e-12


def test_trace_signal_from_file_and_validation(tmp_path):
    entries = [{"t": 0.0, "intensity": 120.0, "region": "default"},
               {"t": 60.0, "intensity": 80.0, "region": "default"}]
    path = tmp_path / "carbon.json"
    path.write_text(json.dumps(entries))
    sig = TraceCarbon.from_file(str(path))
    assert sig.intensity("default", 61.0) == 80.0
    for bad in ([{"intensity": 1.0}],                       # missing t
                [{"t": -1.0, "intensity": 1.0}],            # negative t
                [{"t": 0.0}],                               # missing intensity
                [{"t": 0.0, "intensity": -5.0}],            # negative value
                [{"t": 0.0, "intensity": 1.0, "region": ""}],
                []):                                        # empty trace
        with pytest.raises(ValueError):
            TraceCarbon(bad)
    only_a = TraceCarbon([{"t": 0.0, "intensity": 1.0, "region": "a"}])
    with pytest.raises(ValueError):
        only_a.intensity("b", 0.0)          # no default series to fall back


def test_carbon_policy_validation():
    sig = ConstantCarbon(100.0)
    with pytest.raises(ValueError):
        CarbonPolicy(sig, check_interval_s=0.0)
    with pytest.raises(ValueError):
        CarbonPolicy(sig, preempt_threshold=-1.0)
    with pytest.raises(ValueError):
        CarbonPolicy(sig, preempt_threshold=float("nan"))
    with pytest.raises(ValueError):
        CarbonPolicy(sig, defer_threshold=float("nan"))
    CarbonPolicy(sig)                                 # inf = deferral off
    assert carbon_grams(J_PER_KWH, 400.0) == 400.0    # 1 kWh at 400 g/kWh


# --- criteria / weighting ----------------------------------------------------
def test_carbon_criteria_and_weights():
    crits = greenpod_criteria(carbon=True)
    assert len(crits) == 6 and crits[-1] is CARBON_CRITERION
    assert not CARBON_CRITERION.benefit                  # a cost criterion
    mask = benefit_mask(crits)
    np.testing.assert_array_equal(mask[:5], benefit_mask())
    assert not mask[5]
    # paper schemes pad a zero carbon weight; carbon schemes are 6-long
    for s in SCHEME_NAMES:
        w6 = weights_for(s, carbon=True)
        assert w6.shape == (6,) and w6[5] == 0.0
        np.testing.assert_allclose(w6[:5], weights_for(s))
    for s in CARBON_SCHEME_NAMES:
        w = weights_for(s)
        assert w.shape == (6,) and w[5] > 0.0
        assert abs(w.sum() - 1.0) < 1e-9
    with pytest.raises(ValueError):
        weights_for("nope", carbon=True)
    # adaptive: energy weight shifts, carbon weight untouched
    w_idle = adaptive_weights("carbon_centric", 0.0)
    w_full = adaptive_weights("carbon_centric", 1.0)
    assert w_full[1] < w_idle[1]
    assert abs(w_full[5] / w_full.sum() - w_idle[5]) < 0.05


def test_carbon_scheme_requires_signal():
    with pytest.raises(ValueError):
        GreenPodScheduler("carbon_centric")
    with pytest.raises(ValueError):
        BatchScheduler("carbon_energy_balanced")
    # fine with a signal
    GreenPodScheduler("carbon_centric", carbon_signal=ConstantCarbon())
    BatchScheduler("carbon_centric", carbon_signal=ConstantCarbon())


# --- decision matrix ---------------------------------------------------------
def test_decision_matrix_carbon_column():
    nodes = make_paper_cluster()
    nodes[1].bind(0.5, 1.0)                   # node B awake
    table = NodeTable.from_nodes(nodes)
    pod = Pod(0, WORKLOADS["medium"], "topsis")
    inten = np.array([100.0, 200.0, 300.0, 400.0])
    M = decision_matrix(pod, table, carbon_intensity=inten)
    assert M.shape == (4, 6)
    np.testing.assert_allclose(M[:, :5], decision_matrix(pod, table))
    for i in range(4):
        power = (table.dyn_power_per_vcpu[i] * pod.cpu
                 + (0.0 if table.awake[i] else table.idle_power[i]))
        assert abs(M[i, 5] - power * inten[i]) < 1e-12
    # batch rows match the single-pod matrix
    pods = [pod, Pod(1, WORKLOADS["light"], "topsis")]
    B = decision_matrix_batch(pods, table, carbon_intensity=inten)
    assert B.shape == (2, 4, 6)
    for i, p in enumerate(pods):
        np.testing.assert_allclose(
            B[i], decision_matrix(p, table, carbon_intensity=inten),
            rtol=0, atol=0)


# --- zero-weight equivalence across backends (satellite property test) -------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n=st.sampled_from((4, 64, 257)),
       p=st.integers(1, 6), util=st.floats(0.0, 0.8),
       t=st.floats(0.0, 5000.0))
def test_property_zero_carbon_weight_matches_legacy_5criteria(seed, n, p,
                                                              util, t):
    """With the carbon criterion at zero weight, 6-criteria closeness on
    every backend matches the legacy 5-criteria ``closeness_np`` at 1e-5
    over randomized fleets, queues, and decision times."""
    rng = np.random.default_rng(seed)
    table = make_fleet(n, seed=seed, utilization=util)
    kinds = list(WORKLOADS)
    pods = [Pod(i, WORKLOADS[kinds[int(rng.integers(len(kinds)))]], "topsis")
            for i in range(p)]
    legacy = BatchScheduler("energy_centric",
                            backend="numpy").score_queue(pods, table)
    sig = diurnal_fleet_signal(period_s=1800.0)
    for backend in ("numpy", "jax", "pallas"):
        got = BatchScheduler("energy_centric", backend=backend,
                             carbon_signal=sig).score_queue(pods, table,
                                                            now=t)
        finite = np.isfinite(legacy)
        np.testing.assert_array_equal(finite, np.isfinite(got))
        np.testing.assert_allclose(got[finite], legacy[finite], atol=1e-5)


def test_table6_still_matches_golden_bitwise():
    """The carbon stack leaves paper mode untouched: table6() equals the
    recorded pre-refactor golden exactly (bitwise through the JSON
    round-trip), not merely within tolerance."""
    t6 = table6()
    for level, d in GOLDEN["table6"].items():
        for scheme, vals in d.items():
            for key, want in vals.items():
                assert t6[level][scheme][key] == want, (level, scheme, key)


def test_zero_weight_scenario_reproduces_carbon_free_engine_bitwise():
    """energy_centric with a signal attached (zero carbon weight, no
    thresholds): identical placements and bitwise-identical energy totals
    to the carbon-free engine on a Poisson scenario."""
    arrivals = lambda: PoissonArrivals(rate_per_s=0.3, n_bursts=4,
                                       burst_size=6, seed=5)
    factory = lambda: make_scenario_cluster("mixed", 16, seed=2)
    plain = run_scenario(arrivals(), "energy_centric",
                         cluster_factory=factory, batch=True,
                         batch_backend="numpy")
    carbon = run_scenario(arrivals(), "energy_centric",
                          cluster_factory=factory, batch=True,
                          batch_backend="numpy",
                          carbon=CarbonPolicy(diurnal_fleet_signal()))
    assert [r.node for r in plain.records] \
        == [r.node for r in carbon.records]
    for s in ("topsis", "default"):
        assert plain.energy_kj(s) == carbon.energy_kj(s)
    # and the carbon run can account carbon; the plain one cannot
    assert carbon.total_carbon_g("topsis") > 0.0
    with pytest.raises(ValueError):
        plain.total_carbon_g("topsis")


# --- carbon steering ---------------------------------------------------------
def test_carbon_rate_criterion_steers_to_clean_region():
    """All else equal (twin nodes), full carbon weight places on the node
    in the currently-cleanest region."""
    sig = ConstantCarbon(500.0, per_region={"clean": 50.0})
    nodes = [Node("dirty-0", "B", 4, 8, region="dirty"),
             Node("clean-0", "B", 4, 8, region="clean")]
    s = GreenPodScheduler("carbon_centric", carbon_signal=sig)
    idx, _ = s.select(Pod(0, WORKLOADS["medium"], "topsis"), nodes)
    assert nodes[idx].region == "clean"


def test_carbon_centric_reduces_carbon_on_sinusoidal_mixed_scenario():
    """The acceptance invariant at test scale: carbon_centric emits less
    than energy_centric under the staggered sinusoidal signal on a mixed
    fleet (spatial shifting toward clean regions)."""
    sig = diurnal_fleet_signal(period_s=1800.0, phase_s=450.0,
                               stagger_s=112.5)
    policy = CarbonPolicy(sig)
    arrivals = lambda: PoissonArrivals(rate_per_s=0.3, n_bursts=4,
                                       burst_size=6, seed=5)
    factory = lambda: make_scenario_cluster("mixed", 16, seed=2)
    run = lambda scheme: run_scenario(arrivals(), scheme,
                                      cluster_factory=factory, batch=True,
                                      batch_backend="numpy", carbon=policy)
    assert (run("carbon_centric").total_carbon_g("topsis")
            < run("energy_centric").total_carbon_g("topsis"))


# --- deferral events ---------------------------------------------------------
def _one_pod_trace(deadline_s, kind="light"):
    return TraceArrivals([{"t": 0.0, "kind": kind, "scheduler": "topsis",
                           "deferrable": True, "deadline_s": deadline_s}])


def test_deferrable_pod_waits_for_dip():
    """High intensity until t=90, then a dip: the deferrable pod schedules
    at the first carbon-check wake at/after the dip, not at arrival."""
    sig = TraceCarbon([{"t": 0.0, "intensity": 500.0},
                       {"t": 90.0, "intensity": 100.0}])
    res = run_scenario(_one_pod_trace(500.0), "energy_centric",
                       carbon=CarbonPolicy(sig, defer_threshold=300.0,
                                           check_interval_s=30.0))
    assert len(res.records) == 1 and res.unschedulable == 0
    assert res.records[0].start_s == 90.0
    assert res.mean_deferral_latency_s() == 90.0


def test_deferred_pod_never_schedules_past_deadline():
    """A never-dipping signal: the pod starts exactly at its deadline —
    even when the check interval does not divide it."""
    sig = ConstantCarbon(500.0)
    for deadline, interval in ((77.0, 30.0), (120.0, 45.0)):
        res = run_scenario(_one_pod_trace(deadline), "energy_centric",
                           carbon=CarbonPolicy(sig, defer_threshold=300.0,
                                               check_interval_s=interval))
        assert len(res.records) == 1 and res.unschedulable == 0
        assert res.records[0].start_s == deadline
    # non-deferrable pods are untouched by the same policy
    res = run_scenario(
        TraceArrivals([{"t": 0.0, "kind": "light", "scheduler": "topsis"}]),
        "energy_centric",
        carbon=CarbonPolicy(sig, defer_threshold=300.0))
    assert res.records[0].start_s == 0.0


def test_deferral_works_in_batch_mode():
    sig = TraceCarbon([{"t": 0.0, "intensity": 500.0},
                       {"t": 60.0, "intensity": 100.0}])
    res = run_scenario(
        TraceArrivals([{"t": 0.0, "kind": "light", "scheduler": "topsis",
                        "deferrable": True, "deadline_s": 300.0,
                        "count": 3}]),
        "energy_centric", batch=True, batch_backend="numpy",
        carbon=CarbonPolicy(sig, defer_threshold=300.0,
                            check_interval_s=20.0))
    assert len(res.records) == 3
    assert all(r.start_s == 60.0 for r in res.records)


def test_deferrable_pod_with_non_finite_deadline_rejected():
    """The engine rejects a deferrable pod with an unbounded deadline up
    front (an infinite deadline under a never-dipping signal would spin
    the wake loop forever). TraceArrivals/PoissonArrivals already validate
    this; the engine guards custom ArrivalProcess implementations too."""
    class RoguePods:
        def events(self):
            return [(0.0, [Pod(0, WORKLOADS["light"], "topsis",
                               deferrable=True, deadline_s=math.inf)])]
    with pytest.raises(ValueError, match="finite positive deadline"):
        run_scenario(RoguePods(), "energy_centric",
                     carbon=CarbonPolicy(ConstantCarbon(500.0),
                                         defer_threshold=300.0))
    # without a carbon policy the field is inert and nothing raises
    res = run_scenario(RoguePods(), "energy_centric")
    assert len(res.records) == 1


def test_deferral_latency_zero_when_signal_is_low():
    sig = ConstantCarbon(100.0)
    res = run_scenario(_one_pod_trace(500.0), "energy_centric",
                       carbon=CarbonPolicy(sig, defer_threshold=300.0))
    assert res.records[0].start_s == 0.0
    assert res.mean_deferral_latency_s() == 0.0


# --- preemption events -------------------------------------------------------
def _two_region_cluster():
    return [Node("na", "A", 4, 8, region="ra"),
            Node("nb", "B", 4, 8, region="rb")]


def test_preemption_splits_energy_interval():
    """A spike on the running node's region at t=30 evicts the deferrable
    task; its PowerTimeline segment is truncated at 30 and the requeued
    run appends a second segment — energy intervals split exactly."""
    sig = TraceCarbon([{"t": 0.0, "intensity": 100.0, "region": "ra"},
                       {"t": 0.0, "intensity": 100.0, "region": "rb"},
                       {"t": 30.0, "intensity": 900.0, "region": "rb"}])
    res = run_scenario(
        _one_pod_trace(600.0, kind="medium"), "energy_centric",
        cluster_factory=_two_region_cluster,
        carbon=CarbonPolicy(sig, defer_threshold=1000.0,
                            preempt_threshold=400.0, check_interval_s=10.0))
    assert res.preemptions == 1
    assert len(res.records) == 2             # partial run + requeued run
    first, second = res.records
    assert first.pod.uid == second.pod.uid
    assert first.start_s == 0.0 and first.runtime_s == 30.0
    # a carbon-blind scheme would restart on the same node at the same
    # instant; the engine blocks that for the eviction round, so the rerun
    # lands at the next carbon-check wake (t = 30 + interval)
    assert second.start_s == 40.0
    # the timeline's dynamic energy is the sum of both split intervals
    segs = res.timeline.segments
    assert len(segs) == 2
    assert segs[0].runtime_s == 30.0
    assert abs(segs[0].energy_j - segs[0].dyn_power_w * 30.0) < 1e-12
    want = segs[0].energy_j + segs[1].energy_j
    assert abs(res.timeline.dynamic_energy_j("topsis") - want) < 1e-12
    assert abs(first.energy_j - segs[0].energy_j) < 1e-12
    # busy intervals reflect the truncation (no phantom occupancy past 30
    # on the first attempt's interval)
    ivs = res.timeline.busy_intervals("topsis")
    assert sorted(sum(ivs.values(), []))[0] == (0.0, 30.0)


def test_preemption_migrates_under_carbon_weights():
    """With carbon weight, the evicted task re-places onto the clean
    region's node (migration), and only once (no ping-pong). Twin nodes
    (identical power draw) so the carbon-rate column is decided purely by
    regional intensity: the pod starts on the momentarily-cleaner region,
    which then spikes."""
    sig = TraceCarbon([{"t": 0.0, "intensity": 100.0, "region": "ra"},
                       {"t": 0.0, "intensity": 90.0, "region": "rb"},
                       {"t": 30.0, "intensity": 900.0, "region": "rb"}])
    twins = lambda: [Node("na", "B", 4, 8, region="ra"),
                     Node("nb", "B", 4, 8, region="rb")]
    res = run_scenario(
        _one_pod_trace(600.0, kind="medium"), "carbon_centric",
        cluster_factory=twins,
        carbon=CarbonPolicy(sig, defer_threshold=1000.0,
                            preempt_threshold=400.0, check_interval_s=10.0))
    assert res.preemptions == 1
    assert len(res.records) == 2
    assert res.records[0].node == "nb"       # started on the cheap-and-clean
    assert res.records[1].node == "na"       # migrated off the spike
    assert res.unschedulable == 0


def test_select_many_blocked_node_falls_through_without_ledger_charge():
    """A blocked top choice is skipped inside the greedy ledger (no
    phantom capacity charge): the blocked pod takes its next-ranked node,
    and a second pod wanting the blocked pod's top node still gets it."""
    nodes = [Node("a-0", "A", vcpus=4, mem_gb=16),
             Node("b-small", "B", vcpus=1.2, mem_gb=2.5),   # fits one complex
             Node("c-0", "C", vcpus=8, mem_gb=32)]
    table = NodeTable.from_nodes(nodes)
    pods = [Pod(0, WORKLOADS["complex"], "topsis"),
            Pod(1, WORKLOADS["complex"], "topsis")]
    sched = BatchScheduler("energy_centric", backend="numpy")
    base, diag = sched.select_many(pods, table)
    top = int(np.argmax(diag["closeness"][0]))
    assert base[0] == top == 1          # both rank b-small first; pod 0 wins
    # block pod 0 from its top node: pod 0 falls through to its next-ranked
    # node, and pod 1 — no longer beaten to it — now takes b-small
    blocked_asn, d2 = sched.select_many(pods, table, blocked=[top, None])
    assert blocked_asn[0] != top and blocked_asn[0] is not None
    assert blocked_asn[0] == int(np.argsort(-d2["closeness"][0],
                                            kind="stable")[1])
    assert blocked_asn[1] == top


def test_no_preemption_without_threshold_or_for_non_deferrable():
    sig = TraceCarbon([{"t": 0.0, "intensity": 100.0},
                       {"t": 10.0, "intensity": 900.0}])
    # threshold unset
    res = run_scenario(_one_pod_trace(600.0, kind="medium"),
                       "energy_centric",
                       carbon=CarbonPolicy(sig, defer_threshold=1000.0))
    assert res.preemptions == 0 and len(res.records) == 1
    # non-deferrable task under a spiking signal with preemption on
    res = run_scenario(
        TraceArrivals([{"t": 0.0, "kind": "medium", "scheduler": "topsis"}]),
        "energy_centric",
        carbon=CarbonPolicy(sig, defer_threshold=1000.0,
                            preempt_threshold=400.0, check_interval_s=5.0))
    assert res.preemptions == 0 and len(res.records) == 1
    assert res.records[0].runtime_s > 30.0   # ran to completion


# --- timeline carbon accounting ----------------------------------------------
def test_timeline_carbon_constant_signal_matches_energy():
    """Under a flat signal, carbon is exactly energy x intensity / 3.6e6
    (dynamic + idle), and the series integrates to the total."""
    tl = PowerTimeline(carbon_signal=ConstantCarbon(400.0),
                       node_region={"n0": "default"})
    tl.add("n0", "A", "topsis", 0.0, 10.0, 3.0)
    tl.add("n0", "A", "topsis", 5.0, 10.0, 2.0)
    energy_j = tl.dynamic_energy_j("topsis") + tl.idle_energy_j("topsis")
    want = carbon_grams(energy_j, 400.0)
    assert abs(tl.total_carbon_g("topsis") - want) < 1e-12
    edges, grams = tl.carbon_series("topsis")
    assert grams[0] == 0.0
    assert abs(grams[-1] - want) < 1e-9
    assert np.all(np.diff(grams) >= -1e-12)


def test_timeline_carbon_time_varying_signal():
    """A step signal weights late energy more: two identical segments, the
    later one in the expensive window, carbon ratio follows the step."""
    sig = TraceCarbon([{"t": 0.0, "intensity": 100.0},
                       {"t": 10.0, "intensity": 300.0}])
    tl = PowerTimeline(carbon_signal=sig, node_region={"n0": "default"})
    tl.add("n0", "A", "topsis", 0.0, 10.0, 5.0)     # cheap window
    tl.add("n0", "A", "topsis", 10.0, 10.0, 5.0)    # 3x window
    idle = NODE_ENERGY_PROFILES["A"]["idle_power"]
    per_w = (5.0 + idle)                             # constant power draw
    want = (per_w * 100.0 * 10.0 + per_w * 300.0 * 10.0) / J_PER_KWH
    assert abs(tl.total_carbon_g("topsis") - want) < 1e-12
    # region mapping: an unmapped node uses the trace's default series
    assert tl.region_of("n0") == "default"


def test_scenario_carbon_series_consistent_with_total():
    res = run_scenario(
        PoissonArrivals(rate_per_s=0.3, n_bursts=4, burst_size=6, seed=5),
        "carbon_energy_balanced",
        cluster_factory=lambda: make_scenario_cluster("mixed", 16, seed=2),
        batch=True, batch_backend="numpy",
        carbon=CarbonPolicy(diurnal_fleet_signal(period_s=1800.0)))
    for sched in ("topsis", "default", None):
        total = res.total_carbon_g(sched)
        edges, grams = res.carbon_series(sched)
        assert abs(grams[-1] - total) < 1e-9 * max(total, 1.0)
        assert np.all(np.diff(grams) >= -1e-12)
        assert np.all(np.diff(edges) > 0)


def test_poisson_deferrable_share():
    """At share 0.0 (default) no extra RNG draws happen, so pre-carbon
    streams replay bitwise; at share 1.0 every pod is tagged (still
    deterministic per seed)."""
    base = PoissonArrivals(rate_per_s=0.5, n_bursts=4, burst_size=6, seed=3)
    assert all(not p.deferrable for _, pods in base.events() for p in pods)
    tagged = PoissonArrivals(rate_per_s=0.5, n_bursts=4, burst_size=6,
                             seed=3, deferrable_share=1.0, deadline_s=99.0)
    for _, pods in tagged.events():
        assert all(p.deferrable and p.deadline_s == 99.0 for p in pods)
    # burst 1 precedes any per-pod draw, so its epoch is share-invariant
    assert base.events()[0][0] == tagged.events()[0][0]
    # deterministic replay with the extra draws in the stream
    assert ([t for t, _ in tagged.events()]
            == [t for t, _ in PoissonArrivals(
                rate_per_s=0.5, n_bursts=4, burst_size=6, seed=3,
                deferrable_share=1.0, deadline_s=99.0).events()])
    with pytest.raises(ValueError):
        PoissonArrivals(deferrable_share=1.5)
    with pytest.raises(ValueError):
        PoissonArrivals(deferrable_share=0.5, deadline_s=float("inf"))


# --- region plumbing ---------------------------------------------------------
def test_region_columns():
    nodes = [Node("x", "A", 2, 4, region="eu-west"), Node("y", "B", 2, 8)]
    table = NodeTable.from_nodes(nodes)
    assert table.region == ["eu-west", "default"]
    # synthetic fleets spread regions round-robin, deterministically
    t1 = make_fleet(8, seed=0)
    t2 = make_fleet(8, seed=0)
    assert t1.region == t2.region and len(set(t1.region)) == 4
    cl = make_scenario_cluster("mixed", 8, seed=0)
    assert [n.region for n in cl] == t1.region
    # paper cluster keeps the single default region
    assert all(n.region == "default" for n in make_paper_cluster())


def test_backends_agree_on_carbon_scenario():
    """numpy and jax batched backends place a carbon-weighted scenario
    identically (the carbon column is backend-invariant)."""
    sig = diurnal_fleet_signal(period_s=1800.0)
    runs = {}
    for backend in ("numpy", "jax"):
        runs[backend] = run_scenario(
            PoissonArrivals(rate_per_s=0.3, n_bursts=4, burst_size=6,
                            seed=5, deferrable_share=0.5, deadline_s=400.0),
            "carbon_centric",
            cluster_factory=lambda: make_scenario_cluster("mixed", 16,
                                                          seed=2),
            batch=True, batch_backend=backend,
            carbon=CarbonPolicy(sig, defer_threshold=300.0,
                                check_interval_s=30.0))
    assert ([r.node for r in runs["numpy"].records]
            == [r.node for r in runs["jax"].records])
    assert abs(runs["numpy"].total_carbon_g("topsis")
               - runs["jax"].total_carbon_g("topsis")) < 1e-9

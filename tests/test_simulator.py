"""Cluster simulator: reproduces the paper's factorial experiment trends."""
import numpy as np
import pytest

from repro.cluster.simulator import run_experiment, table6

# Paper Table VI optimization percentages.
PAPER_TABLE6 = {
    ("low", "general"): 8.93, ("low", "energy_centric"): 37.96,
    ("low", "performance_centric"): 2.22, ("low", "resource_efficient"): 26.80,
    ("medium", "general"): 16.57, ("medium", "energy_centric"): 39.13,
    ("medium", "performance_centric"): 7.72,
    ("medium", "resource_efficient"): 32.70,
    ("high", "general"): 13.50, ("high", "energy_centric"): 33.82,
    ("high", "performance_centric"): 8.29,
    ("high", "resource_efficient"): 4.86,
}


@pytest.fixture(scope="module")
def t6():
    return table6()


def test_all_pods_scheduled():
    for level in ("low", "medium", "high"):
        res = run_experiment(level, "energy_centric")
        assert res.unschedulable == 0
        n_expected = {"low": 8, "medium": 14, "high": 22}[level]
        assert len(res.records) == n_expected


def test_energy_accounting_positive(t6):
    for level, d in t6.items():
        for scheme, v in d.items():
            assert v["default_kj"] > 0 and v["topsis_kj"] > 0


def test_energy_centric_beats_default_everywhere(t6):
    """Headline claim: energy-centric TOPSIS saves energy at every
    competition level (37.96/39.13/33.82 % in the paper)."""
    for level in ("low", "medium", "high"):
        assert t6[level]["energy_centric"]["optimization_pct"] > 20


def test_energy_centric_is_best_profile(t6):
    for level in ("low", "medium", "high"):
        e = t6[level]["energy_centric"]["optimization_pct"]
        for scheme, v in t6[level].items():
            assert e >= v["optimization_pct"] - 1e-9


def test_performance_centric_is_worst_profile(t6):
    """Paper §V.B: performance-centric has the lowest savings everywhere."""
    for level in ("low", "medium", "high"):
        p = t6[level]["performance_centric"]["optimization_pct"]
        for scheme, v in t6[level].items():
            assert p <= v["optimization_pct"] + 1e-9


def test_medium_competition_is_sweet_spot(t6):
    """Paper §V.C: medium competition gives the best average optimization."""
    avg = {lvl: np.mean([v["optimization_pct"] for v in d.values()])
           for lvl, d in t6.items()}
    assert avg["medium"] > avg["low"]
    assert avg["medium"] > avg["high"]


def test_matches_paper_energy_centric_within_tolerance(t6):
    """Quantitative match of the headline numbers (calibrated default
    column; TOPSIS column is a prediction — see EXPERIMENTS.md §Repro)."""
    for level in ("low", "medium", "high"):
        ours = t6[level]["energy_centric"]["optimization_pct"]
        paper = PAPER_TABLE6[(level, "energy_centric")]
        assert abs(ours - paper) < 8.0, (level, ours, paper)


def test_energy_centric_allocates_to_class_a():
    """Paper §V.D: energy-centric prefers category-A (frugal) nodes."""
    res = run_experiment("medium", "energy_centric")
    alloc = res.allocation("topsis")
    assert alloc.get("A", 0) >= max(alloc.values()) - 1


def test_scheduling_overhead_small():
    """Paper: 'minimal scheduling overhead' — TOPSIS adds < 5 ms/pod here."""
    res = run_experiment("high", "energy_centric")
    assert res.mean_sched_time_ms("topsis") < 5.0


def test_deterministic():
    a = run_experiment("medium", "general")
    b = run_experiment("medium", "general")
    assert [r.node for r in a.records] == [r.node for r in b.records]
    assert a.energy_kj("topsis") == b.energy_kj("topsis")

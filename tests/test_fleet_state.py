"""FleetState delta maintenance: the dirty-column contract against the
full-rebuild oracle, incremental scoring equivalence on every backend, and
burst routing by scheduler group.

The tentpole invariant: after ANY interleaving of commit/release/evict/
sleep/wake mutations, the delta-maintained columns are bitwise-equal to a
fresh ``NodeTable.from_nodes`` rebuild of the same Node objects, and the
incremental criteria cache scores bitwise (numpy) / within 1e-5 (float32
backends) of the full-rebuild scoring path kept verbatim in
``BatchScheduler.score_queue``.
"""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    def settings(*args, **kwargs):
        def wrap(f):
            return f
        return wrap

    def given(*args, **kwargs):
        def wrap(f):
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.cluster.engine import EventEngine, SimState
from repro.cluster.node import FleetState, NodeTable, make_fleet_nodes
from repro.cluster.workload import WORKLOADS, ArrivalProcess, Pod
from repro.core.carbon import diurnal_fleet_signal
from repro.core.elastic import ASLEEP, IDLE
from repro.core.energy import PowerTimeline
from repro.core.scheduler import (BatchScheduler, DefaultK8sScheduler,
                                  GreenPodScheduler)

BACKENDS = ("numpy", "jax", "pallas")

# the op alphabet of the property tests: every mutator the event engine
# drives through FleetState (commit=bind, completion=release, evict, and
# the elastic sleep/wake power-state transitions)
OPS = st.lists(
    st.tuples(st.sampled_from(["bind", "release", "evict", "sleep", "wake"]),
              st.integers(0, 2**16), st.integers(0, 2**16)),
    max_size=60)


def _apply(fs: FleetState, ops, bound=None) -> None:
    """Replay an op word against the fleet, keeping it physically valid:
    binds honor capacity, releases/evicts pop an outstanding bind (release
    the newest, evict the oldest — both are column releases; the engine
    only differs in requeue bookkeeping)."""
    n = len(fs)
    bound = bound if bound is not None else []
    for kind, a, b in ops:
        i = a % n
        if kind == "bind":
            cpu = 0.25 * (1 + b % 8)
            mem = 0.5 * (1 + b % 4)
            if fs.free_cpu[i] >= cpu and fs.free_mem[i] >= mem:
                fs.bind(i, cpu, mem)
                bound.append((i, cpu, mem))
        elif kind == "release" and bound:
            fs.release(*bound.pop())
        elif kind == "evict" and bound:
            fs.release(*bound.pop(0))
        elif kind == "sleep":
            states = list(fs.power_state)
            states[i] = ASLEEP
            fs.set_power_states(states)
        elif kind == "wake":
            states = list(fs.power_state)
            states[i] = IDLE
            fs.set_power_states(states)


def _queue(n_pods: int = 6) -> list[Pod]:
    uid = itertools.count()
    kinds = itertools.cycle(["light", "medium", "complex"])
    return [Pod(next(uid), WORKLOADS[next(kinds)], "topsis")
            for _ in range(n_pods)]


# --- the dirty-column contract ----------------------------------------------
@settings(deadline=None, max_examples=60)
@given(ops=OPS, seed=st.integers(0, 3))
def test_columns_bitwise_equal_fresh_rebuild(ops, seed):
    """Any commit/release/evict/sleep/wake interleaving leaves every
    delta-maintained column bitwise-equal to NodeTable.from_nodes over the
    same Node objects — the rebuild the engine used to pay per round."""
    fs = FleetState.from_nodes(make_fleet_nodes(12, seed=seed,
                                                utilization=0.25))
    _apply(fs, ops)
    ref = NodeTable.from_nodes(fs.nodes)
    np.testing.assert_array_equal(fs.used_cpu, ref.used_cpu)
    np.testing.assert_array_equal(fs.used_mem, ref.used_mem)
    np.testing.assert_array_equal(fs.awake, ref.awake)
    assert list(fs.power_state) == list(ref.power_state)
    np.testing.assert_array_equal(fs.free_cpu, ref.free_cpu)
    np.testing.assert_array_equal(fs.free_mem, ref.free_mem)


def test_columns_equal_rebuild_seeded():
    """Deterministic twin of the column property (runs without
    hypothesis)."""
    rng = np.random.default_rng(11)
    kinds = ["bind", "release", "evict", "sleep", "wake"]
    for seed in range(4):
        ops = [(kinds[int(rng.integers(len(kinds)))],
                int(rng.integers(2**16)), int(rng.integers(2**16)))
               for _ in range(50)]
        fs = FleetState.from_nodes(make_fleet_nodes(12, seed=seed,
                                                    utilization=0.25))
        _apply(fs, ops)
        ref = NodeTable.from_nodes(fs.nodes)
        np.testing.assert_array_equal(fs.used_cpu, ref.used_cpu)
        np.testing.assert_array_equal(fs.used_mem, ref.used_mem)
        np.testing.assert_array_equal(fs.awake, ref.awake)
        assert list(fs.power_state) == list(ref.power_state)


def test_modified_since_is_a_multi_consumer_cursor():
    """Each consumer holds its own version cursor; older cursors keep
    seeing the union of everything touched since."""
    fs = FleetState.from_nodes(make_fleet_nodes(8, seed=0))
    v0 = fs.version
    fs.bind(3, 1.0, 2.0)
    fs.bind(5, 1.0, 2.0)
    assert set(fs.modified_since(v0)) == {3, 5}
    v1 = fs.version
    fs.release(3, 1.0, 2.0)
    assert set(fs.modified_since(v1)) == {3}
    assert set(fs.modified_since(v0)) == {3, 5}
    assert fs.modified_since(fs.version).size == 0
    # a no-op power-state write must not dirty anything
    v2 = fs.version
    fs.set_power_states(list(fs.power_state))
    assert fs.version == v2


# --- incremental scoring vs the full-rebuild oracle --------------------------
def _check_incremental_vs_oracle(fs, ops, backend):
    """Interleave mutation bursts with scoring rounds: the attached
    (incremental) scheduler must agree with a detached scheduler scoring a
    fresh NodeTable rebuild — bitwise on numpy (same float64 arithmetic),
    1e-5 on the float32 jax/pallas backends — with identical -inf
    feasibility patterns."""
    inc = BatchScheduler("energy_centric", backend=backend)
    inc.attach(fs)
    oracle = BatchScheduler("energy_centric", backend=backend)
    pods = _queue()
    bound = []
    step = max(1, len(ops) // 3)
    for lo in range(0, len(ops) + 1, step):
        _apply(fs, ops[lo:lo + step], bound)
        cc_inc = inc.score_queue(pods, fs, now=0.0)
        cc_ref = oracle.score_queue(pods, NodeTable.from_nodes(fs.nodes),
                                    now=0.0)
        np.testing.assert_array_equal(np.isneginf(cc_inc),
                                      np.isneginf(cc_ref))
        finite = np.isfinite(cc_ref)
        if backend == "numpy":
            np.testing.assert_array_equal(cc_inc, cc_ref)
        else:
            np.testing.assert_allclose(cc_inc[finite], cc_ref[finite],
                                       atol=1e-5, rtol=0)


@settings(deadline=None, max_examples=8)
@given(ops=OPS, backend=st.sampled_from(BACKENDS))
def test_incremental_scores_match_rebuild_oracle(ops, backend):
    fs = FleetState.from_nodes(make_fleet_nodes(24, seed=1, utilization=0.3))
    _check_incremental_vs_oracle(fs, ops, backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_incremental_scores_match_rebuild_oracle_seeded(backend):
    """Deterministic twin of the property test (runs even without
    hypothesis): seeded random op words through the same oracle check."""
    rng = np.random.default_rng(7)
    kinds = ["bind", "release", "evict", "sleep", "wake"]
    for seed in range(3):
        ops = [(kinds[int(rng.integers(len(kinds)))],
                int(rng.integers(2**16)), int(rng.integers(2**16)))
               for _ in range(40)]
        fs = FleetState.from_nodes(make_fleet_nodes(24, seed=seed,
                                                    utilization=0.3))
        _check_incremental_vs_oracle(fs, ops, backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_incremental_carbon_column_tracks_decision_time(backend):
    """With a carbon signal the cached carbon column must follow ``now``:
    scoring at a later instant refreshes intensity x power for ALL nodes,
    not just dirty ones, and still matches the rebuild oracle."""
    sig = diurnal_fleet_signal(base=300.0, amplitude=200.0, period_s=3600.0)
    fs = FleetState.from_nodes(make_fleet_nodes(16, seed=2, utilization=0.2))
    inc = BatchScheduler("energy_centric", backend=backend,
                         carbon_signal=sig)
    inc.attach(fs)
    oracle = BatchScheduler("energy_centric", backend=backend,
                            carbon_signal=sig)
    pods = _queue(4)
    for now in (0.0, 0.0, 617.3, 1805.0):   # repeat: carbon_moved=False leg
        fs.bind(3, 0.5, 1.0)
        cc_inc = inc.score_queue(pods, fs, now=now)
        cc_ref = oracle.score_queue(pods, NodeTable.from_nodes(fs.nodes),
                                    now=now)
        finite = np.isfinite(cc_ref)
        np.testing.assert_array_equal(np.isneginf(cc_inc),
                                      np.isneginf(cc_ref))
        if backend == "numpy":
            np.testing.assert_array_equal(cc_inc, cc_ref)
        else:
            np.testing.assert_allclose(cc_inc[finite], cc_ref[finite],
                                       atol=1e-5, rtol=0)
        fs.release(3, 0.5, 1.0)


def test_attached_per_pod_scheduler_matches_detached():
    """GreenPodScheduler's cached select agrees with the detached
    rebuild-per-call form after fleet mutations (same index, same scores)."""
    fs = FleetState.from_nodes(make_fleet_nodes(20, seed=3, utilization=0.4))
    inc = GreenPodScheduler("energy_centric")
    inc.attach(fs)
    det = GreenPodScheduler("energy_centric")
    pod = Pod(0, WORKLOADS["medium"], "topsis")
    for i in (7, 11, 13):
        if fs.free_cpu[i] >= 0.5 and fs.free_mem[i] >= 1.0:
            fs.bind(i, 0.5, 1.0)
        idx_i, diag_i = inc.select(pod, fs)
        idx_d, diag_d = det.select(pod, NodeTable.from_nodes(fs.nodes))
        assert idx_i == idx_d
        np.testing.assert_array_equal(diag_i["closeness"],
                                      diag_d["closeness"])


# --- burst routing by scheduler group (engine regression) --------------------
class _SpyBatch(BatchScheduler):
    """BatchScheduler that records which pods it was asked to place."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.seen: list[int] = []

    def select_many(self, pods, nodes, now=0.0, blocked=None, exclude=None):
        self.seen.extend(p.uid for p in pods)
        return super().select_many(pods, nodes, now=now, blocked=blocked,
                                   exclude=exclude)


class _OneBurst(ArrivalProcess):
    def __init__(self, pods):
        self._pods = list(pods)

    def events(self):
        return [(0.0, self._pods)]


def test_burst_routing_by_scheduler_group():
    """Regression: the burst path used to hardcode schedulers["topsis"],
    so in a mixed queue every batch-capable group's pods were scored (and
    logged) by the wrong engine. Bursts must group by ``pod.scheduler``
    and each group must flow through its own ``select_many``."""
    fleet = FleetState.from_nodes(make_fleet_nodes(8, seed=0))
    a = _SpyBatch("energy_centric", backend="numpy")
    b = _SpyBatch("energy_centric", backend="numpy")
    schedulers = {"topsis": a, "alt": b, "default": DefaultK8sScheduler()}
    for sched in (a, b):
        sched.attach(fleet)
    uid = itertools.count()
    pods = [Pod(next(uid), WORKLOADS["light"], s)
            for s in ("topsis", "alt", "topsis", "alt", "topsis")]
    state = SimState(fleet=fleet, schedulers=schedulers,
                     timeline=PowerTimeline())
    res = EventEngine(state, (), _OneBurst(pods), batch=True).run()
    assert a.seen == [0, 2, 4]
    assert b.seen == [1, 3]
    assert len(res.records) == len(pods)
    # and each engine's decision log only carries its own group's pods
    assert {d["pod"] for d in a.decision_log} == {0, 2, 4}
    assert {d["pod"] for d in b.decision_log} == {1, 3}

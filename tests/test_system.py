"""System-level sanity: public API surface + end-to-end quickstart flow."""
import importlib

import jax
import numpy as np


def test_all_modules_import():
    mods = [
        "repro.core.topsis", "repro.core.criteria", "repro.core.weighting",
        "repro.core.energy", "repro.core.scheduler",
        "repro.cluster.node", "repro.cluster.workload",
        "repro.cluster.simulator",
        "repro.models.config", "repro.models.layers", "repro.models.moe",
        "repro.models.mamba2", "repro.models.rwkv6", "repro.models.lm",
        "repro.sharding.rules", "repro.optim.adamw", "repro.optim.compress",
        "repro.data.pipeline", "repro.train.loop", "repro.train.checkpoint",
        "repro.train.fault", "repro.serve.engine",
        "repro.kernels.ref", "repro.kernels.ops",
        "repro.configs.registry", "repro.launch.mesh", "repro.launch.specs",
        "repro.launch.hlo_analysis", "repro.launch.fleet",
    ]
    for m in mods:
        importlib.import_module(m)


def test_registry_covers_all_assigned_archs():
    from repro.configs import registry
    assert len(registry.ARCH_IDS) == 10
    for alias in registry.ALIASES:
        cfg = registry.config(alias)
        smoke = registry.smoke_config(alias)
        assert smoke.n_layers <= 4 or smoke.n_layers <= cfg.n_layers // 4


def test_quickstart_flow():
    """The README quickstart: schedule the paper's workload with both
    schedulers and observe the headline energy effect."""
    from repro.cluster.simulator import run_experiment
    res = run_experiment("low", "energy_centric")
    assert res.unschedulable == 0
    savings = (res.mean_energy_kj("default")
               - res.mean_energy_kj("topsis")) / res.mean_energy_kj("default")
    assert savings > 0.2       # the paper's headline effect, low competition


def test_specs_no_allocation():
    """input_specs must be ShapeDtypeStructs (no device memory touched)."""
    from repro.configs import registry
    from repro.launch import specs
    from repro.models import lm
    c = specs.cell("llama3-8b", "train_4k")
    cfg = registry.config("llama3-8b")
    b = specs.model_inputs(cfg, c)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in b.values())
    assert b["tokens"].shape == (256, 4096)
    model = lm.build(cfg)
    p = specs.params_specs(model)
    assert all(isinstance(v, jax.ShapeDtypeStruct)
               for v in jax.tree.leaves(p))
    n_params = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(p))
    assert abs(n_params - cfg.param_count()) / cfg.param_count() < 0.35

"""Pareto frontier engine: weight grids, fused grid scoring, dominance.

Pins the tentpole equivalences: ``closeness_grid`` row ``s`` is bitwise
(numpy) / 1e-5 (jax, pallas) equal to scoring the queue under ``ws[s]``
alone; the paper's named schemes come back as a grid special case with
placements identical to per-scheme ``select_many``; and the dominance
filter is exact on hand-built metric sets. The property-based block needs
``hypothesis`` (requirements-dev.txt); when absent it skips cleanly.
"""
import xml.etree.ElementTree as ET

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # Degrade gracefully: stand-in decorators collect each property test as
    # a no-arg test that skips at runtime (mirrors @given consuming the
    # function's parameters, so pytest never looks for fixtures).
    def settings(*args, **kwargs):
        def wrap(f):
            return f
        return wrap

    def given(*args, **kwargs):
        def wrap(f):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

from repro.core import pareto, topsis
from repro.core.scheduler import BatchScheduler
from repro.core.weighting import (SCHEME_NAMES, scheme_grid,
                                  validate_weights, weights_for)
from repro.cluster.node import (FleetState, make_fleet_nodes,
                                make_paper_cluster)
from repro.cluster.workload import WORKLOADS, Pod

BENEFIT5 = np.array([False, False, True, True, True])


def make_queue(n):
    kinds = ("light", "medium", "complex")
    return [Pod(i, WORKLOADS[kinds[i % 3]], "topsis") for i in range(n)]


def rand_grid_inputs(p, n, s, seed):
    rng = np.random.default_rng(seed)
    mats = rng.uniform(0.1, 10.0, (p, n, 5))
    ws = rng.uniform(0.01, 1.0, (s, 5))
    ws /= ws.sum(axis=1, keepdims=True)
    valids = rng.random((p, n)) > 0.3
    valids[:, 0] = True          # at least one feasible node per pod
    return mats, ws, valids


# --- simplex-lattice weight grids -------------------------------------------
def test_weight_grid_unit_vectors_at_n1():
    g = pareto.weight_grid(1, 5)
    assert g.shape == (5, 5)
    assert np.array_equal(g, np.eye(5))
    g6 = pareto.weight_grid(1, 6)
    assert np.array_equal(g6, np.eye(6))


@pytest.mark.parametrize("n,criteria", [(1, 5), (2, 5), (4, 5), (3, 6)])
def test_weight_grid_counts_and_validity(n, criteria):
    g = pareto.weight_grid(n, criteria)
    assert g.shape == (pareto.grid_size(n, criteria), criteria)
    assert (g >= 0.0).all()
    assert np.allclose(g.sum(axis=1), 1.0, atol=1e-12)
    # normalized at generation: every grid scheme passes the same check
    # user-supplied vectors get
    validate_weights(g)
    # all rows distinct
    assert len({tuple(row) for row in g}) == len(g)


def test_weight_grid_upto_is_deterministic_prefix():
    ws = pareto.weight_grid_upto(512)
    assert ws.shape == (512, 5)
    full = pareto.weight_grid(pareto.lattice_n_for(512), 5)
    assert np.array_equal(ws, full[:512])
    assert pareto.lattice_n_for(5) == 1


def test_weight_grid_rejects_bad_args():
    with pytest.raises(ValueError):
        pareto.weight_grid(0, 5)
    with pytest.raises(ValueError):
        pareto.weight_grid(2, 4)


# --- weight validation (satellite bugfix) ------------------------------------
def test_validate_weights_accepts_scheme_registry():
    for name in SCHEME_NAMES:
        validate_weights(weights_for(name))
        validate_weights(weights_for(name, carbon=True))
    validate_weights(weights_for("carbon_centric"))
    validate_weights(scheme_grid())


@pytest.mark.parametrize("bad,msg", [
    (np.array([0.5, 0.5, 0.5, 0.2, 0.1]), "sums to"),
    (np.array([0.5, 0.6, -0.1, 0.0, 0.0]), "negative"),
    (np.array([0.5, 0.5, np.nan, 0.0, 0.0]), "non-finite"),
    (np.array([0.5, 0.5]), "5 weights"),
    (np.ones((2, 2, 5)) / 5.0, "vector or"),
])
def test_validate_weights_rejects(bad, msg):
    with pytest.raises(ValueError, match=msg):
        validate_weights(bad)


def test_validate_weights_names_offending_row():
    grid = np.vstack([np.full(5, 0.2), np.full(5, 0.5)])
    with pytest.raises(ValueError, match=r"weights\[1\]"):
        validate_weights(grid)


def test_scheduler_rejects_unnormalized_grid():
    sched = BatchScheduler(backend="numpy")
    pods = make_queue(3)
    nodes = make_paper_cluster()
    with pytest.raises(ValueError, match="sums to"):
        sched.score_queue_grid(pods, nodes, np.full((2, 5), 0.3))
    with pytest.raises(ValueError, match="6 weights"):
        # 6-weight rows need a carbon signal on the scheduler
        sched.score_queue_grid(pods, nodes, np.full((2, 6), 1.0 / 6.0))


# --- closeness_grid equivalence ----------------------------------------------
def test_closeness_grid_np_rows_bitwise():
    mats, ws, valids = rand_grid_inputs(4, 23, 6, seed=0)
    grid = topsis.closeness_grid_np(mats, ws, BENEFIT5, valids)
    assert grid.shape == (6, 4, 23)
    for s in range(6):
        per_scheme = topsis.batched_closeness_np(
            mats, np.broadcast_to(ws[s], (4, 5)), BENEFIT5, valids)
        assert np.array_equal(grid[s], per_scheme)
        for i in range(4):
            row = topsis.closeness_np(mats[i], ws[s], BENEFIT5,
                                      valids[i]).closeness
            assert np.array_equal(grid[s, i], row)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_closeness_grid_matches_reference(backend):
    mats, ws, valids = rand_grid_inputs(3, 37, 5, seed=1)
    want = topsis.closeness_grid_np(mats, ws, BENEFIT5, valids)
    if backend == "jax":
        got = np.asarray(topsis.closeness_grid(mats, ws, BENEFIT5, valids))
    else:
        from repro.kernels import ops
        got = np.asarray(ops.topsis_closeness_grid(mats, ws, BENEFIT5,
                                                   valid=valids))
    finite = np.isfinite(want)
    assert np.array_equal(finite, np.isfinite(got))
    assert np.max(np.abs(got[finite] - want[finite])) < 1e-5


def test_closeness_grid_no_mask_matches_masked_alltrue():
    mats, ws, _ = rand_grid_inputs(2, 9, 3, seed=2)
    a = np.asarray(topsis.closeness_grid(mats, ws, BENEFIT5))
    b = np.asarray(topsis.closeness_grid(mats, ws, BENEFIT5,
                                         np.ones((2, 9), bool)))
    assert np.array_equal(a, b)
    ref = topsis.closeness_grid_np(mats, ws, BENEFIT5)
    assert np.max(np.abs(a - ref)) < 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_grid_row_property(s, seed):
    """Property: every grid row equals its per-scheme score — bitwise on
    numpy, 1e-5 on the float32 jax and pallas engines."""
    from repro.kernels import ops
    mats, ws, valids = rand_grid_inputs(3, 17, s, seed)
    want = topsis.closeness_grid_np(mats, ws, BENEFIT5, valids)
    for si in range(s):
        assert np.array_equal(
            want[si],
            topsis.batched_closeness_np(
                mats, np.broadcast_to(ws[si], (3, 5)), BENEFIT5, valids))
    for got in (np.asarray(topsis.closeness_grid(mats, ws, BENEFIT5,
                                                 valids)),
                np.asarray(ops.topsis_closeness_grid(mats, ws, BENEFIT5,
                                                     valid=valids))):
        finite = np.isfinite(want)
        assert np.array_equal(finite, np.isfinite(got))
        assert np.max(np.abs(got[finite] - want[finite])) < 1e-5


# --- dominance filtering ------------------------------------------------------
def test_pareto_mask_hand_built():
    m = np.array([[1.0, 1.0],     # optimal
                  [2.0, 2.0],     # dominated by 0
                  [1.0, 2.0],     # dominated by 0
                  [0.5, 3.0],     # optimal (best metric 0)
                  [1.0, 1.0]])    # exact tie with 0: both kept
    assert pareto.pareto_mask(m).tolist() == [True, False, False, True,
                                              True]


def test_pareto_mask_single_point_and_all_dominated():
    assert pareto.pareto_mask(np.array([[3.0, 7.0]])).tolist() == [True]
    # one point dominates everything else -> front is exactly that point
    m = np.array([[5.0, 5.0], [1.0, 1.0], [9.0, 2.0], [2.0, 9.0]])
    assert pareto.pareto_mask(m).tolist() == [False, True, False, False]


def test_pareto_mask_rejects_bad_input():
    with pytest.raises(ValueError):
        pareto.pareto_mask(np.ones(4))
    with pytest.raises(ValueError):
        pareto.pareto_mask(np.array([[1.0, np.inf]]))


def test_frontier_dominant_deterministic_tie_break():
    pts = [pareto.SchemePoint(i, np.eye(3)[i % 3],
                              {"a": a, "b": b})
           for i, (a, b) in enumerate([(1.0, 2.0), (2.0, 1.0),
                                       (1.0, 2.0)])]
    f = pareto.ParetoFrontier(pts, ("a", "b"))
    assert f.mask.tolist() == [True, True, True]
    # symmetric costs: normalized means tie at 0.5 -> lowest index wins
    assert f.dominant().index == 0


def test_frontier_atlas_lookup():
    pts = [pareto.SchemePoint(0, np.eye(5)[0], {"a": 1.0, "b": 1.0})]
    atlas = pareto.FrontierAtlas()
    atlas.add("low", pareto.ParetoFrontier(pts, ("a", "b")))
    assert atlas.dominant_scheme("low").index == 0
    with pytest.raises(KeyError, match="low"):
        atlas.dominant_scheme("nope")
    rep = atlas.to_report()
    assert rep["low"]["n_front"] == 1
    assert rep["low"]["dominant"]["index"] == 0


# --- paper schemes as a grid special case ------------------------------------
@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_paper_schemes_recovered_from_grid(backend):
    """Stacking the paper's named schemes as a weight grid and placing via
    ``select_many_grid`` reproduces per-scheme ``select_many`` placements
    exactly (the table6 decision path) — bitwise scores on numpy."""
    pods = make_queue(8)
    nodes = make_paper_cluster()
    grid_sched = BatchScheduler(scheme="general", backend=backend)
    assigns, diag = grid_sched.select_many_grid(pods, nodes,
                                                list(SCHEME_NAMES))
    assert len(assigns) == len(SCHEME_NAMES)
    for s, name in enumerate(SCHEME_NAMES):
        solo = BatchScheduler(scheme=name, backend=backend)
        want_assign, want_diag = solo.select_many(pods, nodes)
        assert assigns[s] == want_assign
        if backend == "numpy":
            assert np.array_equal(diag["closeness"][s],
                                  want_diag["closeness"])
        else:
            got, want = diag["closeness"][s], want_diag["closeness"]
            finite = np.isfinite(want)
            assert np.array_equal(finite, np.isfinite(got))
            assert np.max(np.abs(got[finite] - want[finite])) < 1e-5


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_grid_incremental_matches_rebuild(backend):
    """The attached-fleet grid path (dirty-column sync + device-resident
    tensor) agrees with the full-rebuild numpy reference after churn."""
    pods = make_queue(6)
    ws = pareto.weight_grid(2, 5)          # 15 schemes
    fleet = FleetState.from_nodes(make_fleet_nodes(40, seed=3,
                                                   utilization=0.3))
    sched = BatchScheduler(scheme="general", backend=backend)
    sched.attach(fleet)
    sched.score_queue_grid(pods, fleet, ws)      # warm sync + upload
    fleet.bind(2, 1.0, 2.0)
    fleet.bind(11, 0.5, 0.5)
    fleet.release(2, 1.0, 2.0)
    got = sched.score_queue_grid(pods, fleet, ws)
    want = BatchScheduler(scheme="general",
                          backend="numpy").score_queue_grid(pods, fleet,
                                                            ws)
    finite = np.isfinite(want)
    assert np.array_equal(finite, np.isfinite(got))
    err = np.max(np.abs(got[finite] - want[finite]))
    assert err == 0.0 if backend == "numpy" else err < 1e-5


# --- metric collection + report ----------------------------------------------
def test_placement_metrics_and_frontier():
    pods = make_queue(10)
    nodes = make_fleet_nodes(32, seed=4, utilization=0.3)
    ws = pareto.weight_grid_upto(24)
    points = pareto.placement_metrics(pods, nodes, ws, backend="numpy")
    assert len(points) == 24
    for p in points:
        assert set(p.metrics) == {"energy_kj", "mean_latency_s",
                                  "unschedulable_rate"}
        assert all(np.isfinite(v) for v in p.metrics.values())
    f = pareto.frontier_for(points)
    assert 1 <= len(f.front) <= 24
    assert f.dominant() in f.front
    # the dominant pick is never dominated by any swept point
    dom = np.array([f.dominant().metrics[k] for k in f.metric_names])
    for p in points:
        row = np.array([p.metrics[k] for k in f.metric_names])
        assert not ((row <= dom).all() and (row < dom).any())


def test_placement_metrics_reads_decision_tensor():
    """One pod, one feasible node: metrics are exactly the decision
    tensor's predicted energy / runtime for that placement."""
    from repro.core.scheduler import decision_matrix_batch
    pods = make_queue(1)
    nodes = make_paper_cluster()
    ws = np.full((1, 5), 0.2)
    points = pareto.placement_metrics(pods, nodes, ws, backend="numpy")
    [pt] = points
    mats = decision_matrix_batch(pods, nodes)
    sched = BatchScheduler(scheme="general", backend="numpy")
    [assign], _ = sched.select_many_grid(pods, nodes, ws)
    a = assign[0]
    assert pt.metrics["energy_kj"] == pytest.approx(mats[0, a, 1] / 1e3)
    assert pt.metrics["mean_latency_s"] == pytest.approx(mats[0, a, 0])
    assert pt.metrics["unschedulable_rate"] == 0.0


def test_report_frontier_section_well_formed():
    from repro.telemetry.report import html_report
    pods = make_queue(8)
    nodes = make_fleet_nodes(16, seed=5, utilization=0.2)
    points = pareto.placement_metrics(pods, nodes,
                                      pareto.weight_grid_upto(12),
                                      backend="numpy")
    atlas = pareto.FrontierAtlas()
    atlas.add("baseline", pareto.frontier_for(points))
    doc = html_report(frontier=atlas.to_report())
    ET.fromstring(doc)                 # well-formed XML, as the spec pins
    assert "Pareto frontier" in doc
    assert "baseline" in doc

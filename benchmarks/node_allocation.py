"""Paper §V.D: node allocation patterns per scheme x competition level.

Energy-centric should concentrate on frugal class-A nodes; performance-
centric on high-capacity class C; default K8s spreads (LeastRequested).
"""
from __future__ import annotations

from repro.cluster.simulator import run_experiment

SCHEMES = ("general", "energy_centric", "performance_centric",
           "resource_efficient")
CLASSES = ("A", "B", "C", "default")


def run(csv: bool = True):
    print("level,scheme,scheduler," + ",".join(CLASSES))
    out = {}
    for level in ("low", "medium", "high"):
        for scheme in SCHEMES:
            res = run_experiment(level, scheme)
            for sched in ("topsis", "default"):
                alloc = res.allocation(sched)
                row = [alloc.get(c, 0) for c in CLASSES]
                print(f"{level},{scheme},{sched}," +
                      ",".join(map(str, row)))
                out[(level, scheme, sched)] = row
    return out


if __name__ == "__main__":
    run()

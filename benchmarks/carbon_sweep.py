"""Carbon sweep: energy, operational carbon, deferral latency, and
preemption count per (scenario x scheme x backend) through the carbon-aware
event-driven engine.

Every cell streams Poisson bursts (half the pods deferrable) onto a
scenario fleet whose nodes are spread across regions with a staggered
sinusoidal grid-intensity signal — all regions start near their peak and
dip within the run, so both levers are exercised: *spatial* shifting (the
carbon-rate criterion steers placements toward currently-clean regions)
and *temporal* shifting (deferrable pods wait for the dip, bounded by
their deadline; running deferrable tasks are preempted off spiking
regions). Per cell we record scalar energy and carbon totals per
scheduler, the mean deferral latency, and the preemption count. A
verification cell re-runs ``energy_centric`` with the signal attached but
zero carbon weight and asserts placements and energy totals are bitwise
identical to the carbon-free engine (the PR-2 path).

Run: PYTHONPATH=src python benchmarks/carbon_sweep.py \
        [--smoke] [--backend all|numpy|jax|pallas] \
        [--profiles mixed,edge_heavy] [--nodes 16,64] [--bursts 8] \
        [--burst-size 16] [--schemes energy_centric,carbon_centric,...] \
        [--seed 0] [--out BENCH_carbon.json]

``--smoke`` shrinks everything (one profile, 8 nodes, 3 bursts of 4) so CI
can exercise the whole carbon path in seconds.
"""
from __future__ import annotations

import itertools

try:
    from benchmarks import common
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    import common
from repro.core.carbon import CarbonPolicy, diurnal_fleet_signal
from repro.cluster.node import DEFAULT_REGIONS, make_scenario_cluster
from repro.cluster.simulator import run_scenario
from repro.cluster.workload import PoissonArrivals

DEFAULT_PROFILES = ("mixed", "edge_heavy")
DEFAULT_NODES = (16, 64)
DEFAULT_SCHEMES = ("energy_centric", "carbon_energy_balanced",
                   "carbon_centric")
DEFAULT_BACKENDS = common.DEFAULT_BACKENDS

# Signal: one sinusoidal "day" compressed to 30 min so a few-minute
# scenario sees real intensity movement. The global phase puts every
# region near its peak at t=0 (deferrable pods defer, then catch the dip);
# the stagger spreads regional peaks so a clean region usually exists
# (spatial shifting). Thresholds sit at the midline (defer) and upper
# quartile (preempt).
PERIOD_S = 1800.0
BASE, AMPLITUDE = 300.0, 200.0


def make_policy(preempt: bool = True) -> CarbonPolicy:
    sig = diurnal_fleet_signal(DEFAULT_REGIONS, base=BASE,
                               amplitude=AMPLITUDE, period_s=PERIOD_S,
                               phase_s=PERIOD_S / 4.0,
                               stagger_s=PERIOD_S / 16.0)
    return CarbonPolicy(sig, defer_threshold=BASE,
                        preempt_threshold=(BASE + 0.75 * AMPLITUDE
                                           if preempt else None),
                        check_interval_s=30.0)


def make_arrivals(n_bursts: int, burst_size: int, seed: int,
                  deferrable_share: float = 0.5) -> PoissonArrivals:
    return PoissonArrivals(rate_per_s=0.2, n_bursts=n_bursts,
                           burst_size=burst_size, seed=seed,
                           deferrable_share=deferrable_share,
                           deadline_s=PERIOD_S / 2.0)


def run_cell(profile: str, n_nodes: int, scheme: str, backend: str,
             n_bursts: int, burst_size: int, seed: int = 0) -> dict:
    res = run_scenario(
        make_arrivals(n_bursts, burst_size, seed), scheme,
        cluster_factory=lambda: make_scenario_cluster(profile, n_nodes,
                                                      seed=seed),
        batch=True, batch_backend=backend, carbon=make_policy())
    return {
        "profile": profile, "n_nodes": n_nodes, "scheme": scheme,
        "backend": backend, "n_bursts": n_bursts, "burst_size": burst_size,
        # a preempted pod has one record per run attempt: count unique pods
        "pods": len({r.pod.uid for r in res.records}) + res.unschedulable,
        "unschedulable_rate": res.unschedulable_rate(),
        "energy_topsis_kj": res.energy_kj("topsis"),
        "energy_default_kj": res.energy_kj("default"),
        "carbon_topsis_g": res.total_carbon_g("topsis"),
        "carbon_default_g": res.total_carbon_g("default"),
        "mean_deferral_latency_s": res.mean_deferral_latency_s("topsis"),
        "preemptions": res.preemptions,
        "carbon_series_points": int(len(res.carbon_series()[0])),
    }


def run_zero_weight_check(profile: str, n_nodes: int, backend: str,
                          n_bursts: int, burst_size: int,
                          seed: int = 0) -> dict:
    """energy_centric with the signal attached (zero carbon weight, no
    deferral/preemption thresholds) must reproduce the carbon-free engine
    bitwise — placements and energy totals."""
    arrivals = lambda: make_arrivals(n_bursts, burst_size, seed,
                                     deferrable_share=0.0)
    factory = lambda: make_scenario_cluster(profile, n_nodes, seed=seed)
    plain = run_scenario(arrivals(), "energy_centric",
                         cluster_factory=factory, batch=True,
                         batch_backend=backend)
    carbon = run_scenario(arrivals(), "energy_centric",
                          cluster_factory=factory, batch=True,
                          batch_backend=backend,
                          carbon=CarbonPolicy(make_policy().signal))
    same_nodes = ([r.node for r in plain.records]
                  == [r.node for r in carbon.records])
    same_energy = all(plain.energy_kj(s) == carbon.energy_kj(s)
                      for s in ("topsis", "default"))
    if not (same_nodes and same_energy):
        raise AssertionError(
            f"zero-carbon-weight run diverged from the carbon-free engine "
            f"({profile}, {n_nodes} nodes, {backend}): "
            f"placements equal={same_nodes}, energy equal={same_energy}")
    return {"profile": profile, "n_nodes": n_nodes, "backend": backend,
            "zero_weight_bitwise_match": True,
            "energy_topsis_kj": plain.energy_kj("topsis")}


def run(profiles=DEFAULT_PROFILES, node_counts=DEFAULT_NODES,
        schemes=DEFAULT_SCHEMES, backends=DEFAULT_BACKENDS,
        n_bursts: int = 8, burst_size: int = 16, seed: int = 0,
        out: str | None = "BENCH_carbon.json") -> dict:
    results, checks = [], []
    print("profile,n_nodes,scheme,backend,pods,E_topsis_kJ,C_topsis_g,"
          "defer_s,preempt")
    for profile, n in itertools.product(profiles, node_counts):
        for scheme, backend in itertools.product(schemes, backends):
            rec = run_cell(profile, n, scheme, backend,
                           n_bursts, burst_size, seed=seed)
            results.append(rec)
            print(f"{profile},{n},{scheme},{backend},"
                  f"{rec['pods']},{rec['energy_topsis_kj']:.4f},"
                  f"{rec['carbon_topsis_g']:.4f},"
                  f"{rec['mean_deferral_latency_s']:.1f},"
                  f"{rec['preemptions']}")
        checks.append(run_zero_weight_check(profile, n, backends[0],
                                            n_bursts, burst_size,
                                            seed=seed))
        print(f"{profile},{n}: zero-carbon-weight run matches the "
              f"carbon-free engine bitwise")
    # headline: carbon_centric vs energy_centric carbon reduction per cell
    summary = []
    by_key = {(r["profile"], r["n_nodes"], r["backend"], r["scheme"]): r
              for r in results}
    for (profile, n, backend, scheme), r in by_key.items():
        if scheme != "carbon_centric":
            continue
        base = by_key.get((profile, n, backend, "energy_centric"))
        if base and base["carbon_topsis_g"] > 0:
            summary.append({
                "profile": profile, "n_nodes": n, "backend": backend,
                "carbon_reduction_pct": 100.0
                * (1.0 - r["carbon_topsis_g"] / base["carbon_topsis_g"])})
    for s in summary:
        print(f"carbon_centric vs energy_centric "
              f"({s['profile']}, {s['n_nodes']}, {s['backend']}): "
              f"{s['carbon_reduction_pct']:.1f}% less carbon")
    report = {"bench": "carbon_sweep",
              "config": {"profiles": list(profiles),
                         "node_counts": list(node_counts),
                         "schemes": list(schemes),
                         "backends": list(backends),
                         "n_bursts": n_bursts, "burst_size": burst_size,
                         "seed": seed, "period_s": PERIOD_S,
                         "base": BASE, "amplitude": AMPLITUDE},
              "results": results,
              "zero_weight_checks": checks,
              "carbon_reduction_summary": summary}
    return common.write_report(report, out)


def main():
    ap = common.sweep_parser("BENCH_carbon.json", DEFAULT_PROFILES,
                             DEFAULT_NODES, schemes=DEFAULT_SCHEMES)
    args = ap.parse_args()
    profiles = common.split_csv(args.profiles)
    run(profiles=profiles[:1] if args.smoke else profiles,
        schemes=common.split_csv(args.schemes),
        backends=common.resolve_backends(args.backend),
        seed=args.seed, out=args.out, **common.sweep_sizes(args))


if __name__ == "__main__":
    main()

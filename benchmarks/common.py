"""Shared scaffolding for the sweep benchmarks.

Every sweep (scenario / carbon / autoscale / scheduling) repeats the same
boilerplate: an argparse front-end with smoke/backend/fleet flags, a
comma-list parser, the ``--backend all`` resolution, the nested
(profile x nodes x variant x backend) cell loop, and the JSON report emit.
This module holds one copy of each; the sweep modules keep only their
cell logic and defaults.
"""
from __future__ import annotations

import argparse
import datetime
import itertools
import json
import os
import platform
import subprocess
from typing import Iterable, Iterator, Sequence

# Append-only JSONL trajectory of recorded sweeps and check verdicts —
# one line per event, so the bench history is a series, not a snapshot.
HISTORY_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "history")

# The batched backends every sweep defaults to; pallas is opt-in
# (interpret mode is slow on CPU).
DEFAULT_BACKENDS = ("numpy", "jax")

# The CI smoke lane's scenario sizes: tiny fleet, few events, whole path
# exercised in seconds.
SMOKE_NODE_COUNTS = (8,)
SMOKE_N_BURSTS = 3
SMOKE_BURST_SIZE = 4


def split_csv(value: str) -> tuple[str, ...]:
    """``"a,b,"`` -> ``("a", "b")`` (empty items dropped)."""
    return tuple(x for x in value.split(",") if x)


def split_csv_int(value: str) -> tuple[int, ...]:
    return tuple(int(x) for x in value.split(",") if x)


def resolve_backends(arg: str,
                     default: Sequence[str] = DEFAULT_BACKENDS
                     ) -> tuple[str, ...]:
    """``--backend all`` -> the sweep's defaults; otherwise a comma-list
    from numpy,jax,pallas."""
    return tuple(default) if arg == "all" else split_csv(arg)


def sweep_parser(out_default: str, profiles: Sequence[str],
                 node_counts: Sequence[int],
                 schemes: Sequence[str] | None = None,
                 policies: Sequence[str] | None = None,
                 backends: Sequence[str] = DEFAULT_BACKENDS
                 ) -> argparse.ArgumentParser:
    """The flag set the scenario-style sweeps share; ``schemes`` /
    ``policies`` add the sweep's variant axis when given."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fleet, few events (CI lane); other flags "
                         "still apply, only the scenario sizes shrink")
    ap.add_argument("--backend", default="all",
                    help=f"all (= {','.join(backends)}; pallas is "
                         "opt-in, interpret mode is slow on CPU) or a "
                         "comma-list from numpy,jax,pallas")
    ap.add_argument("--profiles", default=",".join(profiles))
    ap.add_argument("--nodes", default=",".join(map(str, node_counts)))
    if schemes is not None:
        ap.add_argument("--schemes", default=",".join(schemes))
    if policies is not None:
        ap.add_argument("--policies", default=",".join(policies))
    ap.add_argument("--bursts", type=int, default=8)
    ap.add_argument("--burst-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=out_default)
    return ap


def sweep_sizes(args: argparse.Namespace) -> dict:
    """Resolve the scenario sizes from parsed args: the smoke lane's tiny
    sizes, or the flag values."""
    if args.smoke:
        return dict(node_counts=SMOKE_NODE_COUNTS,
                    n_bursts=SMOKE_N_BURSTS, burst_size=SMOKE_BURST_SIZE)
    return dict(node_counts=split_csv_int(args.nodes),
                n_bursts=args.bursts, burst_size=args.burst_size)


def iter_cells(profiles: Iterable, node_counts: Iterable,
               variants: Iterable, backends: Iterable
               ) -> Iterator[tuple]:
    """The sweeps' shared (profile x nodes x variant x backend) cell
    order: backends innermost, so per-(profile, nodes) work (fleet
    construction, verification rows) amortizes naturally."""
    return itertools.product(profiles, node_counts, variants, backends)


def _git_sha() -> str | None:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return None


def provenance() -> dict:
    """Environment fingerprint for a recorded report: without it a
    BENCH_*.json number is unattributable — was it CPU interpret-mode
    pallas or a real TPU, which jax, which commit, when?"""
    prov: dict = {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "git_sha": _git_sha(),
        "utc_timestamp": datetime.datetime.now(datetime.timezone.utc)
                                 .isoformat(timespec="seconds"),
    }
    try:
        import jax
        from repro.kernels.ops import _on_tpu
        prov["jax_version"] = jax.__version__
        prov["jax_platform"] = jax.default_backend()
        # the default the pallas wrappers resolve `interpret=None` to
        prov["pallas_interpret"] = not _on_tpu()
    except Exception as e:               # jax broken/absent: record why
        prov["jax_version"] = None
        prov["jax_error"] = repr(e)
    return prov


def write_report(report: dict, out: str | None,
                 history: bool = True) -> dict:
    """Emit a sweep's JSON report with a :func:`provenance` block stamped
    in (no-op when ``out`` is falsy; an explicit block in ``report`` is
    kept), and append the run to the sweep's ``benchmarks/history/``
    JSONL so successive recordings form a trajectory."""
    report.setdefault("provenance", provenance())
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {out}")
        if history:
            from repro.telemetry.baseline import append_history
            bench = report.get("bench") or os.path.basename(out)
            append_history(
                {"kind": "record", "bench": bench,
                 "provenance": report.get("provenance"),
                 "config": report.get("config"),
                 "results": report.get("results")},
                os.path.join(HISTORY_DIR, f"{bench}.jsonl"))
    return report

"""Render the EXPERIMENTS.md §Roofline table from launch/dryrun.py output.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [dir] [--mesh single]
"""
from __future__ import annotations

import glob
import json
import os
import sys

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def load(dryrun_dir: str, mesh: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return recs


def fmt(recs, md=True):
    lines = []
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | HBM GB/dev | MODEL_FLOPS/HLO | ok |")
    lines.append(hdr)
    lines.append("|" + "---|" * 10)
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | -"
                         f" | - | - | - | - | FAIL: {r.get('error','')[:40]} |")
            continue
        rf = r["roofline"]
        peak = r["memory"]["peak_bytes"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.3e} | {rf['memory_s']:.3e} "
            f"| {rf['collective_s']:.3e} | {rf['dominant'].replace('_s','')} "
            f"| {peak:.2f} | {r['useful_flops_frac']:.2f} | ok |")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    meshes = ["single", "multi"]
    if "--mesh" in sys.argv:
        meshes = [sys.argv[sys.argv.index("--mesh") + 1]]
    for mesh in meshes:
        recs = load(d, mesh)
        print(f"\n### Roofline — {mesh}-pod mesh "
              f"({'256' if mesh == 'single' else '512'} chips)\n")
        print(fmt(recs))


if __name__ == "__main__":
    main()

"""Scenario sweep: energy, scheduling time, and unschedulable rate per
(scenario x scheme x backend) through the event-driven engine.

Each scenario is an (arrival process, fleet) pair well beyond the paper's
single all-at-t0 burst on 4 nodes: Poisson bursts streamed onto edge-heavy /
cloud-heavy / mixed fleets (``make_scenario_cluster``), with every TOPSIS
burst routed through ``BatchScheduler.select_many`` on the chosen backend.
Per cell we record scalar energy totals (dynamic + idle decomposition off
the power timeline), the per-pod scheduling time, the unschedulable rate,
and the length of the energy-vs-time series. Results go to
BENCH_scenarios.json.

Run: PYTHONPATH=src python benchmarks/scenario_sweep.py \
        [--smoke] [--backend all|numpy|jax|pallas] \
        [--profiles mixed,edge_heavy,cloud_heavy] [--nodes 16,256] \
        [--bursts 8] [--burst-size 16] [--schemes energy_centric,...] \
        [--out BENCH_scenarios.json]

``--smoke`` shrinks everything (8 nodes, 3 bursts of 4) so CI can exercise
the whole scenario path in seconds.
"""
from __future__ import annotations

try:
    from benchmarks import common
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    import common
from repro.cluster.node import SCENARIO_PROFILES, make_scenario_cluster
from repro.cluster.simulator import run_scenario
from repro.cluster.workload import PoissonArrivals

DEFAULT_PROFILES = tuple(SCENARIO_PROFILES)
DEFAULT_NODES = (16, 256)
DEFAULT_SCHEMES = ("energy_centric", "performance_centric")
DEFAULT_BACKENDS = common.DEFAULT_BACKENDS


def run_cell(profile: str, n_nodes: int, scheme: str, backend: str,
             n_bursts: int, burst_size: int, seed: int = 0) -> dict:
    arrivals = PoissonArrivals(rate_per_s=0.2, n_bursts=n_bursts,
                               burst_size=burst_size, seed=seed)
    res = run_scenario(
        arrivals, scheme,
        cluster_factory=lambda: make_scenario_cluster(profile, n_nodes,
                                                      seed=seed),
        batch=True, batch_backend=backend)
    tl = res.timeline
    edges, _ = res.energy_series()
    return {
        "profile": profile, "n_nodes": n_nodes, "scheme": scheme,
        "backend": backend, "n_bursts": n_bursts, "burst_size": burst_size,
        "pods": len(res.records) + res.unschedulable,
        "unschedulable_rate": res.unschedulable_rate(),
        "energy_topsis_kj": res.energy_kj("topsis"),
        "energy_default_kj": res.energy_kj("default"),
        "dyn_energy_topsis_j": tl.dynamic_energy_j("topsis"),
        "idle_energy_topsis_j": tl.idle_energy_j("topsis"),
        "mean_sched_time_topsis_ms": res.mean_sched_time_ms("topsis"),
        "mean_sched_time_default_ms": res.mean_sched_time_ms("default"),
        "energy_series_points": int(len(edges)),
    }


def run(profiles=DEFAULT_PROFILES, node_counts=DEFAULT_NODES,
        schemes=DEFAULT_SCHEMES, backends=DEFAULT_BACKENDS,
        n_bursts: int = 8, burst_size: int = 16, seed: int = 0,
        out: str | None = "BENCH_scenarios.json") -> dict:
    results = []
    print("profile,n_nodes,scheme,backend,pods,unsched_rate,"
          "E_topsis_kJ,E_default_kJ,sched_ms_topsis")
    for profile, n, scheme, backend in common.iter_cells(
            profiles, node_counts, schemes, backends):
        rec = run_cell(profile, n, scheme, backend,
                       n_bursts, burst_size, seed=seed)
        results.append(rec)
        print(f"{profile},{n},{scheme},{backend},"
              f"{rec['pods']},{rec['unschedulable_rate']:.3f},"
              f"{rec['energy_topsis_kj']:.4f},"
              f"{rec['energy_default_kj']:.4f},"
              f"{rec['mean_sched_time_topsis_ms']:.3f}")
    report = {"bench": "scenario_sweep",
              "config": {"profiles": list(profiles),
                         "node_counts": list(node_counts),
                         "schemes": list(schemes),
                         "backends": list(backends),
                         "n_bursts": n_bursts, "burst_size": burst_size,
                         "seed": seed},
              "results": results}
    return common.write_report(report, out)


def main():
    ap = common.sweep_parser("BENCH_scenarios.json", DEFAULT_PROFILES,
                             DEFAULT_NODES, schemes=DEFAULT_SCHEMES)
    args = ap.parse_args()
    run(profiles=common.split_csv(args.profiles),
        schemes=common.split_csv(args.schemes),
        backends=common.resolve_backends(args.backend),
        seed=args.seed, out=args.out, **common.sweep_sizes(args))


if __name__ == "__main__":
    main()

"""Paper Table VI: energy (kJ) per (competition level x weighting scheme)
for default K8s vs GreenPod TOPSIS, plus optimization %.

Prints the reproduced table next to the paper's published numbers.
"""
from __future__ import annotations

import numpy as np

from repro.cluster.simulator import table6

PAPER = {  # (level, scheme) -> (default_kj, topsis_kj, optimization_pct)
    ("low", "general"): (0.5036, 0.4586, 8.93),
    ("low", "energy_centric"): (0.5036, 0.3124, 37.96),
    ("low", "performance_centric"): (0.5036, 0.4924, 2.22),
    ("low", "resource_efficient"): (0.5036, 0.3686, 26.80),
    ("medium", "general"): (0.4375, 0.3650, 16.57),
    ("medium", "energy_centric"): (0.4375, 0.2663, 39.13),
    ("medium", "performance_centric"): (0.4375, 0.4037, 7.72),
    ("medium", "resource_efficient"): (0.4375, 0.2944, 32.70),
    ("high", "general"): (0.4471, 0.3867, 13.50),
    ("high", "energy_centric"): (0.4257, 0.2817, 33.82),
    ("high", "performance_centric"): (0.4257, 0.3904, 8.29),
    ("high", "resource_efficient"): (0.4257, 0.4050, 4.86),
}


def run(csv: bool = False):
    t = table6()
    rows = []
    errs = []
    for (level, scheme), (dk, tk, opt) in PAPER.items():
        c = t[level][scheme]
        errs.append(abs(c["optimization_pct"] - opt))
        rows.append((level, scheme, c["default_kj"], dk, c["topsis_kj"], tk,
                     c["optimization_pct"], opt))
    if csv:
        print("level,scheme,default_kj,paper_default,topsis_kj,paper_topsis,"
              "opt_pct,paper_opt")
        for r in rows:
            print(",".join(str(round(x, 4)) if isinstance(x, float) else x
                           for x in r))
    else:
        print(f"{'level':8s}{'scheme':22s}{'ours kJ':>9s}{'paper':>8s}"
              f"{'opt %':>8s}{'paper':>8s}")
        for level, scheme, dkj, pdk, tkj, ptk, o, po in rows:
            print(f"{level:8s}{scheme:22s}{tkj:9.4f}{ptk:8.4f}"
                  f"{o:8.2f}{po:8.2f}")
    avg = {lvl: float(np.mean([v["optimization_pct"] for v in d.values()]))
           for lvl, d in t.items()}
    print(f"# averages low/med/high: {avg['low']:.2f}/{avg['medium']:.2f}/"
          f"{avg['high']:.2f}  (paper: 18.98/24.03/15.12)")
    print(f"# mean |optimization error|: {float(np.mean(errs)):.2f} pp")
    return t, float(np.mean(errs))


if __name__ == "__main__":
    run()

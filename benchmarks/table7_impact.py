"""Paper §V.E-F / Table VII: real-world impact extrapolation.

Exact arithmetic of the paper, driven by OUR reproduced average optimization
(and, for reference, the paper's 19.38%): SURF Lisa job statistics [31],
Dayarathna blade power model [32], EPA eGRID CO2 factor [33], EIA rates [35],
World Bank carbon prices [36].
"""
from __future__ import annotations

import numpy as np

from repro.cluster.simulator import table6
from repro.core.energy import paper_job_energy_kwh

JOBS_PER_DAY = 6304          # SURF Lisa daily average [31]
CO2_KG_PER_MWH = 0.823 * 0.4536 * 1000.0     # EPA eGRID lb/kWh -> kg/MWh
VEHICLE_T_CO2 = 4.6          # EPA passenger vehicle t/yr [34]
RATE_USD_KWH = 0.1289        # EIA commercial rate [35]
CARBON_USD_MIN, CARBON_USD_MAX = 0.46, 167.0  # World Bank range [36]


def impact(optimization_frac: float, clusters: int = 1) -> dict:
    job_kwh = paper_job_energy_kwh()               # ~0.024 kWh (paper §V.E)
    daily_mwh = job_kwh * JOBS_PER_DAY * optimization_frac / 1000.0
    annual_mwh = daily_mwh * 365.0
    co2_t = annual_mwh * CO2_KG_PER_MWH / 1000.0
    usd = annual_mwh * 1000.0 * RATE_USD_KWH
    return {
        "clusters": clusters,
        "daily_MWh": daily_mwh * clusters,
        "monthly_MWh": daily_mwh * 30 * clusters,
        "annual_MWh": annual_mwh * clusters,
        "annual_CO2_t": co2_t * clusters,
        "vehicles_removed": co2_t / VEHICLE_T_CO2 * clusters,
        "annual_usd": usd * clusters,
        "carbon_credit_usd_min": co2_t * CARBON_USD_MIN * clusters,
        "carbon_credit_usd_max": co2_t * CARBON_USD_MAX * clusters,
    }


def run(csv: bool = False):
    t = table6()
    ours = float(np.mean([v["optimization_pct"]
                          for d in t.values() for v in d.values()])) / 100.0
    print(f"# average optimization: ours={ours * 100:.2f}% "
          f"(paper: 19.38%)")
    print("metric,ours_1_cluster,ours_10_clusters,"
          "paper_1_cluster,paper_10_clusters")
    ours1, ours10 = impact(ours), impact(ours, 10)
    pap1, pap10 = impact(0.1938), impact(0.1938, 10)
    paper_pub = {  # published Table VII values
        "daily_MWh": (0.0293, 0.29), "monthly_MWh": (0.88, 8.80),
        "annual_MWh": (10.70, 107.02), "annual_CO2_t": (3.99, 39.94),
        "vehicles_removed": (0.87, 8.70), "annual_usd": (1380, 13795),
    }
    for k in ours1:
        if k == "clusters":
            continue
        pub = paper_pub.get(k, ("-", "-"))
        print(f"{k},{ours1[k]:.4g},{ours10[k]:.4g},{pap1[k]:.4g} "
              f"(pub {pub[0]}),{pap10[k]:.4g} (pub {pub[1]})")
    return ours1


if __name__ == "__main__":
    run()

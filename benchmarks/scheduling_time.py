"""Paper §IV.C 'Scheduling Time (ms)' at fleet scale.

The paper's cluster has 4 nodes; a production fleet has thousands. This
benchmark sweeps N candidate nodes and times the scheduling engines two
ways:

  per-pod   — GreenPodScheduler.select in a Python loop over the queue
              (numpy backend: the latency path, one rescore per bind)
  batched   — BatchScheduler.select_many: one scoring pass for the whole
              queue on a backend:
                numpy   per-pod closeness_np loop (reference)
                jax     topsis.batched_closeness (vmap + jit)
                pallas  the tiled TOPSIS kernel (interpret mode on CPU;
                        compiles to Mosaic on a real TPU)

Every batched backend's closeness matrix is asserted against
``topsis.closeness_np`` within 1e-5 before timing. Results are printed as
CSV and written to BENCH_scheduling.json.

Run: PYTHONPATH=src python benchmarks/scheduling_time.py \
        [--backend all|numpy|jax|pallas] [--nodes 4,256,2048,8192] \
        [--pods 64] [--out BENCH_scheduling.json]
"""
from __future__ import annotations

import argparse
import itertools
import time

import numpy as np

try:
    from benchmarks import common
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    import common
from repro.core.scheduler import BACKENDS, BatchScheduler, GreenPodScheduler
from repro.cluster.node import make_fleet
from repro.cluster.workload import WORKLOADS, Pod

DEFAULT_NODES = (4, 256, 2048, 8192)


def _time(f, reps=10, warmup=2):
    for _ in range(warmup):
        f()
    t0 = time.perf_counter()
    for _ in range(reps):
        f()
    return (time.perf_counter() - t0) / reps


def make_queue(n_pods: int) -> list[Pod]:
    kinds = itertools.cycle(["light", "medium", "complex"])
    return [Pod(i, WORKLOADS[next(kinds)], "topsis") for i in range(n_pods)]


def verify_backend(backend: str, pods, table, want, atol=1e-5) -> float:
    """Max |closeness - want| over the queue's feasible entries, where
    ``want`` is the numpy-reference score matrix for the same snapshot."""
    if backend == "numpy":
        return 0.0          # `want` IS the numpy backend's output
    got = BatchScheduler("energy_centric",
                         backend=backend).score_queue(pods, table)
    finite = np.isfinite(want)
    assert np.array_equal(finite, np.isfinite(got)), \
        f"{backend}: feasibility masks differ"
    err = float(np.max(np.abs(got[finite] - want[finite]))) \
        if finite.any() else 0.0
    assert err < atol, f"{backend}: max closeness err {err:.2e} >= {atol}"
    return err


def run(backends=BACKENDS, node_counts=DEFAULT_NODES, n_pods: int = 64,
        reps: int = 10, out: str | None = "BENCH_scheduling.json",
        seed: int = 0) -> dict:
    pods = make_queue(n_pods)
    results = []
    print("mode,backend,n_nodes,pods,ms_total,us_per_pod")
    for n in node_counts:
        table = make_fleet(n, seed=seed, utilization=0.3)
        # the per-pod latency baseline: P independent select() calls
        g = GreenPodScheduler("energy_centric", backend="numpy")
        t = _time(lambda: [g.select(p, table) for p in pods], reps=reps)
        per_pod_ms = t * 1e3
        results.append({"mode": "per-pod", "backend": "numpy",
                        "n_nodes": n, "pods": n_pods,
                        "ms_total": t * 1e3,
                        "us_per_pod": t / n_pods * 1e6})
        print(f"per-pod,numpy,{n},{n_pods},{t * 1e3:.3f},"
              f"{t / n_pods * 1e6:.1f}")
        want = BatchScheduler("energy_centric",
                              backend="numpy").score_queue(pods, table)
        for backend in backends:
            err = verify_backend(backend, pods, table, want)
            s = BatchScheduler("energy_centric", backend=backend)
            t = _time(lambda: s.select_many(pods, table), reps=reps)
            rec = {"mode": "batched", "backend": backend, "n_nodes": n,
                   "pods": n_pods, "ms_total": t * 1e3,
                   "us_per_pod": t / n_pods * 1e6,
                   "max_closeness_err_vs_numpy": err,
                   "speedup_vs_per_pod_numpy": per_pod_ms / (t * 1e3)}
            results.append(rec)
            print(f"batched,{backend},{n},{n_pods},{t * 1e3:.3f},"
                  f"{t / n_pods * 1e6:.1f}")
    report = {"bench": "scheduling_time",
              "config": {"pods": n_pods, "reps": reps, "seed": seed,
                         "node_counts": list(node_counts),
                         "backends": list(backends)},
              "results": results}
    return common.write_report(report, out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="all",
                    help="all | " + " | ".join(BACKENDS))
    ap.add_argument("--nodes", default=",".join(map(str, DEFAULT_NODES)),
                    help="comma-separated fleet sizes to sweep")
    ap.add_argument("--pods", type=int, default=64)
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--out", default="BENCH_scheduling.json")
    args = ap.parse_args()
    backends = common.resolve_backends(args.backend, default=BACKENDS)
    node_counts = common.split_csv_int(args.nodes)
    run(backends=backends, node_counts=node_counts, n_pods=args.pods,
        reps=args.reps, out=args.out)


if __name__ == "__main__":
    main()

"""Paper §IV.C 'Scheduling Time (ms)' at fleet scale.

The paper's cluster has 4 nodes; a production fleet has tens of thousands.
This benchmark sweeps N candidate nodes and times a scheduling round three
ways:

  per-pod      — GreenPodScheduler.select in a Python loop over the queue
                 (numpy backend: the latency path, one rescore per bind;
                 only timed through N=8192 — it is off the pareto front
                 long before that)
  rebuild      — the pre-FleetState round: flatten the Node list into a
                 fresh NodeTable snapshot, build the (P, N, C) decision
                 tensor from scratch, score (BatchScheduler's full-rebuild
                 path, kept as the reference oracle)
  incremental  — the delta-maintained round: an attached FleetState with
                 dirty-column sync (FleetCriteriaCache), scoring through
                 the per-kind (K, N, C) cache — numpy reads zero-copy row
                 views, jax gathers from the device-resident donated
                 mirror in one dispatch, pallas streams kind blocks
                 through the scalar-prefetch kernel

Each timed rep first touches ~32 random node columns (bind+release pairs:
net-zero capacity, but they dirty the columns) so the incremental path
pays its per-round delta sync honestly. Every backend/mode closeness
matrix is asserted against ``topsis.closeness_np`` within 1e-5 before
timing. The pallas backend runs the kernel in interpret mode off-TPU
(recorded as ``interpret_mode``) and is capped at ``--pallas-max-nodes``
(default 8192) there — interpret-mode wall time is not a kernel
measurement, the cap just keeps the sweep finishable on CPU. Results are
printed as CSV and written to BENCH_scheduling.json.

Run: PYTHONPATH=src python benchmarks/scheduling_time.py \
        [--backend all|numpy|jax|pallas] \
        [--nodes 4,256,2048,8192,32768,65536] [--pods 64] \
        [--pallas-max-nodes 8192] [--smoke] [--out BENCH_scheduling.json]
"""
from __future__ import annotations

import argparse
import itertools
import time

import numpy as np

try:
    from benchmarks import common
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    import common
from repro.core.scheduler import BACKENDS, BatchScheduler, GreenPodScheduler
from repro.cluster.node import FleetState, NodeTable, make_fleet_nodes
from repro.cluster.workload import WORKLOADS, Pod
from repro.kernels.ops import _on_tpu

DEFAULT_NODES = (4, 256, 2048, 8192, 32768, 65536)
MAX_PER_POD_NODES = 8192     # the per-pod baseline stops scaling here
BIG_N = 32768                # fewer reps at and past this fleet size
DIRTY_PER_ROUND = 32         # node columns touched per timed rep


def _time(f, reps=10, warmup=2):
    for _ in range(warmup):
        f()
    t0 = time.perf_counter()
    for _ in range(reps):
        f()
    return (time.perf_counter() - t0) / reps


def make_queue(n_pods: int) -> list[Pod]:
    kinds = itertools.cycle(["light", "medium", "complex"])
    return [Pod(i, WORKLOADS[next(kinds)], "topsis") for i in range(n_pods)]


def _dirty(fleet: FleetState, rng: np.random.Generator,
           k: int = DIRTY_PER_ROUND) -> None:
    """Touch ~k node columns the way an engine round does (commit +
    completion): net-zero on capacity so every timed rep scores the same
    snapshot, but each touched column goes through the dirty tracker."""
    for i in rng.integers(0, len(fleet), size=k):
        if fleet.free_cpu[i] >= 0.25 and fleet.free_mem[i] >= 0.5:
            fleet.bind(i, 0.25, 0.5)
            fleet.release(i, 0.25, 0.5)


def verify_scores(label: str, got, want, atol=1e-5) -> float:
    """Max |closeness - want| over the queue's feasible entries, where
    ``want`` is the numpy-reference score matrix for the same snapshot."""
    got = np.asarray(got)
    finite = np.isfinite(want)
    assert np.array_equal(finite, np.isfinite(got)), \
        f"{label}: feasibility masks differ"
    err = float(np.max(np.abs(got[finite] - want[finite]))) \
        if finite.any() else 0.0
    assert err < atol, f"{label}: max closeness err {err:.2e} >= {atol}"
    return err


def run(backends=BACKENDS, node_counts=DEFAULT_NODES, n_pods: int = 64,
        reps: int = 10, out: str | None = "BENCH_scheduling.json",
        seed: int = 0, pallas_max_nodes: int = MAX_PER_POD_NODES) -> dict:
    interpret_mode = not _on_tpu()
    pods = make_queue(n_pods)
    results = []
    print("mode,backend,n_nodes,pods,ms_total,us_per_pod")

    def emit(rec):
        results.append(rec)
        print(f"{rec['mode']},{rec['backend']},{rec['n_nodes']},"
              f"{rec['pods']},{rec['ms_total']:.3f},"
              f"{rec['us_per_pod']:.1f}")

    for n in node_counts:
        n_reps = reps if n < BIG_N else max(2, reps // 3)
        fleet = FleetState.from_nodes(
            make_fleet_nodes(n, seed=seed, utilization=0.3))
        rng = np.random.default_rng(seed + 1)
        if n <= MAX_PER_POD_NODES:
            # the per-pod latency baseline: P independent select() calls
            g = GreenPodScheduler("energy_centric", backend="numpy")
            table = NodeTable.from_nodes(fleet.nodes)
            t = _time(lambda: [g.select(p, table) for p in pods],
                      reps=n_reps)
            emit({"mode": "per-pod", "backend": "numpy", "n_nodes": n,
                  "pods": n_pods, "ms_total": t * 1e3,
                  "us_per_pod": t / n_pods * 1e6})
        want = BatchScheduler("energy_centric", backend="numpy").score_queue(
            pods, NodeTable.from_nodes(fleet.nodes))
        for backend in backends:
            if backend == "pallas" and interpret_mode \
                    and n > pallas_max_nodes:
                print(f"# skip pallas at N={n}: interpret mode "
                      f"(--pallas-max-nodes {pallas_max_nodes})")
                continue
            # rebuild: flatten + full (P, N, C) build + score, per round
            s_reb = BatchScheduler("energy_centric", backend=backend)
            verify_scores(
                f"rebuild/{backend}/N={n}",
                s_reb.score_queue(pods, NodeTable.from_nodes(fleet.nodes)),
                want)
            t_reb = _time(
                lambda: (_dirty(fleet, rng),
                         s_reb.select_many(
                             pods, NodeTable.from_nodes(fleet.nodes))),
                reps=n_reps)
            rec = {"mode": "rebuild", "backend": backend, "n_nodes": n,
                   "pods": n_pods, "ms_total": t_reb * 1e3,
                   "us_per_pod": t_reb / n_pods * 1e6}
            if backend == "pallas":
                rec["interpret_mode"] = interpret_mode
            emit(rec)
            # incremental: attached FleetState, dirty-column sync only
            s_inc = BatchScheduler("energy_centric", backend=backend)
            s_inc.attach(fleet)
            err = verify_scores(f"incremental/{backend}/N={n}",
                                s_inc.score_queue(pods, fleet), want)
            t_inc = _time(
                lambda: (_dirty(fleet, rng),
                         s_inc.select_many(pods, fleet)),
                reps=n_reps)
            rec = {"mode": "incremental", "backend": backend, "n_nodes": n,
                   "pods": n_pods, "ms_total": t_inc * 1e3,
                   "us_per_pod": t_inc / n_pods * 1e6,
                   "max_closeness_err_vs_numpy": err,
                   "speedup_vs_rebuild": t_reb / t_inc}
            if backend == "pallas":
                rec["interpret_mode"] = interpret_mode
            emit(rec)
    report = {"bench": "scheduling_time",
              "config": {"pods": n_pods, "reps": reps, "seed": seed,
                         "node_counts": list(node_counts),
                         "backends": list(backends),
                         "dirty_per_round": DIRTY_PER_ROUND,
                         "pallas_max_nodes": pallas_max_nodes,
                         "interpret_mode": interpret_mode},
              "results": results}
    return common.write_report(report, out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="all",
                    help="all | " + " | ".join(BACKENDS))
    ap.add_argument("--nodes", default=",".join(map(str, DEFAULT_NODES)),
                    help="comma-separated fleet sizes to sweep")
    ap.add_argument("--pods", type=int, default=64)
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--pallas-max-nodes", type=int,
                    default=MAX_PER_POD_NODES,
                    help="largest N the pallas backend runs at in "
                         "interpret mode (no cap on a real TPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI lane: N=8, 8 pods, 2 reps")
    ap.add_argument("--out", default="BENCH_scheduling.json")
    args = ap.parse_args()
    backends = common.resolve_backends(args.backend, default=BACKENDS)
    node_counts = common.split_csv_int(args.nodes)
    n_pods, reps = args.pods, args.reps
    if args.smoke:
        node_counts, n_pods, reps = list(common.SMOKE_NODE_COUNTS), 8, 2
    run(backends=backends, node_counts=node_counts, n_pods=n_pods,
        reps=reps, out=args.out, pallas_max_nodes=args.pallas_max_nodes)


if __name__ == "__main__":
    main()

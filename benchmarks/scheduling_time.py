"""Paper §IV.C 'Scheduling Time (ms)': TOPSIS decision latency.

The paper's cluster has 4 nodes; a production fleet has thousands. We sweep
N = 4 .. 4096 candidate nodes and time three backends:

  numpy    — the per-pod hot path used by the cluster scheduler
  jax-jit  — the jittable engine (fleet batch scoring on accelerators)
  kernel   — the Pallas TOPSIS kernel (interpret mode on CPU; compiles to
             Mosaic on a real TPU)

Also times the DEFAULT K8s scheduler's python scoring for reference.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import topsis
from repro.core.criteria import benefit_mask
from repro.kernels import ops


def _time(f, *args, reps=30, warmup=3):
    for _ in range(warmup):
        f(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        f(*args)
    return (time.perf_counter() - t0) / reps


def run(csv: bool = True):
    rng = np.random.default_rng(0)
    benefit = benefit_mask()
    w = np.full(5, 0.2)
    print("backend,n_nodes,us_per_decision")
    results = {}
    for n in (4, 16, 64, 256, 1024, 4096):
        M = rng.uniform(0.1, 10.0, (n, 5))
        t_np = _time(lambda: topsis.closeness_np(M, w, benefit))
        Mj = jax.numpy.asarray(M)
        wj = jax.numpy.asarray(w)
        bj = jax.numpy.asarray(benefit)
        vj = jax.numpy.ones((n,), bool)
        jf = jax.jit(lambda M, w, b, v:
                     topsis.closeness(M, w, b, v).closeness)
        t_jit = _time(lambda: jf(Mj, wj, bj, vj).block_until_ready())
        kf = jax.jit(lambda M, w, b: ops.topsis_closeness(M, w, b))
        t_k = _time(lambda: kf(Mj, wj, bj).block_until_ready(), reps=10)
        for name, t in (("numpy", t_np), ("jax-jit", t_jit),
                        ("pallas-interpret", t_k)):
            print(f"{name},{n},{t * 1e6:.1f}")
            results[(name, n)] = t * 1e6
    return results


if __name__ == "__main__":
    run()

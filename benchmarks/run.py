"""Benchmark entry point: one module per paper table/figure + the roofline
report (assignment §Roofline, from the dry-run artifacts if present).

Usage: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import time


def main() -> None:
    t0 = time.time()
    print("=" * 72)
    print("Table VI — energy by profile x competition (paper headline)")
    print("=" * 72)
    from benchmarks import table6_energy
    table6_energy.run()

    print()
    print("=" * 72)
    print("Fig 2 analogue — node allocation patterns (paper §V.D)")
    print("=" * 72)
    from benchmarks import node_allocation
    node_allocation.run()

    print()
    print("=" * 72)
    print("Scheduling time (paper §IV.C) — decision latency vs fleet size")
    print("=" * 72)
    from benchmarks import scheduling_time
    scheduling_time.run()

    print()
    print("=" * 72)
    print("Table VII — real-world impact extrapolation (paper §V.E-F)")
    print("=" * 72)
    from benchmarks import table7_impact
    table7_impact.run()

    if os.path.isdir("experiments/dryrun"):
        print()
        print("=" * 72)
        print("Roofline (assignment) — from dry-run artifacts")
        print("=" * 72)
        from benchmarks import roofline_report
        recs = roofline_report.load("experiments/dryrun", "single")
        if recs:
            print(roofline_report.fmt(recs))

    print(f"\n# benchmarks completed in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

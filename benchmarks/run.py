"""Benchmark entry point: one module per paper table/figure + the roofline
report (assignment §Roofline, from the dry-run artifacts if present),
plus an aggregation pass that folds every recorded ``BENCH_*.json``
(scheduling / scenarios / carbon / autoscale) into one summary
(``BENCH_summary.json``), and a cross-run regression gate.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # run benchmarks
    PYTHONPATH=src python -m benchmarks.run --check    # regression gate

``--check`` diffs each recorded BENCH_*.json against its committed
baseline under ``benchmarks/baselines/`` (see
``repro.telemetry.baseline``) and exits nonzero on any regression.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# The recorded sweep files the aggregation pass knows how to headline.
BENCH_FILES = ("BENCH_scheduling.json", "BENCH_scenarios.json",
               "BENCH_carbon.json", "BENCH_autoscale.json",
               "BENCH_pareto.json")

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")


def _headline(name: str, data: dict) -> dict:
    """Compress one recorded sweep into its headline numbers."""
    results = data.get("results", [])
    out: dict = {"bench": data.get("bench", name), "cells": len(results)}
    if name == "BENCH_scheduling.json":
        # best batched-vs-per-pod us/pod speedup at any fleet size
        perpod = {r["n_nodes"]: r["us_per_pod"] for r in results
                  if r.get("mode") == "per-pod" and r.get("backend") == "numpy"}
        speedups = [perpod[r["n_nodes"]] / r["us_per_pod"] for r in results
                    if r.get("mode") == "batched" and r.get("us_per_pod")
                    and r["n_nodes"] in perpod]
        if speedups:
            out["max_batched_speedup"] = round(max(speedups), 2)
    elif name == "BENCH_scenarios.json":
        rates = [r["unschedulable_rate"] for r in results
                 if "unschedulable_rate" in r]
        if rates:
            out["max_unschedulable_rate"] = max(rates)
    elif name == "BENCH_carbon.json":
        red = [s["carbon_reduction_pct"]
               for s in data.get("carbon_reduction_summary", [])]
        if red:
            out["carbon_reduction_pct_range"] = [min(red), max(red)]
    elif name == "BENCH_autoscale.json":
        red = [s["idle_reduction_pct"]
               for s in data.get("idle_reduction_summary", [])
               if s["policy"] == "idle_timeout"]
        if red:
            out["idle_reduction_pct_range"] = [min(red), max(red)]
    elif name == "BENCH_pareto.json":
        # headline: best fused-vs-serial speedup at S >= 512 on jax (the
        # acceptance number); falls back to any-S when the sweep was small
        ups = [r["speedup_fused_vs_serial"] for r in results
               if r.get("backend") == "jax"
               and r.get("speedup_fused_vs_serial")
               and r.get("n_schemes", 0) >= 512]
        if not ups:
            ups = [r["speedup_fused_vs_serial"] for r in results
                   if r.get("backend") == "jax"
                   and r.get("speedup_fused_vs_serial")]
        if ups:
            out["max_grid_speedup_jax"] = round(max(ups), 2)
    return out


def _provenance_warnings(summary: dict) -> list[str]:
    """Mismatched environment fingerprints across the aggregated sweeps:
    different git SHAs or pallas interpret-mode flags mean the summary
    mixes runs that are not comparable as one sweep."""
    provs = {name: head["provenance"] for name, head in summary.items()
             if isinstance(head, dict)
             and isinstance(head.get("provenance"), dict)}
    warnings: list[str] = []
    for field, what in (("git_sha", "git SHAs"),
                        ("pallas_interpret", "pallas interpret-mode "
                                             "flags")):
        values = {name: p[field] for name, p in provs.items()
                  if field in p and p[field] is not None}
        if len(set(values.values())) > 1:
            detail = ", ".join(f"{name}={v}"
                               for name, v in sorted(values.items()))
            warnings.append(
                f"aggregated sweeps carry mismatched {what} ({detail}) "
                f"— the summary mixes runs from different "
                f"{'commits' if field == 'git_sha' else 'pallas modes'}")
    return warnings


def aggregate(out: str | None = "BENCH_summary.json") -> dict:
    """Fold every recorded BENCH_*.json into one summary dict (and file).
    Missing or unreadable sweeps are skipped with a warning — run their
    benchmarks to (re-)record them."""
    summary: dict = {}
    for name in BENCH_FILES:
        if not os.path.exists(name):
            print(f"warning: {name} not recorded yet — run its sweep "
                  f"benchmark to record it (skipping)")
            continue
        try:
            with open(name) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: could not read {name} ({e}) — re-run its "
                  f"sweep benchmark (skipping)")
            continue
        if not isinstance(data, dict) or not isinstance(
                data.get("results", []), list):
            print(f"warning: {name} is not a sweep report (expected an "
                  f"object with a 'results' list) — re-run its sweep "
                  f"benchmark (skipping)")
            continue
        try:
            head = _headline(name, data)
        except (AttributeError, KeyError, TypeError, ZeroDivisionError) as e:
            print(f"warning: {name} has an unexpected shape ({e!r}) — "
                  f"re-run its sweep benchmark (skipping)")
            continue
        # carry each sweep's recorded environment fingerprint forward so
        # the summary's numbers stay attributable without the sweep files
        if isinstance(data.get("provenance"), dict):
            head["provenance"] = data["provenance"]
        summary[name] = head
    if not summary:
        print("no BENCH_*.json recorded yet; run the sweep benchmarks first")
        return summary
    # a summary stitched from sweeps recorded at different commits or
    # pallas modes is not one coherent run — say so, loudly
    warnings = _provenance_warnings(summary)
    for w in warnings:
        print(f"warning: {w}")
    if warnings:
        summary["provenance_warnings"] = warnings
    print(f"{'sweep':28s} headline")
    for name, head in summary.items():
        extras = {k: v for k, v in head.items()
                  if k not in ("bench", "cells", "provenance")}
        print(f"{head['bench']:28s} {head['cells']} cells  "
              + "  ".join(f"{k}={v}" for k, v in extras.items()))
    from benchmarks.common import provenance
    summary["provenance"] = provenance()
    if out:
        with open(out, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"wrote {out}")
    return summary


def check(files=BENCH_FILES, baseline_dir: str = BASELINE_DIR,
          verbose: bool = False) -> int:
    """Regression gate: diff each fresh BENCH_*.json against its
    committed baseline; returns the exit code (1 iff any gated metric
    regressed). Missing current files or baselines are warnings, not
    failures — a sweep that was never run can't regress."""
    from repro.telemetry.baseline import (append_history, compare_reports,
                                          format_verdict)
    from benchmarks.common import HISTORY_DIR, provenance

    exit_code = 0
    checked = 0
    for name in files:
        base_path = os.path.join(baseline_dir, name)
        if not os.path.exists(name):
            print(f"warning: {name} not recorded — run its sweep before "
                  f"checking (skipping)")
            continue
        if not os.path.exists(base_path):
            print(f"warning: no committed baseline at {base_path} "
                  f"(skipping {name})")
            continue
        try:
            with open(name) as f:
                current = json.load(f)
            with open(base_path) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: could not read {name} or its baseline "
                  f"({e}) — skipping")
            continue
        verdict = compare_reports(current, baseline)
        print(format_verdict(verdict, verbose=verbose))
        checked += 1
        bench = verdict["bench"] or name
        append_history(
            {"kind": "check", "bench": bench,
             "status": verdict["status"],
             "regressions": verdict["regressions"],
             "provenance": current.get("provenance") or provenance()},
            os.path.join(HISTORY_DIR, f"{bench}.jsonl"))
        if verdict["status"] == "regression":
            exit_code = 1
    if not checked:
        print("nothing checked: no (recorded sweep, committed baseline) "
              "pair found")
    return exit_code


def main() -> None:
    t0 = time.time()
    print("=" * 72)
    print("Table VI — energy by profile x competition (paper headline)")
    print("=" * 72)
    from benchmarks import table6_energy
    table6_energy.run()

    print()
    print("=" * 72)
    print("Fig 2 analogue — node allocation patterns (paper §V.D)")
    print("=" * 72)
    from benchmarks import node_allocation
    node_allocation.run()

    print()
    print("=" * 72)
    print("Scheduling time (paper §IV.C) — decision latency vs fleet size")
    print("=" * 72)
    from benchmarks import scheduling_time
    scheduling_time.run()

    print()
    print("=" * 72)
    print("Table VII — real-world impact extrapolation (paper §V.E-F)")
    print("=" * 72)
    from benchmarks import table7_impact
    table7_impact.run()

    if os.path.isdir("experiments/dryrun"):
        print()
        print("=" * 72)
        print("Roofline (assignment) — from dry-run artifacts")
        print("=" * 72)
        from benchmarks import roofline_report
        recs = roofline_report.load("experiments/dryrun", "single")
        if recs:
            print(roofline_report.fmt(recs))

    print()
    print("=" * 72)
    print("Recorded sweep summary — BENCH_*.json aggregation")
    print("=" * 72)
    aggregate()

    print(f"\n# benchmarks completed in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="diff recorded BENCH_*.json against the "
                         "committed baselines and exit nonzero on "
                         "regression (runs no benchmarks)")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR,
                    help="baseline directory for --check")
    ap.add_argument("--verbose", action="store_true",
                    help="with --check, print ok rows too")
    args = ap.parse_args()
    if args.check:
        sys.exit(check(baseline_dir=args.baseline_dir,
                       verbose=args.verbose))
    main()

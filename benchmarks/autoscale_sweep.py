"""Autoscale sweep: energy, carbon, and latency per (scenario x policy x
backend) through the elastic event-driven engine.

Every cell streams Poisson bursts (half the pods deferrable, with real
deadlines) onto a scenario fleet with a flat carbon signal attached (for
carbon accounting — zero carbon weight, so placements stay comparable) and
one of four elasticity policies:

  * ``none``         — today's engine: no lifecycle, no state ledger. Its
                       fleet idle energy is the *always-on analytic
                       baseline* sum(idle_power) x horizon — what a fleet
                       without a lifecycle actually pays.
  * ``always_on``    — AutoscalePolicy(idle_timeout_s=inf): full state
                       accounting, nodes never sleep. Sanity row: its fleet
                       idle energy must equal the analytic baseline of its
                       own horizon.
  * ``idle_timeout`` — nodes empty for 60 s fall asleep; queue pressure
                       wakes the TOPSIS-best sleeping node.
  * ``consolidate``  — idle-timeout plus a periodic drain pass that
                       migrates low-utilization nodes' tasks and puts the
                       nodes straight to sleep.

Per cell we record fleet idle energy / total fleet energy / fleet carbon
(state ledger included), per-scheduler task energy, mean start delay and
exec time (wake latencies and migration reruns show up here), and the
wake/sleep/migration counters. The headline is the fleet idle-energy
reduction of ``idle_timeout`` (and ``consolidate``) vs the ``none``
baseline, asserted positive on at least one swept fleet — the acceptance
invariant (tight fleets that never idle long enough legitimately sit at
~0%) — along with a per-record check that no deferrable pod ever started
past its deadline.

Run: PYTHONPATH=src python benchmarks/autoscale_sweep.py \
        [--smoke] [--backend all|numpy|jax|pallas] \
        [--profiles mixed,edge_heavy] [--nodes 16,64] [--bursts 8] \
        [--burst-size 16] [--seed 0] [--out BENCH_autoscale.json]

``--smoke`` shrinks everything (one profile, 8 nodes, 3 bursts of 4) so CI
can exercise the whole elastic path in seconds.
"""
from __future__ import annotations

import math

try:
    from benchmarks import common
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    import common
from repro.core.carbon import CarbonPolicy, ConstantCarbon
from repro.core.elastic import AutoscalePolicy, always_on_fleet_idle_kj
from repro.cluster.node import make_scenario_cluster
from repro.cluster.simulator import run_scenario
from repro.cluster.workload import PoissonArrivals

DEFAULT_PROFILES = ("mixed", "edge_heavy")
DEFAULT_NODES = (16, 64)
DEFAULT_BACKENDS = common.DEFAULT_BACKENDS
CARBON_INTENSITY = 400.0          # flat gCO2/kWh: accounting only
DEADLINE_S = 900.0

POLICIES: dict[str, AutoscalePolicy | None] = {
    "none": None,
    "always_on": AutoscalePolicy(idle_timeout_s=math.inf),
    "idle_timeout": AutoscalePolicy(idle_timeout_s=60.0, min_awake=1),
    "consolidate": AutoscalePolicy(idle_timeout_s=60.0, min_awake=1,
                                   consolidate_interval_s=30.0,
                                   consolidate_util_below=0.3),
}


def _mean_start_delay_s(res) -> float:
    """Mean wait between arrival and first start per pod (wake latencies
    and capacity queueing both land here)."""
    first: dict[int, float] = {}
    arrival: dict[int, float] = {}
    for r in res.records:
        arrival[r.pod.uid] = r.arrival_s
        cur = first.get(r.pod.uid)
        if cur is None or r.start_s < cur:
            first[r.pod.uid] = r.start_s
    if not first:
        return 0.0
    return sum(first[u] - arrival[u] for u in first) / len(first)


def _check_deadlines(res) -> None:
    """No deferrable pod's attempt may start past its deadline (drains and
    wake latencies included)."""
    for r in res.records:
        if r.pod.deferrable:
            assert r.start_s <= r.arrival_s + r.pod.deadline_s + 1e-9, (
                f"deferrable pod {r.pod.uid} started at {r.start_s} past "
                f"deadline {r.arrival_s + r.pod.deadline_s}")


def run_cell(profile: str, n_nodes: int, policy_name: str, backend: str,
             n_bursts: int, burst_size: int, seed: int = 0) -> dict:
    nodes = make_scenario_cluster(profile, n_nodes, seed=seed)
    res = run_scenario(
        PoissonArrivals(rate_per_s=0.2, n_bursts=n_bursts,
                        burst_size=burst_size, seed=seed,
                        deferrable_share=0.5, deadline_s=DEADLINE_S),
        "energy_centric",
        cluster_factory=lambda: make_scenario_cluster(profile, n_nodes,
                                                      seed=seed),
        batch=True, batch_backend=backend,
        carbon=CarbonPolicy(ConstantCarbon(CARBON_INTENSITY)),
        autoscale=POLICIES[policy_name])
    _check_deadlines(res)
    horizon = max((r.start_s + r.runtime_s for r in res.records),
                  default=0.0)
    if policy_name == "none":
        # the lifecycle-free engine pays every node's idle power for the
        # whole run: the always-on analytic baseline
        fleet_idle_kj = always_on_fleet_idle_kj(nodes, horizon)
    else:
        fleet_idle_kj = res.fleet_idle_energy_kj()
    dyn_kj = res.timeline.dynamic_energy_j(None) / 1000.0
    return {
        "profile": profile, "n_nodes": n_nodes, "policy": policy_name,
        "backend": backend, "n_bursts": n_bursts, "burst_size": burst_size,
        "pods": len({r.pod.uid for r in res.records}) + res.unschedulable,
        "unschedulable_rate": res.unschedulable_rate(),
        "horizon_s": horizon,
        "fleet_idle_energy_kj": fleet_idle_kj,
        "fleet_energy_kj": dyn_kj + fleet_idle_kj,
        "fleet_carbon_g": (res.fleet_carbon_g() if policy_name != "none"
                           else (dyn_kj + fleet_idle_kj) * 1000.0
                           * CARBON_INTENSITY / 3.6e6),
        "energy_topsis_kj": res.energy_kj("topsis"),
        "energy_default_kj": res.energy_kj("default"),
        "mean_start_delay_s": _mean_start_delay_s(res),
        "mean_exec_time_topsis_s": res.mean_exec_time_s("topsis"),
        "wakes": res.wakes, "sleeps": res.sleeps,
        "migrations": res.migrations,
    }


def run(profiles=DEFAULT_PROFILES, node_counts=DEFAULT_NODES,
        policies=tuple(POLICIES), backends=DEFAULT_BACKENDS,
        n_bursts: int = 8, burst_size: int = 16, seed: int = 0,
        out: str | None = "BENCH_autoscale.json") -> dict:
    results = []
    print("profile,n_nodes,policy,backend,pods,fleet_idle_kJ,fleet_kJ,"
          "delay_s,wakes,sleeps,migr")
    for profile, n, policy_name, backend in common.iter_cells(
            profiles, node_counts, policies, backends):
        rec = run_cell(profile, n, policy_name, backend,
                       n_bursts, burst_size, seed=seed)
        results.append(rec)
        print(f"{profile},{n},{policy_name},{backend},"
              f"{rec['pods']},"
              f"{rec['fleet_idle_energy_kj']:.4f},"
              f"{rec['fleet_energy_kj']:.4f},"
              f"{rec['mean_start_delay_s']:.2f},"
              f"{rec['wakes']},{rec['sleeps']},"
              f"{rec['migrations']}")
    # headline: fleet idle-energy reduction vs the no-policy baseline
    summary = []
    by_key = {(r["profile"], r["n_nodes"], r["backend"], r["policy"]): r
              for r in results}
    for (profile, n, backend, policy_name), r in by_key.items():
        if policy_name in ("none", "always_on"):
            continue
        base = by_key.get((profile, n, backend, "none"))
        if base and base["fleet_idle_energy_kj"] > 0:
            summary.append({
                "profile": profile, "n_nodes": n, "backend": backend,
                "policy": policy_name,
                "idle_reduction_pct": 100.0
                * (1.0 - r["fleet_idle_energy_kj"]
                   / base["fleet_idle_energy_kj"])})
    for s in summary:
        print(f"{s['policy']} vs none ({s['profile']}, {s['n_nodes']}, "
              f"{s['backend']}): {s['idle_reduction_pct']:.1f}% less fleet "
              f"idle energy")
    # acceptance: idle_timeout cuts fleet idle energy on every fleet swept
    assert any(s["policy"] == "idle_timeout" and s["idle_reduction_pct"] > 0
               for s in summary), \
        "idle_timeout policy failed to reduce fleet idle energy anywhere"
    report = {"bench": "autoscale_sweep",
              "config": {"profiles": list(profiles),
                         "node_counts": list(node_counts),
                         "policies": list(policies),
                         "backends": list(backends),
                         "n_bursts": n_bursts, "burst_size": burst_size,
                         "seed": seed, "deadline_s": DEADLINE_S,
                         "carbon_intensity": CARBON_INTENSITY},
              "results": results,
              "idle_reduction_summary": summary}
    return common.write_report(report, out)


def main():
    ap = common.sweep_parser("BENCH_autoscale.json", DEFAULT_PROFILES,
                             DEFAULT_NODES, policies=tuple(POLICIES))
    args = ap.parse_args()
    profiles = common.split_csv(args.profiles)
    run(profiles=profiles[:1] if args.smoke else profiles,
        policies=common.split_csv(args.policies),
        backends=common.resolve_backends(args.backend),
        seed=args.seed, out=args.out, **common.sweep_sizes(args))


if __name__ == "__main__":
    main()

"""Pareto weight-scheme sweep: fused grid dispatch vs serial per-scheme loop.

The frontier workload (repro.core.pareto) scores one pod queue under S
weighting schemes on one fleet snapshot — the offline what-if analysis an
operator runs to pick a scheme. This benchmark sweeps S x fleet size x
backend and times the scoring round two ways through the SAME attached
incremental machinery (FleetCriteriaCache; jax keeps the criteria tensor
device-resident, no re-upload per scheme):

  fused   — ONE ``BatchScheduler.score_queue_grid`` call over the whole
            (S, C) grid: one engine dispatch for the (S, P, N) tensor
            (jax: ``topsis.closeness_grid``; pallas: the weight-grid kernel
            with schemes innermost so each criteria node-block is fetched
            once; numpy: the scheme x pod reference loop)
  serial  — S single-scheme ``score_queue_grid`` calls, one per grid row:
            the pre-grid status quo of one scoring round per scheme. On
            numpy both modes are the same Python loop (speedup ~1x, there
            is no dispatch to amortize); the jax speedup is the headline.

Before timing, every backend's fused (S, P, N) tensor is verified against
the ``topsis.closeness_grid_np`` float64 reference at 1e-5. The reference
scores also drive the frontier lane: per-scheme greedy placements
(``_greedy_assign``), decision-tensor metrics
(``pareto.points_from_placements``), and the exact dominance filter —
``frontier_size`` and ``frontier_checksum`` are backend-independent and
gated EXACTLY by the regression check (timings are one-sided). The pallas
backend is opt-in off-TPU (interpret mode, flagged ``interpret_mode``) and
capped by ``--pallas-max-schemes``; numpy timing is capped by
``--numpy-max-schemes`` (the frontier/reference lane still runs at full S).

Run: PYTHONPATH=src python benchmarks/pareto_sweep.py \
        [--backend all|numpy|jax|pallas] [--nodes 64,1024] \
        [--schemes 5,64,512,4096] [--pods 8] [--smoke] \
        [--out BENCH_pareto.json]
"""
from __future__ import annotations

import argparse
import itertools
import time

import numpy as np

try:
    from benchmarks import common
except ImportError:          # run as a script: benchmarks/ is sys.path[0]
    import common
from repro.core import pareto, topsis
from repro.core.criteria import benefit_mask
from repro.core.scheduler import (BACKENDS, BatchScheduler, _greedy_assign,
                                  decision_matrix_batch)
from repro.cluster.node import FleetState, NodeTable, make_fleet_nodes
from repro.cluster.workload import WORKLOADS, Pod
from repro.kernels.ops import _on_tpu

DEFAULT_NODES = (64, 1024)
DEFAULT_SCHEME_COUNTS = (5, 64, 512, 4096)
DEFAULT_PODS = 8             # keeps the S=4096 (S, P, N, C) tensor in RAM
BIG_S = 512                  # fewer reps at and past this scheme count
MAX_NUMPY_SCHEMES = 64       # numpy timing cap (reference lane uncapped)
MAX_PALLAS_SCHEMES = 512     # pallas interpret-mode timing cap off-TPU


def _time(f, reps=5, warmup=2):
    for _ in range(warmup):
        f()
    t0 = time.perf_counter()
    for _ in range(reps):
        f()
    return (time.perf_counter() - t0) / reps


def make_queue(n_pods: int) -> list[Pod]:
    kinds = itertools.cycle(["light", "medium", "complex"])
    return [Pod(i, WORKLOADS[next(kinds)], "topsis") for i in range(n_pods)]


def _frontier_fingerprint(points) -> tuple[int, float]:
    """(size, checksum) of the Pareto set — an exact, order-sensitive
    membership fingerprint (sum of squared 1-based member indices, folded
    to 31 bits) so the regression gate catches any membership change."""
    front = pareto.frontier_for(points)
    members = np.flatnonzero(front.mask).astype(np.int64)
    checksum = int(((members + 1) ** 2).sum() % (2 ** 31))
    return int(front.mask.sum()), float(checksum)


def run(backends=common.DEFAULT_BACKENDS, node_counts=DEFAULT_NODES,
        scheme_counts=DEFAULT_SCHEME_COUNTS, n_pods: int = DEFAULT_PODS,
        reps: int = 5, out: str | None = "BENCH_pareto.json", seed: int = 0,
        numpy_max_schemes: int = MAX_NUMPY_SCHEMES,
        pallas_max_schemes: int = MAX_PALLAS_SCHEMES) -> dict:
    interpret_mode = not _on_tpu()
    pods = make_queue(n_pods)
    benefit = benefit_mask()
    results = []
    print("backend,n_nodes,n_schemes,pods,ms_fused,ms_serial,speedup,"
          "frontier_size")

    def emit(rec):
        results.append(rec)
        print(f"{rec['backend']},{rec['n_nodes']},{rec['n_schemes']},"
              f"{rec['pods']},{rec['ms_fused']:.3f},{rec['ms_serial']:.3f},"
              f"{rec['speedup_fused_vs_serial']:.2f},"
              f"{rec['frontier_size']}")

    for n in node_counts:
        nodes = make_fleet_nodes(n, seed=seed, utilization=0.3)
        table = NodeTable.from_nodes(nodes)
        mats = decision_matrix_batch(pods, table)
        valid = table.fits(np.asarray([p.cpu for p in pods])[:, None],
                           np.asarray([p.mem for p in pods])[:, None])
        for n_s in scheme_counts:
            ws = pareto.weight_grid_upto(n_s)
            # float64 reference: verification oracle AND the frontier lane
            want = topsis.closeness_grid_np(mats, ws, benefit, valid)
            assignments = [_greedy_assign(want[s], pods, table)
                           for s in range(n_s)]
            points = pareto.points_from_placements(ws, assignments, mats)
            frontier_size, frontier_checksum = _frontier_fingerprint(points)
            n_reps = reps if n_s < BIG_S else max(2, reps // 3)
            for backend in backends:
                if backend == "numpy" and n_s > numpy_max_schemes:
                    print(f"# skip numpy timing at S={n_s}: the serial "
                          f"reference loop is O(S*P) closeness_np calls "
                          f"(--numpy-max-schemes {numpy_max_schemes})")
                    continue
                if backend == "pallas" and interpret_mode \
                        and n_s > pallas_max_schemes:
                    print(f"# skip pallas at S={n_s}: interpret mode "
                          f"(--pallas-max-schemes {pallas_max_schemes})")
                    continue
                fleet = FleetState.from_nodes(
                    make_fleet_nodes(n, seed=seed, utilization=0.3))
                sched = BatchScheduler("general", backend=backend)
                sched.attach(fleet)
                got = sched.score_queue_grid(pods, fleet, ws)
                finite = np.isfinite(want)
                assert np.array_equal(finite, np.isfinite(got)), \
                    f"{backend}/N={n}/S={n_s}: feasibility masks differ"
                err = float(np.max(np.abs(got[finite] - want[finite])))
                assert err < 1e-5, \
                    f"{backend}/N={n}/S={n_s}: closeness err {err:.2e}"
                t_fused = _time(
                    lambda: sched.score_queue_grid(pods, fleet, ws),
                    reps=n_reps)
                # the pre-grid status quo: one scoring round per scheme
                # through the same attached incremental path (single-row
                # grids share one jit trace; S dispatches per rep)
                t_serial = _time(
                    lambda: [sched.score_queue_grid(pods, fleet,
                                                    ws[s:s + 1])
                             for s in range(n_s)],
                    reps=max(1, n_reps // 2), warmup=1)
                rec = {"backend": backend, "n_nodes": n, "n_schemes": n_s,
                       "pods": n_pods, "ms_fused": t_fused * 1e3,
                       "ms_serial": t_serial * 1e3,
                       "us_per_scheme_fused": t_fused / n_s * 1e6,
                       "speedup_fused_vs_serial": t_serial / t_fused,
                       "max_closeness_err_vs_numpy": err,
                       "frontier_size": frontier_size,
                       "frontier_checksum": frontier_checksum}
                if backend == "pallas":
                    rec["interpret_mode"] = interpret_mode
                emit(rec)
    report = {"bench": "pareto_sweep",
              "config": {"pods": n_pods, "reps": reps, "seed": seed,
                         "node_counts": list(node_counts),
                         "scheme_counts": list(scheme_counts),
                         "backends": list(backends),
                         "numpy_max_schemes": numpy_max_schemes,
                         "pallas_max_schemes": pallas_max_schemes,
                         "interpret_mode": interpret_mode},
              "results": results}
    return common.write_report(report, out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="all",
                    help="all (= numpy,jax; pallas is opt-in, interpret "
                         "mode is slow on CPU) | comma-list from "
                         + ",".join(BACKENDS))
    ap.add_argument("--nodes", default=",".join(map(str, DEFAULT_NODES)),
                    help="comma-separated fleet sizes to sweep")
    ap.add_argument("--schemes",
                    default=",".join(map(str, DEFAULT_SCHEME_COUNTS)),
                    help="comma-separated scheme-grid sizes S to sweep")
    ap.add_argument("--pods", type=int, default=DEFAULT_PODS)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--numpy-max-schemes", type=int,
                    default=MAX_NUMPY_SCHEMES,
                    help="largest S the numpy backend is TIMED at (its "
                         "reference/frontier lane always runs at full S)")
    ap.add_argument("--pallas-max-schemes", type=int,
                    default=MAX_PALLAS_SCHEMES,
                    help="largest S the pallas backend runs at in "
                         "interpret mode (no cap on a real TPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI lane: N=8, S=4, 4 pods, 2 reps")
    ap.add_argument("--out", default="BENCH_pareto.json")
    args = ap.parse_args()
    backends = common.resolve_backends(args.backend)
    node_counts = common.split_csv_int(args.nodes)
    scheme_counts = common.split_csv_int(args.schemes)
    n_pods, reps = args.pods, args.reps
    if args.smoke:
        node_counts = list(common.SMOKE_NODE_COUNTS)
        scheme_counts, n_pods, reps = [4], 4, 2
    run(backends=backends, node_counts=node_counts,
        scheme_counts=scheme_counts, n_pods=n_pods, reps=reps,
        out=args.out, numpy_max_schemes=args.numpy_max_schemes,
        pallas_max_schemes=args.pallas_max_schemes)


if __name__ == "__main__":
    main()
